"""L2: JAX compute graphs (build-time only; lowered to HLO by aot.py).

Entry points exported to the Rust runtime:

  * linreg_grad / linreg_loss       — least squares (calls L1 kernel)
  * logreg_grad / logreg_loss       — logistic regression (calls L1 kernel)
  * simhash_codes                   — batched SimHash codes (L1 kernel)
  * bert_grad / bert_logits / bert_pooled — the mini-BERT stand-in for
    the paper's §3.2 fine-tuning experiment (Appendix E): a small
    transformer encoder whose pooled [CLS] representation feeds the LSH
    tables while Rust coordinates sampling and optimisation.

All functions are pure and shape-static; the Rust side owns state.
"""

import jax
import jax.numpy as jnp

from compile.kernels import linreg_grad as _linreg_grad_kernel
from compile.kernels import logreg_grad as _logreg_grad_kernel
from compile.kernels import pack_codes, simhash_signs

# ---------------------------------------------------------------------------
# Linear models (delegate to the L1 Pallas kernels)
# ---------------------------------------------------------------------------


def linreg_grad(x, y, theta, weights):
    """Weighted minibatch least-squares gradient. Returns a 1-tuple."""
    return (_linreg_grad_kernel(x, y, theta, weights),)


def linreg_loss(x, y, theta):
    """Mean squared residual; weights not applied (plain loss eval)."""
    r = x @ theta - y
    return (jnp.mean(r * r),)


def logreg_grad(x, y, theta, weights):
    """Weighted minibatch logistic gradient. Returns a 1-tuple."""
    return (_logreg_grad_kernel(x, y, theta, weights),)


def logreg_loss(x, y, theta):
    """Mean logistic loss (labels ±1)."""
    m = y * (x @ theta)
    return (jnp.mean(jnp.logaddexp(0.0, -m)),)


def simhash_codes(x, planes, k, l):
    """(B, L) uint32 SimHash table codes of a batch (L1 kernel + packing)."""
    signs = simhash_signs(x, planes)
    return (pack_codes(signs, k, l),)


# ---------------------------------------------------------------------------
# Mini-BERT: transformer encoder for the §3.2 stand-in task
# ---------------------------------------------------------------------------

# Architecture constants (small enough to fine-tune on CPU in seconds,
# structured exactly like BERT: embeddings -> N encoder layers -> pooled
# [CLS] -> classifier).
VOCAB = 1024
MAX_T = 32
D_MODEL = 64
N_HEADS = 4
D_FF = 256
N_LAYERS = 2
N_CLASSES = 2

# Parameter layout: a flat, ordered list of (name, shape). The Rust
# runtime threads parameters positionally, so ORDER IS ABI.
def bert_param_spec():
    """Ordered (name, shape) list of all mini-BERT parameters."""
    spec = [
        ("tok_emb", (VOCAB, D_MODEL)),
        ("pos_emb", (MAX_T, D_MODEL)),
    ]
    for i in range(N_LAYERS):
        spec += [
            (f"l{i}.wq", (D_MODEL, D_MODEL)),
            (f"l{i}.wk", (D_MODEL, D_MODEL)),
            (f"l{i}.wv", (D_MODEL, D_MODEL)),
            (f"l{i}.wo", (D_MODEL, D_MODEL)),
            (f"l{i}.ln1_g", (D_MODEL,)),
            (f"l{i}.ln1_b", (D_MODEL,)),
            (f"l{i}.ff1", (D_MODEL, D_FF)),
            (f"l{i}.ff1_b", (D_FF,)),
            (f"l{i}.ff2", (D_FF, D_MODEL)),
            (f"l{i}.ff2_b", (D_MODEL,)),
            (f"l{i}.ln2_g", (D_MODEL,)),
            (f"l{i}.ln2_b", (D_MODEL,)),
        ]
    spec += [
        ("pool_w", (D_MODEL, D_MODEL)),
        ("pool_b", (D_MODEL,)),
        ("cls_w", (D_MODEL, N_CLASSES)),
        ("cls_b", (N_CLASSES,)),
    ]
    return spec


def bert_init_params(seed=0):
    """Initialise parameters (list of arrays in spec order)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in bert_param_spec():
        key, sub = jax.random.split(key)
        if name.endswith(("_b", "_g")):
            init = jnp.ones(shape) if name.endswith("_g") else jnp.zeros(shape)
        else:
            fan_in = shape[0]
            init = jax.random.normal(sub, shape) * (1.0 / jnp.sqrt(fan_in))
        params.append(init.astype(jnp.float32))
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, wq, wk, wv, wo):
    b, t, d = x.shape
    hd = d // N_HEADS

    def split(w_x):
        return w_x.reshape(b, t, N_HEADS, hd).transpose(0, 2, 1, 3)

    q = split(x @ wq)
    k = split(x @ wk)
    v = split(x @ wv)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(hd)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def _encoder(params, ids):
    """ids (B, T) int32 -> hidden states (B, T, D_MODEL)."""
    names = [n for n, _ in bert_param_spec()]
    p = dict(zip(names, params))
    b, t = ids.shape
    h = p["tok_emb"][ids] + p["pos_emb"][None, :t, :]
    for i in range(N_LAYERS):
        a = _attention(h, p[f"l{i}.wq"], p[f"l{i}.wk"], p[f"l{i}.wv"], p[f"l{i}.wo"])
        h = _layer_norm(h + a, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        ff = jax.nn.gelu(h @ p[f"l{i}.ff1"] + p[f"l{i}.ff1_b"]) @ p[f"l{i}.ff2"] + p[f"l{i}.ff2_b"]
        h = _layer_norm(h + ff, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
    return h


def _pool(params, h):
    """BERT-style pooled representation: tanh(W h_[CLS] + b)."""
    names = [n for n, _ in bert_param_spec()]
    p = dict(zip(names, params))
    return jnp.tanh(h[:, 0, :] @ p["pool_w"] + p["pool_b"])


def _logits_from_params(params, ids):
    h = _encoder(params, ids)
    pooled = _pool(params, h)
    names = [n for n, _ in bert_param_spec()]
    p = dict(zip(names, params))
    return pooled @ p["cls_w"] + p["cls_b"]


def _weighted_ce(params, ids, labels, weights):
    logits = _logits_from_params(params, ids)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(weights * nll)


def bert_grad(*args):
    """Loss and gradients of the weighted fine-tuning objective.

    Args (positional, ABI order): *params, ids (B,T) int32,
      labels (B,) int32, weights (B,) float32.

    Returns: (loss, *grads) — grads in parameter order. The optimiser
    (Adam, per §3.2) runs on the Rust side.
    """
    n = len(bert_param_spec())
    params, (ids, labels, weights) = list(args[:n]), args[n:]
    loss, grads = jax.value_and_grad(_weighted_ce)(params, ids, labels, weights)
    return (loss, *grads)


def bert_logits(*args):
    """Classifier logits: *params, ids -> (B, N_CLASSES)."""
    n = len(bert_param_spec())
    params, (ids,) = list(args[:n]), args[n:]
    return (_logits_from_params(params, ids),)


def bert_pooled(*args):
    """Pooled [CLS] representations: *params, ids -> (B, D_MODEL).

    These are the vectors Appendix E hashes into the LSH tables (and
    periodically refreshes as fine-tuning drifts them).
    """
    n = len(bert_param_spec())
    params, (ids,) = list(args[:n]), args[n:]
    return (_pool(params, _encoder(params, ids)),)
