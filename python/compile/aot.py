"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Writes one `<entry>.hlo.txt` per entry point plus `manifest.json`
describing argument/output shapes and dtypes plus the mini-BERT
parameter ABI — everything the Rust runtime needs to build literals.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (batch, dim) combinations compiled for the linear models. d values are
# the paper's three regression datasets (Table 4; Slice per appendix D);
# batch 1 is the paper's plain setting, the larger batches serve the
# minibatch ablations and loss evaluation.
LINREG_DIMS = (90, 385, 529)
GRAD_BATCHES = (1, 32, 256)
LOSS_BATCH = 1024
LOGREG_DIM = 64
SIMHASH_SHAPES = ((64, 91),)  # (batch, hash-space dim) for yearmsd-like
SIMHASH_K = 5
SIMHASH_L = 100


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _shape_struct(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries():
    """Yield (name, jitted_fn, example_args, arg_specs, out_specs)."""
    entries = []

    for d in LINREG_DIMS:
        for b in GRAD_BATCHES:
            name = f"linreg_grad_b{b}_d{d}"
            args = [
                _shape_struct((b, d)),
                _shape_struct((b,)),
                _shape_struct((d,)),
                _shape_struct((b,)),
            ]
            entries.append(
                (
                    name,
                    model.linreg_grad,
                    args,
                    [_spec((b, d)), _spec((b,)), _spec((d,)), _spec((b,))],
                    [_spec((d,))],
                )
            )
        name = f"linreg_loss_b{LOSS_BATCH}_d{d}"
        args = [
            _shape_struct((LOSS_BATCH, d)),
            _shape_struct((LOSS_BATCH,)),
            _shape_struct((d,)),
        ]
        entries.append(
            (
                name,
                model.linreg_loss,
                args,
                [_spec((LOSS_BATCH, d)), _spec((LOSS_BATCH,)), _spec((d,))],
                [_spec(())],
            )
        )

    d = LOGREG_DIM
    for b in (1, 32):
        entries.append(
            (
                f"logreg_grad_b{b}_d{d}",
                model.logreg_grad,
                [
                    _shape_struct((b, d)),
                    _shape_struct((b,)),
                    _shape_struct((d,)),
                    _shape_struct((b,)),
                ],
                [_spec((b, d)), _spec((b,)), _spec((d,)), _spec((b,))],
                [_spec((d,))],
            )
        )
    entries.append(
        (
            f"logreg_loss_b{LOSS_BATCH}_d{d}",
            model.logreg_loss,
            [
                _shape_struct((LOSS_BATCH, d)),
                _shape_struct((LOSS_BATCH,)),
                _shape_struct((d,)),
            ],
            [_spec((LOSS_BATCH, d)), _spec((LOSS_BATCH,)), _spec((d,))],
            [_spec(())],
        )
    )

    for b, hd in SIMHASH_SHAPES:
        p = SIMHASH_K * SIMHASH_L

        def simhash_fn(x, planes, _k=SIMHASH_K, _l=SIMHASH_L):
            return model.simhash_codes(x, planes, _k, _l)

        entries.append(
            (
                f"simhash_b{b}_d{hd}_k{SIMHASH_K}_l{SIMHASH_L}",
                simhash_fn,
                [_shape_struct((b, hd)), _shape_struct((p, hd))],
                [_spec((b, hd)), _spec((p, hd))],
                [_spec((b, SIMHASH_L), "u32")],
            )
        )

    # --- mini-BERT ---
    spec = model.bert_param_spec()
    pshapes = [s for _, s in spec]
    params = [_shape_struct(s) for s in pshapes]
    bt, tt = 32, model.MAX_T
    entries.append(
        (
            "bert_grad_b32",
            model.bert_grad,
            params
            + [
                _shape_struct((bt, tt), jnp.int32),
                _shape_struct((bt,), jnp.int32),
                _shape_struct((bt,)),
            ],
            [_spec(s) for s in pshapes]
            + [_spec((bt, tt), "s32"), _spec((bt,), "s32"), _spec((bt,))],
            [_spec(())] + [_spec(s) for s in pshapes],
        )
    )
    be = 64
    entries.append(
        (
            "bert_logits_b64",
            model.bert_logits,
            params + [_shape_struct((be, tt), jnp.int32)],
            [_spec(s) for s in pshapes] + [_spec((be, tt), "s32")],
            [_spec((be, model.N_CLASSES))],
        )
    )
    entries.append(
        (
            "bert_pooled_b64",
            model.bert_pooled,
            params + [_shape_struct((be, tt), jnp.int32)],
            [_spec(s) for s in pshapes] + [_spec((be, tt), "s32")],
            [_spec((be, model.D_MODEL))],
        )
    )
    return entries


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated entry filter")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(filter(None, args.only.split(",")))

    manifest = {
        "format": "hlo-text",
        "entries": {},
        "bert": {
            "param_names": [n for n, _ in model.bert_param_spec()],
            "param_shapes": [list(s) for _, s in model.bert_param_spec()],
            "vocab": model.VOCAB,
            "max_t": model.MAX_T,
            "d_model": model.D_MODEL,
            "n_classes": model.N_CLASSES,
        },
        "simhash": {"k": SIMHASH_K, "l": SIMHASH_L},
    }
    for name, fn, example_args, arg_specs, out_specs in build_entries():
        if only and name not in only:
            continue
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": fname,
            "args": arg_specs,
            "outputs": out_specs,
        }
        print(f"  {name}: {len(text)} chars")
    # Initial mini-BERT parameters (npz; keys carry a sort index so the
    # Rust loader can restore ABI order).
    if not only or "bert_init" in only:
        import numpy as np

        params = model.bert_init_params(seed=0)
        names = [n for n, _ in model.bert_param_spec()]
        arrs = {f"p{i:03d}_{n}": np.asarray(p) for i, (n, p) in enumerate(zip(names, params))}
        np.savez(os.path.join(args.out_dir, "bert_init.npz"), **arrs)
        manifest["bert"]["init_file"] = "bert_init.npz"

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
