"""Pallas kernel: importance-weighted batched logistic gradient.

    g = (1/B) * sum_b  w_b * (-y_b) * sigma(-y_b x_b.theta) * x_b

Same batch-tiled accumulator structure as `linreg_grad` (see that module
for the VMEM/MXU tiling rationale); the only difference is the VPU
epilogue computing the sigmoid weighting.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logreg_grad_kernel(x_ref, y_ref, w_ref, th_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]  # (bb, d)
    yb = y_ref[...]
    m = yb * (xb @ th_ref[...])  # (bb,) margins
    s = 1.0 / (1.0 + jnp.exp(m))  # sigma(-m)
    c = -(w_ref[...] * yb * s)  # (bb,)
    o_ref[...] += c @ xb


@functools.partial(jax.jit, static_argnames=("block_b",))
def logreg_grad(x, y, theta, weights, *, block_b=256):
    """Weighted batched logistic gradient via a Pallas kernel.

    Args:
      x: (B, d) float32, y: (B,) float32 labels in ±1, theta: (d,),
      weights: (B,) float32.

    Returns:
      (d,) float32 gradient estimate (mean over the batch).
    """
    b, d = x.shape
    bb = min(block_b, b)
    pad = -b % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        # pad labels with +1 to keep margins finite; zero weight kills them
        y = jnp.pad(y, (0, pad), constant_values=1.0)
        weights = jnp.pad(weights, (0, pad))
    grid = ((b + pad) // bb,)
    out = pl.pallas_call(
        _logreg_grad_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        interpret=True,
    )(x, y, weights, theta)
    return out / b
