"""Pallas kernel: importance-weighted batched least-squares gradient.

Computes the LGD/SGD minibatch gradient estimate
    g = (1/B) * sum_b  w_b * 2 (x_b . theta - y_b) * x_b
by tiling the batch dimension: each grid step loads a (block_b, d) tile
of X into VMEM, forms the residual on the VPU, and accumulates the
rank-1 updates as a (block_b,) x (block_b, d) vector-matrix product on
the MXU. The output block index is constant across the grid, which in
Pallas semantics makes `o_ref` a revisited accumulator.

VMEM budget: block_b * d * 4 bytes per tile (256 x 1024 f32 = 1 MiB),
plus the (d,) accumulator — comfortably double-bufferable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linreg_grad_kernel(x_ref, y_ref, w_ref, th_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]  # (bb, d)
    r = xb @ th_ref[...] - y_ref[...]  # (bb,)
    contrib = (2.0 * (w_ref[...] * r)) @ xb  # (d,)
    o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("block_b",))
def linreg_grad(x, y, theta, weights, *, block_b=256):
    """Weighted batched least-squares gradient via a Pallas kernel.

    Args:
      x: (B, d) float32, y: (B,) float32, theta: (d,) float32,
      weights: (B,) float32 importance weights.

    Returns:
      (d,) float32 gradient estimate (mean over the batch).
    """
    b, d = x.shape
    bb = min(block_b, b)
    pad = -b % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad))
        # zero weight on padding rows -> no contribution
        weights = jnp.pad(weights, (0, pad))
    grid = ((b + pad) // bb,)
    out = pl.pallas_call(
        _linreg_grad_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        interpret=True,
    )(x, y, weights, theta)
    return out / b
