"""L1 Pallas kernels (build-time only) and their pure-jnp oracles."""

from compile.kernels.linreg_grad import linreg_grad
from compile.kernels.logreg_grad import logreg_grad
from compile.kernels.simhash import pack_codes, simhash_signs

__all__ = ["linreg_grad", "logreg_grad", "simhash_signs", "pack_codes"]
