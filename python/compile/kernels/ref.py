"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(`python/tests/`) sweeps shapes/dtypes with hypothesis and asserts
allclose between kernel and oracle. These references are also what the
L2 model entry points are validated against.
"""

import jax.numpy as jnp


def simhash_signs_ref(x, planes):
    """Sign bits of signed random projections.

    Args:
      x: (B, d) float32 input vectors.
      planes: (P, d) float32 hyperplanes (P = K*L).

    Returns:
      (B, P) int32 in {0, 1}: 1 where <plane, x> >= 0.
    """
    proj = x @ planes.T  # (B, P)
    return (proj >= 0.0).astype(jnp.int32)


def pack_codes_ref(signs, k, l):
    """Pack per-bit signs into K-bit table codes.

    Args:
      signs: (B, K*L) int32 in {0, 1}, bit (t*K + b) is table t's bit b.
      k: bits per table.
      l: number of tables.

    Returns:
      (B, L) uint32 codes; bit b of table t contributes
      `signs[:, t*K + b] << (K - 1 - b)` — matching the Rust
      `DenseSrp::code` layout (first hyperplane = most significant bit).
    """
    b = signs.shape[0]
    s = signs.reshape(b, l, k).astype(jnp.uint32)
    shifts = jnp.arange(k - 1, -1, -1, dtype=jnp.uint32)
    return jnp.sum(s << shifts[None, None, :], axis=-1)


def linreg_grad_ref(x, y, theta, weights):
    """Importance-weighted batched least-squares gradient.

    Estimator of the full gradient from a weighted minibatch:
      (1/B) * sum_b w_b * 2 (x_b . theta - y_b) x_b

    Args:
      x: (B, d), y: (B,), theta: (d,), weights: (B,) importance weights
        (all-ones = plain SGD minibatch).

    Returns:
      (d,) gradient estimate.
    """
    r = x @ theta - y  # (B,)
    return (2.0 * (weights * r)) @ x / x.shape[0]


def linreg_loss_ref(x, y, theta):
    """Mean squared residual over the batch: (1/B) sum (x.theta - y)^2."""
    r = x @ theta - y
    return jnp.mean(r * r)


def logreg_grad_ref(x, y, theta, weights):
    """Importance-weighted batched logistic gradient (labels in ±1).

      grad_b = -y_b * sigma(-y_b x_b.theta) * x_b
    """
    m = y * (x @ theta)  # (B,)
    s = 1.0 / (1.0 + jnp.exp(m))  # sigma(-m)
    c = -(weights * y * s)
    return c @ x / x.shape[0]


def logreg_loss_ref(x, y, theta):
    """Mean logistic loss ln(1 + e^{-y x.theta}), overflow-safe."""
    m = y * (x @ theta)
    return jnp.mean(jnp.logaddexp(0.0, -m))
