"""Pallas kernel: SimHash sign bits (L1 hot-spot).

The projection `X @ W^T` followed by sign extraction is LGD's per-query
hash computation; batched over queries it is also the table-build
preprocessing pass. On TPU this is an MXU matmul with a VPU sign
epilogue; the BlockSpec below expresses the HBM->VMEM tiling the paper's
CPU implementation did with cache blocking.

TPU tiling rationale (see DESIGN.md 'Hardware adaptation'):
  * block_b x d x block_p f32 tiles; with the default block_b = 128,
    block_p = 128 and d <= 1024 the working set is
    128*1024*4 + 1024*128*4 + 128*128*4 B ~= 1.1 MiB << 16 MiB VMEM,
    leaving room for double buffering.
  * the (128, 128) output tile matches the MXU systolic array shape.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO so the Rust runtime can
run the same artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _simhash_kernel(x_ref, w_ref, o_ref):
    """One (block_b, block_p) tile of sign(X @ W^T)."""
    proj = jnp.dot(x_ref[...], w_ref[...].T)
    o_ref[...] = (proj >= 0.0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_p"))
def simhash_signs(x, planes, *, block_b=128, block_p=128):
    """Sign bits of signed random projections via a Pallas kernel.

    Args:
      x: (B, d) float32.
      planes: (P, d) float32.
      block_b, block_p: tile sizes (clamped to the actual shapes).

    Returns:
      (B, P) int32 in {0, 1}.
    """
    b, d = x.shape
    p, d2 = planes.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    bb = min(block_b, b)
    bp = min(block_p, p)
    # Pad to tile multiples so the grid divides evenly.
    b_pad = -b % bb
    p_pad = -p % bp
    xp = jnp.pad(x, ((0, b_pad), (0, 0))) if b_pad else x
    wp = jnp.pad(planes, ((0, p_pad), (0, 0))) if p_pad else planes
    grid = ((b + b_pad) // bb, (p + p_pad) // bp)
    out = pl.pallas_call(
        _simhash_kernel,
        out_shape=jax.ShapeDtypeStruct((b + b_pad, p + p_pad), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bp), lambda i, j: (i, j)),
        interpret=True,
    )(xp, wp)
    return out[:b, :p]


def pack_codes(signs, k, l):
    """Pack (B, K*L) sign bits into (B, L) uint32 K-bit codes.

    Pure-jnp epilogue (bit twiddling is VPU work; no MXU benefit from a
    dedicated kernel).
    """
    b = signs.shape[0]
    s = signs.reshape(b, l, k).astype(jnp.uint32)
    shifts = jnp.arange(k - 1, -1, -1, dtype=jnp.uint32)
    return jnp.sum(s << shifts[None, None, :], axis=-1)
