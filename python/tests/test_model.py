"""L2 model-level checks: entry-point shapes, numerics and the mini-BERT
training signal (gradients are finite and actually descend the loss)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def test_linreg_entries_shapes():
    x = jnp.ones((32, 90))
    y = jnp.ones((32,))
    th = jnp.zeros((90,))
    w = jnp.ones((32,))
    (g,) = model.linreg_grad(x, y, th, w)
    assert g.shape == (90,)
    (loss,) = model.linreg_loss(x, y, th)
    assert loss.shape == ()
    np.testing.assert_allclose(float(loss), 1.0, rtol=1e-6)  # residual = -1


def test_logreg_loss_at_zero_is_ln2():
    x = jnp.ones((8, 4))
    y = jnp.asarray([1.0, -1.0] * 4)
    th = jnp.zeros((4,))
    (loss,) = model.logreg_loss(x, y, th)
    np.testing.assert_allclose(float(loss), np.log(2.0), rtol=1e-6)


def test_simhash_codes_entry():
    rng = np.random.default_rng(3)
    k, l = 3, 5
    x = jnp.asarray(rng.normal(size=(16, 12)), jnp.float32)
    planes = jnp.asarray(rng.normal(size=(k * l, 12)), jnp.float32)
    (codes,) = model.simhash_codes(x, planes, k, l)
    assert codes.shape == (16, l)
    want = ref.pack_codes_ref(ref.simhash_signs_ref(x, planes), k, l)
    assert np.array_equal(np.asarray(codes), np.asarray(want))


def _bert_batch(rng, b=32):
    ids = jnp.asarray(rng.integers(0, model.VOCAB, size=(b, model.MAX_T)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, model.N_CLASSES, size=(b,)), jnp.int32)
    weights = jnp.ones((b,), jnp.float32)
    return ids, labels, weights


def test_bert_param_spec_consistent():
    spec = model.bert_param_spec()
    params = model.bert_init_params(0)
    assert len(params) == len(spec)
    for (name, shape), arr in zip(spec, params):
        assert arr.shape == tuple(shape), name
        assert arr.dtype == jnp.float32


def test_bert_grad_shapes_and_finite():
    params = model.bert_init_params(1)
    rng = np.random.default_rng(5)
    ids, labels, weights = _bert_batch(rng)
    out = model.bert_grad(*params, ids, labels, weights)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.all(np.isfinite(np.asarray(g)))


def test_bert_sgd_descends():
    """A few SGD steps on one batch must reduce the loss (overfit check)."""
    params = model.bert_init_params(2)
    rng = np.random.default_rng(7)
    ids, labels, weights = _bert_batch(rng, b=16)

    @jax.jit
    def step(params):
        out = model.bert_grad(*params, ids, labels, weights)
        return out[0], out[1:]

    loss0, grads = step(params)
    lr = 0.02
    for _ in range(20):
        params = [p - lr * g for p, g in zip(params, grads)]
        loss, grads = step(params)
    assert float(loss) < float(loss0) * 0.8, (float(loss0), float(loss))


def test_bert_pooled_in_tanh_range():
    params = model.bert_init_params(3)
    rng = np.random.default_rng(9)
    ids, _, _ = _bert_batch(rng, b=8)
    (pooled,) = model.bert_pooled(*params, ids)
    assert pooled.shape == (8, model.D_MODEL)
    a = np.asarray(pooled)
    assert np.all(a <= 1.0) and np.all(a >= -1.0)


def test_bert_logits_deterministic():
    params = model.bert_init_params(4)
    rng = np.random.default_rng(11)
    ids, _, _ = _bert_batch(rng, b=4)
    (l1,) = model.bert_logits(*params, ids)
    (l2,) = model.bert_logits(*params, ids)
    assert np.array_equal(np.asarray(l1), np.asarray(l2))
    assert l1.shape == (4, model.N_CLASSES)


def test_weighted_ce_weights_scale_loss():
    params = model.bert_init_params(5)
    rng = np.random.default_rng(13)
    ids, labels, _ = _bert_batch(rng, b=8)
    w1 = jnp.ones((8,), jnp.float32)
    out1 = model.bert_grad(*params, ids, labels, w1)
    out2 = model.bert_grad(*params, ids, labels, 2.0 * w1)
    np.testing.assert_allclose(2.0 * float(out1[0]), float(out2[0]), rtol=1e-5)
