"""AOT pipeline checks: entry construction, HLO text generation and
manifest schema — without touching the artifacts/ directory."""

import json

import jax

from compile import aot, model


def test_build_entries_cover_paper_dims():
    names = [e[0] for e in aot.build_entries()]
    for d in (90, 385, 529):
        assert f"linreg_grad_b1_d{d}" in names
        assert f"linreg_grad_b32_d{d}" in names
        assert f"linreg_loss_b1024_d{d}" in names
    assert "bert_grad_b32" in names
    assert "bert_pooled_b64" in names
    assert any(n.startswith("simhash_") for n in names)


def test_entry_specs_match_example_args():
    for name, fn, example_args, arg_specs, out_specs in aot.build_entries():
        assert len(example_args) == len(arg_specs), name
        for ex, spec in zip(example_args, arg_specs):
            assert list(ex.shape) == spec["shape"], name
        assert out_specs, name


def test_hlo_text_generation_smoke():
    """Lower one small entry end-to-end and sanity-check the HLO text."""
    entries = {e[0]: e for e in aot.build_entries()}
    name, fn, example_args, _, _ = entries["linreg_grad_b1_d90"]
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # tuple return (return_tuple=True) so the rust side can to_tuple1()
    assert "f32[90]" in text


def test_manifest_schema(tmp_path):
    """Run the writer restricted to one tiny entry; validate the manifest."""
    import sys
    from unittest import mock

    argv = ["aot", "--out-dir", str(tmp_path), "--only", "linreg_grad_b1_d90"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    ent = manifest["entries"]["linreg_grad_b1_d90"]
    assert ent["file"] == "linreg_grad_b1_d90.hlo.txt"
    assert ent["args"][0] == {"shape": [1, 90], "dtype": "f32"}
    assert ent["outputs"] == [{"shape": [90], "dtype": "f32"}]
    assert (tmp_path / ent["file"]).exists()
    # bert ABI block
    assert manifest["bert"]["param_names"] == [n for n, _ in model.bert_param_spec()]
    assert manifest["bert"]["d_model"] == model.D_MODEL
