"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; every kernel must match its oracle
to float32 tolerance across batch sizes that do and do not divide the
tile size (exercising the padding paths).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linreg_grad, logreg_grad, pack_codes, simhash_signs
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


shapes = st.tuples(
    st.integers(min_value=1, max_value=300),  # batch
    st.integers(min_value=1, max_value=64),  # dim
)


@given(shapes, st.integers(min_value=0, max_value=2**31 - 1))
def test_linreg_grad_matches_ref(shape, seed):
    b, d = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, d)
    y = _rand(rng, b)
    th = _rand(rng, d)
    w = jnp.asarray(rng.uniform(0.0, 3.0, size=(b,)), jnp.float32)
    got = linreg_grad(x, y, th, w, block_b=64)
    want = ref.linreg_grad_ref(x, y, th, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


@given(shapes, st.integers(min_value=0, max_value=2**31 - 1))
def test_logreg_grad_matches_ref(shape, seed):
    b, d = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, d)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=(b,)), jnp.float32)
    th = _rand(rng, d)
    w = jnp.asarray(rng.uniform(0.0, 3.0, size=(b,)), jnp.float32)
    got = logreg_grad(x, y, th, w, block_b=64)
    want = ref.logreg_grad_ref(x, y, th, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


@given(
    st.tuples(
        st.integers(min_value=1, max_value=200),
        st.integers(min_value=1, max_value=48),
        st.integers(min_value=1, max_value=80),
    ),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_simhash_signs_match_ref(shape, seed):
    b, d, p = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, d)
    planes = _rand(rng, p, d)
    got = simhash_signs(x, planes, block_b=32, block_p=32)
    want = ref.simhash_signs_ref(x, planes)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@given(
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pack_codes_matches_ref(b, k, l, seed):
    rng = np.random.default_rng(seed)
    signs = jnp.asarray(rng.integers(0, 2, size=(b, k * l)), jnp.int32)
    got = pack_codes(signs, k, l)
    want = ref.pack_codes_ref(signs, k, l)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).max() < 2**k


def test_zero_weights_zero_gradient():
    rng = np.random.default_rng(7)
    x = _rand(rng, 16, 8)
    y = _rand(rng, 16)
    th = _rand(rng, 8)
    w = jnp.zeros((16,), jnp.float32)
    g = np.asarray(linreg_grad(x, y, th, w))
    assert np.allclose(g, 0.0)


def test_importance_weighting_linearity():
    """g(alpha * w) == alpha * g(w) — the property LGD's 1/(pN) relies on."""
    rng = np.random.default_rng(11)
    x = _rand(rng, 32, 8)
    y = _rand(rng, 32)
    th = _rand(rng, 8)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=(32,)), jnp.float32)
    g1 = np.asarray(linreg_grad(x, y, th, w))
    g3 = np.asarray(linreg_grad(x, y, th, 3.0 * w))
    np.testing.assert_allclose(3.0 * g1, g3, rtol=1e-4)


def test_tile_boundary_exact():
    """Batch exactly equal to, one less, one more than the tile."""
    rng = np.random.default_rng(13)
    for b in (63, 64, 65, 128):
        x = _rand(rng, b, 10)
        y = _rand(rng, b)
        th = _rand(rng, 10)
        w = jnp.ones((b,), jnp.float32)
        got = np.asarray(linreg_grad(x, y, th, w, block_b=64))
        want = np.asarray(ref.linreg_grad_ref(x, y, th, w))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_simhash_antipodal_complement():
    """sign bits of -x are the complement of x's (measure-zero ties aside)."""
    rng = np.random.default_rng(17)
    x = _rand(rng, 8, 16)
    planes = _rand(rng, 24, 16)
    a = np.asarray(simhash_signs(x, planes))
    b = np.asarray(simhash_signs(-x, planes))
    assert np.array_equal(a ^ 1, b)
