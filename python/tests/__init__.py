"""pytest suite for the build-time python layer."""
