//! Quickstart: generate a power-law regression workload, train it with the
//! LGD estimator and with plain SGD, and print the convergence comparison.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lgd::config::spec::{EstimatorKind, RunConfig};
use lgd::coordinator::trainer::{train, GradSource};
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::SynthSpec;
use lgd::optim::Schedule;

fn main() -> lgd::Result<()> {
    // 1. A few thousand examples with heavy-tailed gradient structure —
    //    the regime the paper targets.
    let spec = SynthSpec::power_law("quickstart", 5_000, 64, 42);
    let ds = spec.generate()?;
    let (train_ds, test_ds) = ds.split(0.9, 1)?;
    let pre = preprocess(train_ds, &PreprocessOptions::default())?;
    println!(
        "dataset: {} train / {} test examples, d={}",
        pre.data.len(),
        test_ds.len(),
        pre.data.dim()
    );

    // 2. One config, two estimators (paper defaults: K=5, L=100, sparse
    //    projections at density 1/30).
    let mut results = Vec::new();
    for est in [EstimatorKind::Lgd, EstimatorKind::Sgd] {
        let mut cfg = RunConfig::default();
        cfg.train.estimator = est;
        cfg.train.epochs = 5;
        cfg.train.schedule = Schedule::Const(0.05);
        cfg.train.seed = 7;
        let out = train(&cfg, &pre, &test_ds, GradSource::Native)?;
        results.push(out);
    }

    // 3. Print the per-epoch comparison.
    println!(
        "\n{:<8} {:>14} {:>14} {:>14} {:>14}",
        "epoch", "lgd train", "sgd train", "lgd test", "sgd test"
    );
    let (lgd_r, sgd_r) = (&results[0], &results[1]);
    for (a, b) in lgd_r.curve.iter().zip(&sgd_r.curve) {
        println!(
            "{:<8.1} {:>14.6} {:>14.6} {:>14.6} {:>14.6}",
            a.epoch, a.train_loss, b.train_loss, a.test_loss, b.test_loss
        );
    }
    println!(
        "\nwall-clock: lgd {:.3}s (incl. {:.3}s table build, {} hash lookups) vs sgd {:.3}s",
        lgd_r.wall_secs, lgd_r.preprocess_secs, lgd_r.est_stats.cost.codes, sgd_r.wall_secs
    );
    let l = lgd_r.curve.last().unwrap().train_loss;
    let s = sgd_r.curve.last().unwrap().train_loss;
    println!("final train loss: lgd {l:.6} vs sgd {s:.6} ({})",
        if l < s { "LGD wins" } else { "SGD wins" });
    Ok(())
}
