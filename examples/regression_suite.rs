//! Regression suite: the paper's three linear-regression workloads
//! (Table 4 sizes, scaled) with plain and AdaGrad optimizers, LGD vs SGD —
//! a compact re-run of Figures 10–13 with a summary table.
//!
//! ```bash
//! cargo run --release --example regression_suite [-- scale]
//! ```

use lgd::config::spec::{EstimatorKind, OptimizerKind, RunConfig};
use lgd::coordinator::trainer::{train, GradSource};
use lgd::data::paper_specs;
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::optim::Schedule;

fn main() -> lgd::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.01);
    println!("running at scale {scale} of the paper's dataset sizes\n");
    println!(
        "{:<16} {:<9} {:<9} {:>12} {:>12} {:>10} {:>10}",
        "dataset", "optim", "estimator", "final train", "final test", "wall s", "speedup"
    );
    for spec in paper_specs(scale, 42).into_iter().take(3) {
        let ds = spec.generate()?;
        let (tr, te) = ds.split(0.9, 1)?;
        let pre = preprocess(tr, &PreprocessOptions::default())?;
        for optim in [OptimizerKind::Sgd, OptimizerKind::AdaGrad] {
            let mut wall = [0.0f64; 2];
            let mut when_half = [f64::INFINITY; 2];
            for (i, est) in [EstimatorKind::Lgd, EstimatorKind::Sgd].into_iter().enumerate() {
                let mut cfg = RunConfig::default();
                cfg.train.estimator = est;
                cfg.train.optimizer = optim;
                cfg.train.epochs = 5;
                cfg.train.schedule =
                    Schedule::Const(if optim == OptimizerKind::AdaGrad { 0.1 } else { 0.05 });
                cfg.train.seed = 7;
                cfg.lsh.l = 50;
                let out = train(&cfg, &pre, &te, GradSource::Native)?;
                let first = out.curve.first().unwrap().train_loss;
                when_half[i] = out
                    .curve
                    .iter()
                    .find(|p| p.train_loss <= first * 0.5)
                    .map(|p| p.wall)
                    .unwrap_or(f64::INFINITY);
                wall[i] = out.wall_secs;
                let last = out.curve.last().unwrap();
                println!(
                    "{:<16} {:<9} {:<9} {:>12.6} {:>12.6} {:>10.3} {:>10}",
                    spec.name,
                    match optim {
                        OptimizerKind::AdaGrad => "adagrad",
                        _ => "plain",
                    },
                    out.estimator,
                    last.train_loss,
                    last.test_loss,
                    out.wall_secs,
                    "",
                );
            }
            if when_half[0].is_finite() && when_half[1].is_finite() {
                println!(
                    "{:<16} {:<9} time-to-half-loss speedup (sgd/lgd): {:.2}x",
                    spec.name,
                    "",
                    when_half[1] / when_half[0]
                );
            }
        }
    }
    Ok(())
}
