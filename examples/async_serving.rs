//! High-traffic serving demo for the asynchronous pipelined draw engine.
//!
//! Simulates a serving loop under many concurrent query refreshes: each
//! "request wave" retargets the sampler at a fresh θ (a session boundary =
//! queue flush + one fused re-hash), serves a burst of weighted minibatch
//! draws, and spends per-draw compute on them (the gradient work the
//! pipeline is supposed to hide sampling behind). Reports draws/sec for
//! the synchronous path vs the async engine (single pipelined worker, and
//! one dedicated worker per shard), then demonstrates live churn:
//! streaming removals between sessions are honored immediately — the next
//! session never serves a dead row.
//!
//! The second half demos the **epoch-based shared-read engine**
//! (`runtime::serving`): one immutable published generation served by N
//! concurrent client sessions (read scaling vs client count), then a
//! generation flip under a live pinned reader — the pinned session drains
//! its own generation while a fresh session sees the new membership with
//! zero dead rows.
//!
//! ```text
//! cargo run --release --example async_serving
//! ```

use std::sync::Arc;
use std::time::Instant;

use lgd::coordinator::draw_engine::{run_session, DrawEngineConfig};
use lgd::data::preprocess::{preprocess, Preprocessed, PreprocessOptions};
use lgd::data::SynthSpec;
use lgd::estimator::lgd::LgdOptions;
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator, WeightedDraw};
use lgd::lsh::srp::DenseSrp;
use lgd::runtime::{run_harness, ServingCore, ServingSession};

const N: usize = 20_000;
const D: usize = 24;
const SHARDS: usize = 4;
const WAVES: usize = 12;
const BATCH: usize = 64;
const STEPS: usize = 30;

fn theta_for(wave: usize) -> Vec<f32> {
    (0..D).map(|j| 0.01 * ((j + 3 * wave) as f32 - D as f32 / 2.0)).collect()
}

/// Per-draw "gradient" work: touch the drawn row and fold it into a sink
/// so the compute the pipeline overlaps with sampling is real.
fn consume(pre: &Preprocessed, draws: &[WeightedDraw], sink: &mut f64) {
    for d in draws {
        let (x, _) = pre.data.example(d.index);
        *sink += d.weight * x.iter().map(|v| *v as f64).sum::<f64>();
    }
}

fn mk(pre: &Preprocessed) -> ShardedLgdEstimator<'_, DenseSrp> {
    let hd = pre.hashed.cols();
    ShardedLgdEstimator::new(pre, DenseSrp::new(hd, 5, 25, 13), 15, LgdOptions::default(), SHARDS)
        .unwrap()
}

fn main() {
    let ds = SynthSpec::power_law("serve", N, D, 11).generate().unwrap();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    let total = (WAVES * STEPS * BATCH) as f64;
    println!(
        "async serving demo: n={N} d={D} shards={SHARDS}, {WAVES} query waves x {STEPS} \
         batches x {BATCH} draws"
    );

    // --- Synchronous baseline: the trainer stalls on every draw_batch. ---
    let mut est = mk(&pre);
    let mut out = Vec::new();
    let mut sink = 0.0f64;
    let t0 = Instant::now();
    for wave in 0..WAVES {
        let theta = theta_for(wave);
        for _ in 0..STEPS {
            est.draw_batch(&theta, BATCH, &mut out);
            consume(&pre, &out, &mut sink);
        }
    }
    let sync_secs = t0.elapsed().as_secs_f64();
    println!("  sync              {:>10.0} draws/s", total / sync_secs);

    // --- Async engine: workers=1 (exact sync stream, pipelined) and one
    // dedicated sampler worker per shard. ---
    for workers in [1usize, SHARDS] {
        let mut est = mk(&pre);
        let cfg = DrawEngineConfig { workers, queue_depth: 1024 };
        let (mut hits, mut stalls) = (0u64, 0u64);
        let t0 = Instant::now();
        for wave in 0..WAVES {
            let theta = theta_for(wave);
            let rep = run_session(&mut est, &cfg, &theta, BATCH, STEPS, |_, draws| {
                consume(&pre, draws, &mut sink);
                true
            })
            .unwrap();
            hits += rep.prefetch_hits;
            stalls += rep.queue_stalls;
        }
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "  async workers={workers}   {:>10.0} draws/s  ({:.2}x sync, {hits} prefetched, \
             {stalls} stalls)",
            total / secs,
            sync_secs / secs
        );
    }

    // --- Live churn between sessions: evict a block, serve, verify. ---
    let mut est = mk(&pre);
    for id in 0..N / 4 {
        est.remove(id).unwrap();
    }
    let cfg = DrawEngineConfig { workers: SHARDS, queue_depth: 1024 };
    let theta = theta_for(0);
    let mut served = 0usize;
    let mut dead = 0usize;
    run_session(&mut est, &cfg, &theta, BATCH, STEPS, |_, draws| {
        served += draws.len();
        dead += draws.iter().filter(|d| d.index < N / 4).count();
        true
    })
    .unwrap();
    println!(
        "  live churn: removed {} examples, served {served} draws, dead rows served: {dead} \
         (generation {})",
        N / 4,
        est.shard_set().generation()
    );
    assert_eq!(dead, 0, "the engine must never serve a dead row");

    // --- Shared-read serving (`runtime::serving`): one immutable published
    // generation, N concurrent client sessions. ---
    let pre = Arc::new(pre);
    let core = ServingCore::build(
        Arc::clone(&pre),
        DenseSrp::new(pre.hashed.cols(), 5, 25, 13),
        LgdOptions::default(),
        SHARDS,
    )
    .unwrap();
    let theta = theta_for(0);
    println!("  shared-read core (epoch-based, generation {}):", core.generation());
    for clients in [1usize, 2, 4, 8] {
        let rep = run_harness(&core, clients, STEPS, BATCH, &theta, 15).unwrap();
        println!(
            "    clients={clients}  {:>10.0} draws/s aggregate ({} draws, {} stale rejects)",
            rep.draws_per_sec, rep.draws, rep.stale_rejected
        );
    }

    // --- Generation flip under a live pinned reader: one copy-on-write
    // mutation evicts a block; the pinned session keeps draining its own
    // (fully live) generation, a fresh session sees the new membership. ---
    let mut pinned = ServingSession::open(&core, 99);
    core.mutate(|set, pre| {
        for id in 0..N / 4 {
            set.remove(id, &pre.hashed)?;
        }
        Ok(())
    })
    .unwrap();
    let mut out = Vec::new();
    pinned.draw_batch(&theta, BATCH, &mut out); // generation g: every row live for it
    let mut fresh = ServingSession::open(&core, 100);
    let mut dead = 0usize;
    for _ in 0..STEPS {
        fresh.draw_batch(&theta, BATCH, &mut out);
        dead += out.iter().filter(|d| d.index < N / 4).count();
    }
    println!(
        "    flip under load: generation {} -> {}, fresh session served {} draws, \
         dead rows: {dead}",
        pinned.generation(),
        fresh.generation(),
        STEPS * BATCH
    );
    assert_eq!(dead, 0, "a session must never serve a row dead in its generation");
    std::hint::black_box(sink);
}
