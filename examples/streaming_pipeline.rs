//! Streaming-pipeline demo: records flow Source → Preprocess → parallel
//! Hash workers → Table owner under bounded-channel backpressure; the
//! resulting tables feed the LGD estimator directly and training starts
//! the moment the build finishes.
//!
//! ```bash
//! cargo run --release --example streaming_pipeline
//! ```

use lgd::config::spec::{EstimatorKind, RunConfig};
use lgd::coordinator::metrics::Metrics;
use lgd::coordinator::pipeline::{streaming_build, PipelineConfig};
use lgd::coordinator::trainer::GradSource;
use lgd::data::SynthSpec;
use lgd::estimator::lgd::{LgdEstimator, LgdOptions};
use lgd::lsh::srp::SparseSrp;

fn main() -> lgd::Result<()> {
    let n = 20_000;
    let d = 90;
    let spec = SynthSpec::power_law("stream", n, d, 3);
    let ds = spec.generate()?;
    println!("streaming {} records (d={}) through the pipeline...", ds.len(), d);

    let metrics = Metrics::new();
    let hasher = SparseSrp::paper_default(d + 1, 5, 100, 11);
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { channel_cap: 256, hash_workers: workers };
        let (_pre, _tables, report) =
            streaming_build(ds.clone(), hasher.clone(), &cfg, &metrics)?;
        println!(
            "  {workers} hash workers: {:>8.0} records/s ({:.3}s total)",
            report.throughput, report.wall_secs
        );
    }

    // Build once more and train from the streamed tables.
    let cfg = PipelineConfig::default();
    let (pre, tables, report) = streaming_build(ds, hasher, &cfg, &metrics)?;
    println!(
        "\nfinal build: {} records at {:.0} rec/s; table stats: {:?}",
        report.records,
        report.throughput,
        tables.stats()
    );

    // pipeline tables are unmirrored → cap the importance weights (see
    // DESIGN.md §Deviations on the signed-residual tail)
    let opts = LgdOptions { weight_clip: Some(5.0), ..LgdOptions::default() };
    let mut est = LgdEstimator::from_parts(&pre, tables, 17, opts);
    let mut run_cfg = RunConfig::default();
    run_cfg.train.estimator = EstimatorKind::Sgd; // placeholder; we drive manually
    // quick manual loop to show the streamed tables sampling adaptively
    use lgd::estimator::GradientEstimator;
    use lgd::model::{LinReg, Model};
    let model = LinReg;
    let mut theta = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    let loss0 = model.mean_loss(&pre.data, &theta);
    for _ in 0..4 * pre.data.len() {
        let dr = est.draw(&theta);
        let (x, y) = pre.data.example(dr.index);
        model.grad(x, y, &theta, &mut g);
        lgd::core::matrix::axpy(-(0.05 * dr.weight) as f32, &g, &mut theta);
    }
    let loss1 = model.mean_loss(&pre.data, &theta);
    println!("training on streamed tables: loss {loss0:.5} -> {loss1:.5} (4 epochs)");
    println!("\nmetrics:\n{}", metrics.report());
    let _ = run_cfg;
    let _ = GradSource::Native; // silence unused-variant lint in docs builds
    Ok(())
}
