//! Streaming-pipeline demo: records flow Source → Preprocess → parallel
//! Hash workers → Table owner under bounded-channel backpressure, then the
//! *sharded* variant streams the same records straight into per-shard
//! tables and keeps them live — a skewed arrival burst trips the
//! rebalance threshold, examples migrate between shards, and the
//! estimator's gradient quality is unchanged (Theorem-1 unbiasedness
//! survives migration because the mixture weights R_s/R are recomputed at
//! every step).
//!
//! ```bash
//! cargo run --release --example streaming_pipeline
//! ```

use lgd::coordinator::metrics::Metrics;
use lgd::coordinator::pipeline::{streaming_build, streaming_build_sharded, PipelineConfig};
use lgd::data::preprocess::Preprocessed;
use lgd::data::SynthSpec;
use lgd::estimator::lgd::LgdOptions;
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator};
use lgd::lsh::srp::DenseSrp;
use lgd::model::{LinReg, Model};

/// Quality of the estimator: relative error of the importance-weighted
/// gradient estimate (averaged over `draws` draws) against the full
/// average gradient — the Theorem-1 quantity.
fn grad_rel_err(
    est: &mut ShardedLgdEstimator<'_, DenseSrp>,
    pre: &Preprocessed,
    theta: &[f32],
    draws: usize,
) -> f64 {
    let d = pre.data.dim();
    let model = LinReg;
    let mut full = vec![0.0f32; d];
    model.full_grad(&pre.data, theta, &mut full);
    let full_norm = lgd::core::matrix::norm2(&full).max(1e-12);
    let mut acc = vec![0.0f64; d];
    let mut g = vec![0.0f32; d];
    for _ in 0..draws {
        let dr = est.draw(theta);
        let (x, y) = pre.data.example(dr.index);
        model.grad(x, y, theta, &mut g);
        for j in 0..d {
            acc[j] += dr.weight * g[j] as f64;
        }
    }
    let mut err = 0.0f64;
    for j in 0..d {
        err += (acc[j] / draws as f64 - full[j] as f64).powi(2);
    }
    err.sqrt() / full_norm
}

fn main() -> lgd::Result<()> {
    let n = 20_000;
    let d = 32;
    let spec = SynthSpec::power_law("stream", n, d, 3);
    let ds = spec.generate()?;
    let metrics = Metrics::new();

    // --- Phase 1: unsharded streaming build, hash-worker sweep. ---
    println!("streaming {} records (d={d}) through the pipeline...", ds.len());
    let hasher = DenseSrp::new(d + 1, 5, 50, 11);
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { channel_cap: 256, hash_workers: workers };
        let (_pre, _tables, report) = streaming_build(ds.clone(), hasher.clone(), &cfg, &metrics)?;
        println!(
            "  {workers} hash workers: {:>8.0} records/s ({:.3}s total)",
            report.throughput, report.wall_secs
        );
    }

    // --- Phase 2: sharded streaming ingest → live estimator. ---
    let shards = 4usize;
    let cfg = PipelineConfig::default();
    let (pre, built, report) =
        streaming_build_sharded(ds, hasher.clone(), shards, true, &cfg, &metrics)?;
    println!(
        "\nsharded streaming ingest: {} records into {shards} shards at {:.0} rec/s",
        report.records, report.throughput
    );
    let mut est = ShardedLgdEstimator::from_shards(&pre, built, 17, LgdOptions::default());
    let theta: Vec<f32> = (0..d).map(|j| 0.02 * (j as f32 / d as f32 - 0.5)).collect();
    let q0 = grad_rel_err(&mut est, &pre, &theta, 30_000);
    println!("  estimator quality (balanced): gradient rel-err {q0:.4}");

    // --- Phase 3: skewed arrivals → automatic rebalance. ---
    // Simulate churn: the last quarter of the examples "leave" and later
    // re-arrive in one hot shard (a skewed partition key). The threshold
    // trips mid-burst and the set migrates examples back toward balance.
    let burst = n / 4;
    for id in (n - burst)..n {
        est.remove(id)?;
    }
    est.set_rebalance_threshold(1.2);
    println!("\nskewed re-arrival of {burst} records into shard 0 (threshold 1.2):");
    let mut peak = 0.0f64;
    for (i, id) in ((n - burst)..n).enumerate() {
        est.shard_set_mut().insert_into(0, id, &pre.hashed)?;
        peak = peak.max(est.shard_set().imbalance());
        if (i + 1) % (burst / 5) == 0 {
            let st = est.stats();
            println!(
                "  after {:>5} arrivals: imbalance {:.3} (peak {:.3}), {} migrated in {} passes",
                i + 1,
                est.shard_set().imbalance(),
                peak,
                st.migrations,
                st.rebalances
            );
        }
    }
    let st = est.stats();
    println!(
        "  rebalancing total: {} examples migrated, {} passes, {:.3}s",
        st.migrations, st.rebalances, st.rebalance_secs
    );
    println!("  per-shard examples: {:?}", est.shard_set().counts());

    let q1 = grad_rel_err(&mut est, &pre, &theta, 30_000);
    println!("  estimator quality (post-rebalance): gradient rel-err {q1:.4}");
    println!(
        "  quality unchanged: {q0:.4} -> {q1:.4} (mixture weights stay exact through \
         migration)"
    );

    // --- Phase 4: the rebalanced tables still train. ---
    let model = LinReg;
    let mut theta = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    let loss0 = model.mean_loss(&pre.data, &theta);
    for _ in 0..2 * n {
        let dr = est.draw(&theta);
        let (x, y) = pre.data.example(dr.index);
        model.grad(x, y, &theta, &mut g);
        lgd::core::matrix::axpy(-(0.05 * dr.weight.min(5.0)) as f32, &g, &mut theta);
    }
    let loss1 = model.mean_loss(&pre.data, &theta);
    println!("\ntraining on live sharded tables: loss {loss0:.5} -> {loss1:.5} (2 epochs)");
    println!("\nmetrics:\n{}", metrics.report());
    Ok(())
}
