//! Warm-start serving demo for the `store::snapshot` persistence layer.
//!
//! The paper's §2.2 running-time argument treats the LSH preprocessing as a
//! one-time cost amortized across all subsequent adaptive draws — which
//! only holds in production if the index survives a restart. This demo
//! walks the full lifecycle:
//!
//! 1. **Build** the sharded engine from raw data (the one-time cost).
//! 2. **Save** it with crash-safe atomic writes.
//! 3. **"Restart"**: drop the engine, load the snapshot, restore — zero
//!    table-build work and zero hash invocations, proven by the hash
//!    family's shared counters.
//! 4. **Serve** from both engines and verify the warm engine's draw stream
//!    is identical to the cold one's.
//!
//! ```text
//! cargo run --release --example warm_start
//! ```

use std::time::Instant;

use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::SynthSpec;
use lgd::estimator::lgd::LgdOptions;
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator};
use lgd::lsh::srp::DenseSrp;
use lgd::store::snapshot::{self, LoadedSnapshot};

const N: usize = 20_000;
const D: usize = 24;
const SHARDS: usize = 4;
const SERVE: usize = 2_000;

fn main() {
    let ds = SynthSpec::power_law("warm", N, D, 21).generate().unwrap();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    let hd = pre.hashed.cols();
    println!("warm-start demo: n={N} d={D} shards={SHARDS}");

    // --- 1. cold build (the cost a restart used to re-pay) ---
    let t0 = Instant::now();
    let mut cold = ShardedLgdEstimator::new(
        &pre,
        DenseSrp::new(hd, 5, 25, 23),
        25,
        LgdOptions::default(),
        SHARDS,
    )
    .unwrap();
    let build_secs = t0.elapsed().as_secs_f64();
    println!("  cold build:    {build_secs:.3}s");

    // streaming churn so the snapshot carries live overlay/membership state
    for id in 0..N / 10 {
        cold.remove(id).unwrap();
    }

    // --- 2. save (atomic: *.tmp + fsync + rename) ---
    let dir = std::env::temp_dir().join("lgd-warm-start");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("engine.lgdsnap");
    let t0 = Instant::now();
    let bytes = snapshot::save(&path, &cold, None).unwrap();
    println!("  save:          {:.3}s ({bytes} bytes)", t0.elapsed().as_secs_f64());

    // --- 3. "restart": load + restore, with the zero-rebuild proof ---
    let t0 = Instant::now();
    let snap = snapshot::load(&path).unwrap();
    let LoadedSnapshot { pre: warm_pre, hasher, engine, meta, .. } = snap;
    let handle = hasher.clone();
    let mut warm = snapshot::restore_boxed(hasher, &warm_pre, engine).unwrap();
    let load_secs = t0.elapsed().as_secs_f64();
    let stats = handle.hash_stats();
    println!(
        "  load+restore:  {load_secs:.3}s ({:.1}x faster than the build; generation {})",
        build_secs / load_secs.max(1e-9),
        meta.generation
    );
    println!(
        "  zero rebuild:  {} row hashes, {} query hashes during restore",
        stats.code_calls, stats.fused_calls
    );
    assert_eq!(stats.code_calls, 0, "restore must not build tables");

    // --- 4. serve: the warm engine replays the cold engine's stream ---
    let theta: Vec<f32> = (0..D).map(|j| 0.01 * (j as f32 - D as f32 / 2.0)).collect();
    let t0 = Instant::now();
    for i in 0..SERVE {
        let a = cold.draw(&theta);
        let b = warm.draw(&theta);
        assert_eq!(a, b, "draw {i}: warm engine diverged from the saved stream");
        assert!(a.index >= N / 10, "served an evicted example");
    }
    println!(
        "  serving:       {SERVE} draws from each engine in {:.3}s — streams identical, \
         evicted rows honored",
        t0.elapsed().as_secs_f64()
    );
    let _ = std::fs::remove_file(&path);
    println!("warm start OK");
}
