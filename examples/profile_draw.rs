use lgd::config::spec::{EstimatorKind, RunConfig};
use lgd::coordinator::trainer::build_estimator;
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::SynthSpec;
fn main() {
    let ds = SynthSpec::power_law("p", 9000, 90, 7).generate().unwrap();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    let mut cfg = RunConfig::default();
    cfg.lsh.l = 100;
    cfg.train.estimator = EstimatorKind::Lgd;
    let mut est = build_estimator(&cfg, &pre).unwrap();
    let theta = vec![0.01f32; 90];
    let mut acc = 0.0f64;
    for _ in 0..3_000_000 { acc += std::hint::black_box(est.draw(&theta)).weight; }
    println!("{acc}");
}
