//! End-to-end validation driver (EXPERIMENTS.md §E2E): fine-tune the
//! mini-BERT transformer on the synthetic MRPC-sized sentence-pair task
//! with LGD vs SGD batch sampling, exercising all three layers:
//!
//!   L1 Pallas kernels + L2 JAX transformer  →  AOT HLO text artifacts
//!   →  Rust PJRT runtime (this process)      →  L3 LSH coordinator
//!
//! Prints the epoch-wise loss/accuracy table the paper's Figure 5 plots.
//!
//! ```bash
//! make artifacts && cargo run --release --example bert_finetune
//! ```

use lgd::experiments::{fig5, ExpOptions};

fn main() -> lgd::Result<()> {
    let artifacts = lgd::runtime::default_artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        eprintln!("no artifacts at {} — run `make artifacts` first", artifacts.display());
        std::process::exit(2);
    }
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.25);
    let opts = ExpOptions {
        scale,
        out_dir: std::path::PathBuf::from("results"),
        seed: 42,
        quick: false,
        artifacts: Some(artifacts),
    };
    fig5::run(&opts)?;
    println!("\ncurves in results/fig5.csv — epoch-wise convergence, LGD vs SGD (paper Fig. 5)");
    Ok(())
}
