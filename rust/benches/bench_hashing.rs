//! Hash-function cost: dense vs sparse (paper density 1/30) vs implicit
//! quadratic SRP, per K-bit code and for all-L preprocessing — the
//! "fast hash computation is critical" claim of §2.2.

use lgd::benchkit::{bb, Bench};
use lgd::core::rng::{Pcg64, Rng};
use lgd::lsh::srp::{DenseSrp, SparseSrp, SrpHasher};
use lgd::lsh::QuadraticSrp;

fn unit(d: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    lgd::core::matrix::normalize(&mut v);
    v
}

fn main() {
    let mut b = Bench::new("hashing");
    let (k, l) = (5usize, 100usize);
    let mut rng = Pcg64::seeded(1);
    for &d in &[91usize, 386, 530] {
        let x = unit(d, &mut rng);
        let dense = DenseSrp::new(d, k, l, 2);
        let sparse = SparseSrp::paper_default(d, k, l, 3);
        let quad = QuadraticSrp::new(d.min(64), k, l, 1.0 / 30.0, 4); // quadratic on reduced dim
        let xq = unit(d.min(64), &mut rng);

        b.bench(&format!("dense_code_d{d}"), || {
            bb(dense.code(0, &x));
        });
        b.bench(&format!("sparse_code_d{d}"), || {
            bb(sparse.code(0, &x));
        });
        b.bench(&format!("quadratic_code_d{}", d.min(64)), || {
            bb(quad.code(0, &xq));
        });
        let mut codes = Vec::new();
        b.bench(&format!("sparse_all_L_codes_d{d}"), || {
            sparse.codes_all(&x, &mut codes);
            bb(codes.len());
        });
        println!(
            "  cost model d={d}: dense {:.0} mults/code, sparse {:.1}, ratio {:.1}x",
            dense.mults_per_code(),
            sparse.mults_per_code(),
            dense.mults_per_code() / sparse.mults_per_code()
        );
    }

    // --- Aligned-kernel dispatch A/B (docs/numerics.md): the same
    // collision-probability dot under auto (SIMD when the CPU has it) vs
    // forced-scalar dispatch. Outputs are bitwise identical and no mults
    // counter moves — the ns delta is the whole story (advisory rows).
    {
        use lgd::core::numerics::{set_kernel_mode, simd_active, KernelMode};
        let d = 386usize;
        let x = unit(d, &mut rng);
        let q = unit(d, &mut rng);
        println!("\nkernel dispatch A/B: simd active under auto = {}", simd_active());
        for mode in [KernelMode::Auto, KernelMode::Scalar] {
            set_kernel_mode(mode);
            b.bench(&format!("dot_fast_d{d}_kernel_{}", mode.name()), || {
                bb(lgd::core::matrix::dot_fast(&x, &q));
            });
        }
        set_kernel_mode(KernelMode::Auto);
    }
    b.report();
}
