//! PJRT execution latency of the AOT artifacts: per-call cost of the
//! linreg gradient at batch 1/32/256, loss eval, simhash codes, and the
//! mini-BERT step — quantifying the L3↔runtime boundary. Skips cleanly if
//! artifacts are missing (`make artifacts`).

use lgd::benchkit::{bb, Bench};
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::SynthSpec;
use lgd::estimator::lgd::{LgdEstimator, LgdOptions};
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator};
use lgd::lsh::srp::DenseSrp;
use lgd::runtime::executor::{lit_f32, lit_i32};
use lgd::runtime::{BertSession, Runtime};

/// Native sampling-engine runtime: single-structure vs sharded draw
/// throughput, sealed CSR arena vs Vec buckets. Runs regardless of PJRT
/// artifact availability and emits the machine-readable
/// `BENCH_runtime.json` trajectory file.
fn bench_sharded_draws() {
    let mut b = Bench::new("sampling engine runtime (native)");
    let n = 20_000usize;
    let d = 32usize;
    let ds = SynthSpec::power_law("rt", n, d, 33).generate().unwrap();
    let t0 = std::time::Instant::now();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    b.record("preprocess_n20k", t0.elapsed().as_secs_f64() * 1e9);
    let hd = pre.hashed.cols();
    let theta = vec![0.01f32; d];
    for sealed in [true, false] {
        let tag = if sealed { "sealed" } else { "vec" };
        let opts = LgdOptions { sealed, ..LgdOptions::default() };
        let tb = std::time::Instant::now();
        let mut single =
            LgdEstimator::new(&pre, DenseSrp::new(hd, 5, 25, 35), 37, opts.clone()).unwrap();
        b.record(&format!("table_build_n20k_{tag}"), tb.elapsed().as_secs_f64() * 1e9);
        b.bench(&format!("lgd_draw_n20k_shards1_{tag}"), || {
            bb(single.draw(&theta));
        });
        let st = single.stats();
        let draws = st.draws.max(1) as f64;
        b.note(&format!("probes_per_draw_shards1_{tag}"), st.cost.probes as f64 / draws);
        for &s in &[2usize, 4] {
            let mut sharded = ShardedLgdEstimator::new(
                &pre,
                DenseSrp::new(hd, 5, 25, 35),
                37,
                opts.clone(),
                s,
            )
            .unwrap();
            b.bench(&format!("lgd_draw_n20k_shards{s}_{tag}"), || {
                bb(sharded.draw(&theta));
            });
        }
    }
    b.report();
    let json_path = lgd::benchkit::bench_json_path("BENCH_runtime.json");
    match b.write_json(&json_path) {
        Ok(()) => println!("wrote {}", json_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", json_path.display()),
    }
}

fn main() {
    bench_sharded_draws();
    let dir = lgd::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: no artifacts at {} — run `make artifacts` first", dir.display());
        return;
    }
    let mut rt = Runtime::new(&dir).unwrap();
    let mut b = Bench::new("pjrt runtime");

    let d = 90usize;
    let theta: Vec<f32> = (0..d).map(|i| i as f32 / d as f32).collect();
    for &batch in &[1usize, 32, 256] {
        let entry = format!("linreg_grad_b{batch}_d{d}");
        let x = vec![0.1f32; batch * d];
        let y = vec![0.2f32; batch];
        let w = vec![1.0f32; batch];
        let args = [
            lit_f32(&x, &[batch, d]).unwrap(),
            lit_f32(&y, &[batch]).unwrap(),
            lit_f32(&theta, &[d]).unwrap(),
            lit_f32(&w, &[batch]).unwrap(),
        ];
        rt.load(&entry).unwrap();
        b.bench(&format!("linreg_grad_b{batch}_d{d}"), || {
            bb(rt.execute(&entry, &args).unwrap());
        });
    }

    // loss eval at the chunk size the trainer uses
    let lb = 1024usize;
    let entry = format!("linreg_loss_b{lb}_d{d}");
    let args = [
        lit_f32(&vec![0.1f32; lb * d], &[lb, d]).unwrap(),
        lit_f32(&vec![0.2f32; lb], &[lb]).unwrap(),
        lit_f32(&theta, &[d]).unwrap(),
    ];
    rt.load(&entry).unwrap();
    b.bench("linreg_loss_b1024_d90", || {
        bb(rt.execute(&entry, &args).unwrap());
    });

    // simhash codes kernel
    let entry = "simhash_b64_d91_k5_l100";
    let args = [
        lit_f32(&vec![0.1f32; 64 * 91], &[64, 91]).unwrap(),
        lit_f32(&vec![0.05f32; 500 * 91], &[500, 91]).unwrap(),
    ];
    rt.load(entry).unwrap();
    b.bench("simhash_codes_b64", || {
        bb(rt.execute(entry, &args).unwrap());
    });

    // mini-BERT Adam step (grad through PJRT + update in Rust)
    let mut sess = BertSession::new(&mut rt, 1e-4).unwrap();
    let t = sess.abi().max_t;
    let bsz = sess.grad_batch();
    let ids: Vec<i32> = (0..bsz * t).map(|i| (i % 512) as i32).collect();
    let labels: Vec<i32> = (0..bsz).map(|i| (i % 2) as i32).collect();
    let weights = vec![1.0f32; bsz];
    b.bench("bert_step_b32 (grad+Adam)", || {
        bb(sess.step(&mut rt, &ids, &labels, &weights).unwrap());
    });
    let eids: Vec<i32> = (0..sess.eval_batch() * t).map(|i| (i % 512) as i32).collect();
    b.bench("bert_pooled_b64", || {
        bb(sess.pooled(&mut rt, &eids).unwrap());
    });
    let _ = lit_i32(&[0], &[1]); // keep import used in all cfgs
    b.report();
}
