//! PJRT execution latency of the AOT artifacts: per-call cost of the
//! linreg gradient at batch 1/32/256, loss eval, simhash codes, and the
//! mini-BERT step — quantifying the L3↔runtime boundary. Skips cleanly if
//! artifacts are missing (`make artifacts`).

use std::sync::Arc;

use lgd::benchkit::{bb, Bench};
use lgd::config::spec::{EstimatorKind, RunConfig};
use lgd::coordinator::draw_engine::{run_session, DrawEngineConfig};
use lgd::coordinator::trainer::{train, GradSource};
use lgd::core::matrix::axpy;
use lgd::core::telemetry::probes;
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::SynthSpec;
use lgd::estimator::lgd::{LgdEstimator, LgdOptions};
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator, WeightedDraw};
use lgd::lsh::srp::{DenseSrp, SrpHasher};
use lgd::model::{LinReg, Model};
use lgd::optim::Schedule;
use lgd::runtime::executor::{lit_f32, lit_i32};
use lgd::runtime::{run_harness, BertSession, Runtime, ServingCore};

/// Native sampling-engine runtime: single-structure vs sharded draw
/// throughput, sealed CSR arena vs Vec buckets. Runs regardless of PJRT
/// artifact availability and emits the machine-readable
/// `BENCH_runtime.json` trajectory file.
fn bench_sharded_draws() {
    let mut b = Bench::new("sampling engine runtime (native)");
    let n = 20_000usize;
    let d = 32usize;
    let ds = SynthSpec::power_law("rt", n, d, 33).generate().unwrap();
    let t0 = std::time::Instant::now();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    b.record("preprocess_n20k", t0.elapsed().as_secs_f64() * 1e9);
    let hd = pre.hashed.cols();
    let theta = vec![0.01f32; d];
    for sealed in [true, false] {
        let tag = if sealed { "sealed" } else { "vec" };
        let opts = LgdOptions { sealed, ..LgdOptions::default() };
        let tb = std::time::Instant::now();
        let mut single =
            LgdEstimator::new(&pre, DenseSrp::new(hd, 5, 25, 35), 37, opts.clone()).unwrap();
        b.record(&format!("table_build_n20k_{tag}"), tb.elapsed().as_secs_f64() * 1e9);
        b.bench(&format!("lgd_draw_n20k_shards1_{tag}"), || {
            bb(single.draw(&theta));
        });
        let st = single.stats();
        let draws = st.draws.max(1) as f64;
        b.note(&format!("probes_per_draw_shards1_{tag}"), st.cost.probes as f64 / draws);
        for &s in &[2usize, 4] {
            let mut sharded = ShardedLgdEstimator::new(
                &pre,
                DenseSrp::new(hd, 5, 25, 35),
                37,
                opts.clone(),
                s,
            )
            .unwrap();
            b.bench(&format!("lgd_draw_n20k_shards{s}_{tag}"), || {
                bb(sharded.draw(&theta));
            });
        }
    }
    // --- Async pipelined serving: the sync-vs-async draws/sec throughput
    // matrix across shard counts. Each step samples a 32-draw batch AND
    // runs a simulated gradient step over it, so the async rows show the
    // overlap (sampling hidden behind compute) rather than raw queue
    // overhead. Counters carry draws/sec plus the engine's queue
    // stall/prefetch-hit telemetry (advisory for the regression gate).
    {
        let model = LinReg;
        let m = 32usize;
        let steps = if std::env::var("LGD_BENCH_FAST").is_ok() { 150 } else { 1500 };
        let mut g = vec![0.0f32; d];
        let mut accv = vec![0.0f32; d];
        for &shards in &[1usize, 2, 4] {
            let mk = || {
                ShardedLgdEstimator::new(
                    &pre,
                    DenseSrp::new(hd, 5, 25, 35),
                    37,
                    LgdOptions::default(),
                    shards,
                )
                .unwrap()
            };
            let compute = |draws: &[WeightedDraw], g: &mut Vec<f32>, accv: &mut Vec<f32>| {
                let inv = 1.0 / m as f32;
                for dr in draws {
                    let (x, y) = pre.data.example(dr.index);
                    model.grad(x, y, &theta, g);
                    axpy(dr.weight as f32 * inv, g, accv);
                }
            };
            let mut est = mk();
            let mut out = Vec::new();
            let t0 = std::time::Instant::now();
            for _ in 0..steps {
                est.draw_batch(&theta, m, &mut out);
                compute(&out, &mut g, &mut accv);
            }
            let sync_secs = t0.elapsed().as_secs_f64();
            b.record(
                &format!("pipeline_step_b32_sync_shards{shards}"),
                sync_secs * 1e9 / steps as f64,
            );
            b.note(
                &format!("draws_per_sec_sync_shards{shards}"),
                (steps * m) as f64 / sync_secs,
            );
            // replay = one pipelined sampler thread (exact sync stream);
            // pershard = one dedicated worker per shard (requested via
            // workers >= 2 — the engine spawns rep.workers threads).
            for (mode, workers) in [("replay", 1usize), ("pershard", shards.max(2))] {
                let mut est = mk();
                let ecfg = DrawEngineConfig { workers, queue_depth: 1024 };
                let t0 = std::time::Instant::now();
                let rep = run_session(&mut est, &ecfg, &theta, m, steps, |_, draws| {
                    compute(draws, &mut g, &mut accv);
                    true
                })
                .unwrap();
                let secs = t0.elapsed().as_secs_f64();
                let tag = format!("async_{mode}_shards{shards}");
                b.record(&format!("pipeline_step_b32_{tag}"), secs * 1e9 / steps as f64);
                b.note(&format!("draws_per_sec_{tag}"), (steps * m) as f64 / secs);
                b.note(&format!("queue_stalls_{tag}"), rep.queue_stalls as f64);
                b.note(&format!("prefetch_hits_{tag}"), rep.prefetch_hits as f64);
                b.note(&format!("sampler_threads_{tag}"), rep.workers as f64);
            }
            bb(accv[0]);
        }
        // Shared-query-code contract, async edition: one fused hash
        // invocation per *session*, however many workers/batches it
        // serves (the sync path pays one per batch). Measured via the
        // hasher family's shared counters — this is the gated counter the
        // committed baseline pins at 1.
        let hasher = DenseSrp::new(hd, 5, 25, 35);
        let handle = hasher.clone();
        let mut est =
            ShardedLgdEstimator::new(&pre, hasher, 37, LgdOptions::default(), 4).unwrap();
        let before = handle.hash_stats();
        let ecfg = DrawEngineConfig { workers: 4, queue_depth: 256 };
        run_session(&mut est, &ecfg, &theta, 32, 50, |_, draws| {
            bb(draws.len());
            true
        })
        .unwrap();
        let after = handle.hash_stats();
        b.note(
            "fused_hash_invocations_per_async_session",
            (after.fused_calls - before.fused_calls) as f64,
        );
    }

    // --- snapshot_roundtrip: the amortization argument made measurable.
    // Cold table build vs snapshot save/load+restore for the same engine,
    // plus the bytes on disk. Timing rows are advisory (`_ns`), the byte
    // count matches the advisory `bytes` class — only real work counters
    // gate.
    {
        let shards = 4usize;
        let t0 = std::time::Instant::now();
        let est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 5, 25, 35),
            37,
            LgdOptions::default(),
            shards,
        )
        .unwrap();
        let cold_ns = t0.elapsed().as_secs_f64() * 1e9;
        b.record("snapshot_cold_build_n20k_shards4", cold_ns);
        let dir = std::env::temp_dir().join("lgd-bench-snapshot");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.lgdsnap");
        let t0 = std::time::Instant::now();
        let bytes = lgd::store::snapshot::save(&path, &est, None).unwrap();
        let save_ns = t0.elapsed().as_secs_f64() * 1e9;
        b.record("snapshot_save_n20k_shards4", save_ns);
        let t0 = std::time::Instant::now();
        let snap = lgd::store::snapshot::load(&path).unwrap();
        let mut warm =
            lgd::store::snapshot::restore_boxed(snap.hasher, &snap.pre, snap.engine).unwrap();
        let load_ns = t0.elapsed().as_secs_f64() * 1e9;
        b.record("snapshot_load_restore_n20k_shards4", load_ns);
        // warm engine must serve immediately — one draw as a liveness probe
        bb(warm.draw(&theta));
        b.note("snapshot_bytes_n20k_shards4", bytes as f64);
        b.note("snapshot_cold_build_ns_n20k", cold_ns);
        b.note("snapshot_save_ns_n20k", save_ns);
        b.note("snapshot_load_restore_ns_n20k", load_ns);
        let _ = std::fs::remove_file(&path);
    }

    // --- Telemetry overhead A/B: the same batched draw loop with the
    // sampling-quality probes disarmed vs armed. Two gates ride this row:
    // `telemetry_probe_extra_rng_draws` counts draw-stream divergences
    // between the runs and is pinned at 0 (armed probes observe — they
    // never touch the RNG), and the armed/disarmed throughput delta must
    // stay under 2% (asserted only on full runs; LGD_BENCH_FAST timings
    // are too short to gate on, so fast runs report the advisory rate).
    {
        let m = 32usize;
        let steps = if std::env::var("LGD_BENCH_FAST").is_ok() { 100 } else { 2000 };
        let mk = || {
            ShardedLgdEstimator::new(
                &pre,
                DenseSrp::new(hd, 5, 25, 35),
                37,
                LgdOptions::default(),
                2,
            )
            .unwrap()
        };
        probes::disarm();
        let mut est = mk();
        let mut out: Vec<WeightedDraw> = Vec::new();
        let mut off_draws: Vec<WeightedDraw> = Vec::with_capacity(steps * m);
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            est.draw_batch(&theta, m, &mut out);
            off_draws.extend(out.iter().copied());
        }
        let off_secs = t0.elapsed().as_secs_f64();
        probes::arm(4096, n);
        let mut est = mk();
        let mut on_draws: Vec<WeightedDraw> = Vec::with_capacity(steps * m);
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            est.draw_batch(&theta, m, &mut out);
            on_draws.extend(out.iter().copied());
        }
        let on_secs = t0.elapsed().as_secs_f64();
        probes::disarm();
        let diverged = off_draws.len().abs_diff(on_draws.len())
            + off_draws.iter().zip(&on_draws).filter(|(a, b)| a != b).count();
        assert_eq!(diverged, 0, "armed probes perturbed the draw stream");
        b.note("telemetry_probe_extra_rng_draws", diverged as f64);
        b.record("telemetry_off_draw_ns", off_secs * 1e9 / (steps * m) as f64);
        b.record("telemetry_on_draw_ns", on_secs * 1e9 / (steps * m) as f64);
        let overhead_pct = (on_secs / off_secs - 1.0) * 100.0;
        b.note("telemetry_overhead_rate_pct", overhead_pct);
        if std::env::var("LGD_BENCH_FAST").is_err() {
            assert!(
                overhead_pct < 2.0,
                "armed telemetry costs {overhead_pct:.2}% draw throughput (gate: < 2%)"
            );
        }
    }

    // --- Concurrent serving (`runtime::serving`): aggregate draws/sec of
    // one shared-read core vs client count. Every client is a pipelined
    // session with its own RNG stream and draw queue against the same
    // published generation, so this charts read-scaling, not lock
    // contention. Throughput names are advisory by class (`per_sec`);
    // `stale_candidates_rejected` is a gated work counter pinned at 0 —
    // a session's producer samples from the very generation its consumer
    // checks against, so any nonzero value is a real serving bug.
    {
        let pre = Arc::new(pre);
        let core = ServingCore::build(
            Arc::clone(&pre),
            DenseSrp::new(hd, 5, 25, 35),
            LgdOptions::default(),
            4,
        )
        .unwrap();
        let m = 32usize;
        let batches = if std::env::var("LGD_BENCH_FAST").is_ok() { 50 } else { 400 };
        let mut stale_total = 0u64;
        let mut degraded_total = 0u64;
        for &clients in &[1usize, 2, 4, 8] {
            let rep = run_harness(&core, clients, batches, m, &theta, 37).unwrap();
            b.record(
                &format!("serve_batch_b32_clients{clients}"),
                rep.wall_secs * 1e9 / (clients * batches) as f64,
            );
            b.note(&format!("draws_per_sec_clients{clients}"), rep.draws_per_sec);
            stale_total += rep.stale_rejected;
            degraded_total += rep.degraded;
        }
        b.note("stale_candidates_rejected", stale_total as f64);
        // Sessions that lost their sampler thread and fell back to
        // synchronous draws. Like the stale counter this is pinned at 0:
        // nothing in the bench arms a failpoint, so a nonzero value means a
        // worker died on its own.
        b.note("serve_degraded_sessions", degraded_total as f64);
    }

    // --- Health supervisor overhead: the same tiny training run with the
    // sentinels disarmed vs armed (and never tripping). The per-step
    // timing rows are advisory; the trip/rollback counters are gated work
    // counters pinned at 0 — a clean run that trips (or rolls back) is a
    // supervisor bug, not noise.
    {
        let ds = SynthSpec::power_law("rt-health", 2_000, 16, 51).generate().unwrap();
        let (tr, te) = ds.split(0.9, 1).unwrap();
        let hpre = preprocess(tr, &PreprocessOptions::default()).unwrap();
        let mut cfg = RunConfig::default();
        cfg.train.estimator = EstimatorKind::Lgd;
        cfg.train.epochs = 2;
        cfg.train.batch = 8;
        cfg.train.schedule = Schedule::Const(0.05);
        cfg.lsh.k = 4;
        cfg.lsh.l = 16;
        cfg.lsh.shards = 2;
        let t0 = std::time::Instant::now();
        let off = train(&cfg, &hpre, &te, GradSource::Native).unwrap();
        let off_ns = t0.elapsed().as_secs_f64() * 1e9;
        cfg.health.enabled = true;
        let t0 = std::time::Instant::now();
        let on = train(&cfg, &hpre, &te, GradSource::Native).unwrap();
        let on_ns = t0.elapsed().as_secs_f64() * 1e9;
        let steps = on.iterations.max(1) as f64;
        b.record("health_off_step_ns", off_ns / steps);
        b.record("health_on_step_ns", on_ns / steps);
        assert_eq!(off.theta, on.theta, "armed-but-untripped sentinels must be bitwise invisible");
        b.note("health_sentinel_trips", on.health.sentinel_trips() as f64);
        b.note("health_rollbacks", on.health.rollbacks as f64);
    }

    b.report();
    let json_path = lgd::benchkit::bench_json_path("BENCH_runtime.json");
    match b.write_json(&json_path) {
        Ok(()) => println!("wrote {}", json_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", json_path.display()),
    }
}

fn main() {
    bench_sharded_draws();
    let dir = lgd::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: no artifacts at {} — run `make artifacts` first", dir.display());
        return;
    }
    let mut rt = Runtime::new(&dir).unwrap();
    let mut b = Bench::new("pjrt runtime");

    let d = 90usize;
    let theta: Vec<f32> = (0..d).map(|i| i as f32 / d as f32).collect();
    for &batch in &[1usize, 32, 256] {
        let entry = format!("linreg_grad_b{batch}_d{d}");
        let x = vec![0.1f32; batch * d];
        let y = vec![0.2f32; batch];
        let w = vec![1.0f32; batch];
        let args = [
            lit_f32(&x, &[batch, d]).unwrap(),
            lit_f32(&y, &[batch]).unwrap(),
            lit_f32(&theta, &[d]).unwrap(),
            lit_f32(&w, &[batch]).unwrap(),
        ];
        rt.load(&entry).unwrap();
        b.bench(&format!("linreg_grad_b{batch}_d{d}"), || {
            bb(rt.execute(&entry, &args).unwrap());
        });
    }

    // loss eval at the chunk size the trainer uses
    let lb = 1024usize;
    let entry = format!("linreg_loss_b{lb}_d{d}");
    let args = [
        lit_f32(&vec![0.1f32; lb * d], &[lb, d]).unwrap(),
        lit_f32(&vec![0.2f32; lb], &[lb]).unwrap(),
        lit_f32(&theta, &[d]).unwrap(),
    ];
    rt.load(&entry).unwrap();
    b.bench("linreg_loss_b1024_d90", || {
        bb(rt.execute(&entry, &args).unwrap());
    });

    // simhash codes kernel
    let entry = "simhash_b64_d91_k5_l100";
    let args = [
        lit_f32(&vec![0.1f32; 64 * 91], &[64, 91]).unwrap(),
        lit_f32(&vec![0.05f32; 500 * 91], &[500, 91]).unwrap(),
    ];
    rt.load(entry).unwrap();
    b.bench("simhash_codes_b64", || {
        bb(rt.execute(entry, &args).unwrap());
    });

    // mini-BERT Adam step (grad through PJRT + update in Rust)
    let mut sess = BertSession::new(&mut rt, 1e-4).unwrap();
    let t = sess.abi().max_t;
    let bsz = sess.grad_batch();
    let ids: Vec<i32> = (0..bsz * t).map(|i| (i % 512) as i32).collect();
    let labels: Vec<i32> = (0..bsz).map(|i| (i % 2) as i32).collect();
    let weights = vec![1.0f32; bsz];
    b.bench("bert_step_b32 (grad+Adam)", || {
        bb(sess.step(&mut rt, &ids, &labels, &weights).unwrap());
    });
    let eids: Vec<i32> = (0..sess.eval_batch() * t).map(|i| (i % 512) as i32).collect();
    b.bench("bert_pooled_b64", || {
        bb(sess.pooled(&mut rt, &eids).unwrap());
    });
    let _ = lit_i32(&[0], &[1]); // keep import used in all cfgs
    b.report();
}
