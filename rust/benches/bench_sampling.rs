//! §2.2 sampling-cost table: per-draw cost of uniform (SGD) vs LSH (LGD)
//! sampling, the gradient-update baseline, table build, and the §2.2.1
//! near-neighbor query comparison. Regenerates the paper's running-time
//! accounting on this machine.

use std::time::Instant;

use lgd::benchkit::{bb, Bench};
use lgd::config::spec::{EstimatorKind, HasherKind, RunConfig};
use lgd::coordinator::metrics::Metrics;
use lgd::coordinator::pipeline::{build_shard_tables, streaming_build_sharded, PipelineConfig};
use lgd::coordinator::trainer::build_estimator;
use lgd::core::matrix::axpy;
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::shard::ShardPlan;
use lgd::data::SynthSpec;
use lgd::estimator::lgd::{LgdEstimator, LgdOptions};
use lgd::core::telemetry::probes;
use lgd::core::telemetry::registry::Registry;
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator, WeightedDraw};
use lgd::lsh::sampler::LshSampler;
use lgd::lsh::srp::{DenseSrp, SparseSrp, SrpHasher};
use lgd::lsh::tables::LshTables;
use lgd::model::{LinReg, Model};

fn main() {
    let mut b = Bench::new("sampling (paper §2.2 cost model)");
    // Keep N modest so the bench is quick but buckets are realistic.
    for &(n, d) in &[(8_000usize, 90usize), (4_000, 385), (2_000, 529)] {
        let ds = SynthSpec::power_law(&format!("d{d}"), n, d, 7).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let theta = vec![0.01f32; d];
        let model = LinReg;

        let mut cfg = RunConfig::default();
        cfg.lsh.hasher = HasherKind::Sparse; // paper: sparsity 1/30, K=5, L=100
        cfg.train.estimator = EstimatorKind::Sgd;
        let mut sgd = build_estimator(&cfg, &pre).unwrap();
        cfg.train.estimator = EstimatorKind::Lgd;
        let mut lgd = build_estimator(&cfg, &pre).unwrap();

        b.bench(&format!("sgd_draw_d{d}"), || {
            bb(sgd.draw(&theta));
        });
        b.bench(&format!("lgd_draw_d{d}"), || {
            bb(lgd.draw(&theta));
        });
        // The d-multiplication baseline: one gradient + axpy update.
        let mut g = vec![0.0f32; d];
        let mut out = vec![0.0f32; d];
        let mut i = 0usize;
        b.bench(&format!("grad_update_d{d}"), || {
            let (x, y) = pre.data.example(i % pre.data.len());
            model.grad(x, y, &theta, &mut g);
            axpy(-0.01, &g, &mut out);
            i += 1;
            bb(out[0]);
        });

        // Table build (one-time preprocessing).
        b.bench(&format!("table_build_n{n}_d{d}_L25"), || {
            let h = SparseSrp::paper_default(pre.hashed.cols(), 5, 25, 3);
            let t = LshTables::build(h, (0..pre.data.len()).map(|r| pre.hashed.row(r))).unwrap();
            bb(t.len());
        });

        // §2.2.1: full near-neighbor candidate query.
        let h = SparseSrp::paper_default(pre.hashed.cols(), 5, 100, 3);
        let tables = LshTables::build(h, (0..pre.data.len()).map(|r| pre.hashed.row(r))).unwrap();
        let sampler = LshSampler::new(&tables, &pre.hashed);
        let mut q = Vec::new();
        pre.query(&theta, &mut q);
        b.bench(&format!("nn_query_d{d}"), || {
            bb(sampler.nn_query(&q));
        });
    }
    // Sharded sampling engine: one-time table-build cost over a 50k-point
    // synthetic dataset — a single sequential build vs the concurrent
    // per-shard build (same total rows inserted) — then draw throughput of
    // the single structure vs the 4-shard mixture.
    let n = 50_000usize;
    let d = 32usize;
    let ds = SynthSpec::power_law("shard", n, d, 21).generate().unwrap();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    let hd = pre.hashed.cols();
    let hasher = DenseSrp::new(hd, 5, 50, 9);
    let t0 = Instant::now();
    let full = LshTables::build(hasher.clone(), (0..n).map(|i| pre.hashed.row(i))).unwrap();
    let single = t0.elapsed().as_secs_f64();
    bb(full.len());
    b.record("table_build_n50k_L50_shards1", single * 1e9);
    println!("\nsharded table build, n={n} L=50:");
    println!("  shards=1  {single:.3}s (baseline)");
    for &s in &[2usize, 4, 8] {
        let plan = ShardPlan::round_robin(n, s).unwrap();
        let m = Metrics::new();
        let t0 = Instant::now();
        let built = build_shard_tables(&pre.hashed, &plan, false, &hasher, &m).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        bb(built.len());
        b.record(&format!("table_build_n50k_L50_shards{s}"), wall * 1e9);
        println!("  shards={s}  {wall:.3}s  ({:.2}x vs single)", single / wall);
    }

    // Streaming sharded ingest: same 4-shard tables built from a record
    // stream (Source → Preprocess → per-shard workers) instead of a
    // materialized matrix — the ingest-path cost of the live engine.
    let stream_ds = SynthSpec::power_law("shard", n, d, 21).generate().unwrap();
    let m = Metrics::new();
    let t0 = Instant::now();
    let (_pre_s, shards_s, rep) = streaming_build_sharded(
        stream_ds,
        hasher.clone(),
        4,
        false,
        &PipelineConfig::default(),
        &m,
    )
    .unwrap();
    let stream_wall = t0.elapsed().as_secs_f64();
    bb(shards_s.len());
    b.record("streaming_sharded_build_n50k_L50_shards4", stream_wall * 1e9);
    println!(
        "  streaming 4-shard ingest: {stream_wall:.3}s ({:.0} records/s)",
        rep.throughput
    );

    let theta = vec![0.01f32; d];
    let mut lgd1 =
        LgdEstimator::new(&pre, DenseSrp::new(hd, 5, 25, 11), 13, LgdOptions::default()).unwrap();
    b.bench("lgd_draw_n50k_shards1", || {
        bb(lgd1.draw(&theta));
    });
    let mut lgd4 = ShardedLgdEstimator::new(
        &pre,
        DenseSrp::new(hd, 5, 25, 11),
        13,
        LgdOptions::default(),
        4,
    )
    .unwrap();
    b.bench("lgd_draw_n50k_shards4", || {
        bb(lgd4.draw(&theta));
    });

    // --- Fused vs per-row query hashing (paper config K=5, L=100, density
    // 1/30): the same multiplication budget, one sequential CSC sweep vs
    // L·K scattered sparse rows. The counters record the per-path mults so
    // the trajectory file shows the cost-model parity; the timing rows show
    // the locality win.
    {
        let hq = 91usize;
        let q: Vec<f32> = (0..hq).map(|i| ((i * 37 % 97) as f32 / 97.0) - 0.5).collect();
        let sparse = SparseSrp::paper_default(hq, 5, 100, 41);
        let dense = DenseSrp::new(hq, 5, 100, 41);
        let mut codes = Vec::new();
        b.bench("hash_query_fused_sparse_d91_L100", || {
            sparse.codes_all(&q, &mut codes);
            bb(codes.len());
        });
        b.bench("hash_query_per_row_sparse_d91_L100", || {
            let mut acc = 0u32;
            for t in 0..100 {
                acc ^= sparse.code(t, &q);
            }
            bb(acc);
        });
        b.bench("hash_query_fused_dense_d91_L100", || {
            dense.codes_all(&q, &mut codes);
            bb(codes.len());
        });
        b.bench("hash_query_per_row_dense_d91_L100", || {
            let mut acc = 0u32;
            for t in 0..100 {
                acc ^= dense.code(t, &q);
            }
            bb(acc);
        });
        b.note("hash_sparse_mults_per_query_fused", sparse.mults_all());
        b.note("hash_sparse_mults_per_query_per_row", 100.0 * sparse.mults_per_code());
        b.note("hash_dense_mults_per_query_fused", dense.mults_all());
        b.note("hash_dense_mults_per_query_per_row", 100.0 * dense.mults_per_code());
    }

    // --- Sealed CSR arena vs Vec buckets on the draw path: identical
    // logical work (probe counters match draw-for-draw) — the arena wins on
    // locality, and the counters prove the parity.
    let mk_est = |sealed: bool| {
        let opts = LgdOptions { sealed, ..LgdOptions::default() };
        ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 5, 25, 11), 13, opts, 4).unwrap()
    };
    let mut sealed_est = mk_est(true);
    let mut vec_est = mk_est(false);
    b.bench("lgd_draw_n50k_shards4_sealed", || {
        bb(sealed_est.draw(&theta));
    });
    b.bench("lgd_draw_n50k_shards4_vec", || {
        bb(vec_est.draw(&theta));
    });
    let mut out = Vec::new();
    b.bench("lgd_batch32_n50k_shards4_sealed", || {
        sealed_est.draw_batch(&theta, 32, &mut out);
        bb(out.len());
    });
    b.bench("lgd_batch32_n50k_shards4_vec", || {
        vec_est.draw_batch(&theta, 32, &mut out);
        bb(out.len());
    });
    for (tag, est) in [("sealed", &sealed_est), ("vec", &vec_est)] {
        let st = est.stats();
        let draws = st.draws.max(1) as f64;
        b.note(&format!("bucket_probes_per_draw_{tag}"), st.cost.probes as f64 / draws);
        b.note(&format!("hash_mults_per_draw_{tag}"), st.cost.mults / draws);
    }

    // --- Aligned-kernel dispatch A/B (docs/numerics.md): the same draw
    // stream under auto (SIMD when available) vs forced-scalar dispatch —
    // draws and mults counters are identical by construction; the ns rows
    // are advisory and show the dispatch win on the cp hot path.
    {
        use lgd::core::numerics::{set_kernel_mode, simd_active, KernelMode};
        println!("\nkernel dispatch A/B: simd active under auto = {}", simd_active());
        for mode in [KernelMode::Auto, KernelMode::Scalar] {
            set_kernel_mode(mode);
            b.bench(&format!("lgd_draw_n50k_shards4_kernel_{}", mode.name()), || {
                bb(sealed_est.draw(&theta));
            });
        }
        set_kernel_mode(KernelMode::Auto);
    }

    // --- Shared-query-code contract: one fused hash invocation per batch,
    // zero per-table code() calls on the draw path, independent of shard
    // count (measured via the hasher family's shared counters).
    for shards in [1usize, 4] {
        let hasher = DenseSrp::new(hd, 5, 25, 11);
        let handle = hasher.clone();
        let mut est =
            ShardedLgdEstimator::new(&pre, hasher, 13, LgdOptions::default(), shards).unwrap();
        let base = handle.hash_stats();
        let batches = 50usize;
        for _ in 0..batches {
            est.draw_batch(&theta, 32, &mut out);
        }
        let s = handle.hash_stats();
        b.note(
            &format!("fused_hash_invocations_per_batch_shards{shards}"),
            (s.fused_calls - base.fused_calls) as f64 / batches as f64,
        );
        b.note(
            &format!("per_row_code_calls_on_draw_path_shards{shards}"),
            (s.code_calls - base.code_calls) as f64,
        );
    }

    // --- Telemetry probe gates: the armed sampling-quality probes must be
    // bitwise invisible (same seed → identical draw stream) AND account
    // for every emitted draw exactly once (hit or uniform fallback — the
    // `probe.draws` gauge after `publish`). Both counters gate at 0.
    {
        let batches = 100usize;
        let m = 32usize;
        let mk = || {
            let h = DenseSrp::new(hd, 5, 25, 11);
            ShardedLgdEstimator::new(&pre, h, 13, LgdOptions::default(), 4).unwrap()
        };
        probes::disarm();
        let mut est = mk();
        let mut plain: Vec<WeightedDraw> = Vec::with_capacity(batches * m);
        for _ in 0..batches {
            est.draw_batch(&theta, m, &mut out);
            plain.extend(out.iter().copied());
        }
        probes::arm(4096, n);
        let mut est = mk();
        let mut armed: Vec<WeightedDraw> = Vec::with_capacity(batches * m);
        for _ in 0..batches {
            est.draw_batch(&theta, m, &mut out);
            armed.extend(out.iter().copied());
        }
        probes::publish(Registry::global());
        let accounted = Registry::global().gauge_value("probe.draws");
        probes::disarm();
        let diverged = plain.iter().zip(&armed).filter(|(a, b)| a != b).count();
        assert_eq!(diverged, 0, "armed probes perturbed the draw stream");
        b.note("telemetry_probe_extra_rng_draws", diverged as f64);
        b.note(
            "telemetry_probe_draw_accounting_gap",
            (accounted - (batches * m) as f64).abs(),
        );
    }

    b.report();
    let json_path = lgd::benchkit::bench_json_path("BENCH_sampling.json");
    match b.write_json(&json_path) {
        Ok(()) => println!("\nwrote {}", json_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", json_path.display()),
    }
    println!("\npaper claim: LGD iteration ~= 1.5x SGD iteration; check");
    println!("(lgd_draw + grad_update) / (sgd_draw + grad_update) per d above.");
}
