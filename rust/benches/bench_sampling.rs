//! §2.2 sampling-cost table: per-draw cost of uniform (SGD) vs LSH (LGD)
//! sampling, the gradient-update baseline, table build, and the §2.2.1
//! near-neighbor query comparison. Regenerates the paper's running-time
//! accounting on this machine.

use std::time::Instant;

use lgd::benchkit::{bb, Bench};
use lgd::config::spec::{EstimatorKind, HasherKind, RunConfig};
use lgd::coordinator::metrics::Metrics;
use lgd::coordinator::pipeline::{build_shard_tables, streaming_build_sharded, PipelineConfig};
use lgd::coordinator::trainer::build_estimator;
use lgd::core::matrix::axpy;
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::shard::ShardPlan;
use lgd::data::SynthSpec;
use lgd::estimator::lgd::{LgdEstimator, LgdOptions};
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator};
use lgd::lsh::sampler::LshSampler;
use lgd::lsh::srp::{DenseSrp, SparseSrp};
use lgd::lsh::tables::LshTables;
use lgd::model::{LinReg, Model};

fn main() {
    let mut b = Bench::new("sampling (paper §2.2 cost model)");
    // Keep N modest so the bench is quick but buckets are realistic.
    for &(n, d) in &[(8_000usize, 90usize), (4_000, 385), (2_000, 529)] {
        let ds = SynthSpec::power_law(&format!("d{d}"), n, d, 7).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let theta = vec![0.01f32; d];
        let model = LinReg;

        let mut cfg = RunConfig::default();
        cfg.lsh.hasher = HasherKind::Sparse; // paper: sparsity 1/30, K=5, L=100
        cfg.train.estimator = EstimatorKind::Sgd;
        let mut sgd = build_estimator(&cfg, &pre).unwrap();
        cfg.train.estimator = EstimatorKind::Lgd;
        let mut lgd = build_estimator(&cfg, &pre).unwrap();

        b.bench(&format!("sgd_draw_d{d}"), || {
            bb(sgd.draw(&theta));
        });
        b.bench(&format!("lgd_draw_d{d}"), || {
            bb(lgd.draw(&theta));
        });
        // The d-multiplication baseline: one gradient + axpy update.
        let mut g = vec![0.0f32; d];
        let mut out = vec![0.0f32; d];
        let mut i = 0usize;
        b.bench(&format!("grad_update_d{d}"), || {
            let (x, y) = pre.data.example(i % pre.data.len());
            model.grad(x, y, &theta, &mut g);
            axpy(-0.01, &g, &mut out);
            i += 1;
            bb(out[0]);
        });

        // Table build (one-time preprocessing).
        b.bench(&format!("table_build_n{n}_d{d}_L25"), || {
            let h = SparseSrp::paper_default(pre.hashed.cols(), 5, 25, 3);
            let t = LshTables::build(h, (0..pre.data.len()).map(|r| pre.hashed.row(r))).unwrap();
            bb(t.len());
        });

        // §2.2.1: full near-neighbor candidate query.
        let h = SparseSrp::paper_default(pre.hashed.cols(), 5, 100, 3);
        let tables = LshTables::build(h, (0..pre.data.len()).map(|r| pre.hashed.row(r))).unwrap();
        let sampler = LshSampler::new(&tables, &pre.hashed);
        let mut q = Vec::new();
        pre.query(&theta, &mut q);
        b.bench(&format!("nn_query_d{d}"), || {
            bb(sampler.nn_query(&q));
        });
    }
    // Sharded sampling engine: one-time table-build cost over a 50k-point
    // synthetic dataset — a single sequential build vs the concurrent
    // per-shard build (same total rows inserted) — then draw throughput of
    // the single structure vs the 4-shard mixture.
    let n = 50_000usize;
    let d = 32usize;
    let ds = SynthSpec::power_law("shard", n, d, 21).generate().unwrap();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    let hd = pre.hashed.cols();
    let hasher = DenseSrp::new(hd, 5, 50, 9);
    let t0 = Instant::now();
    let full = LshTables::build(hasher.clone(), (0..n).map(|i| pre.hashed.row(i))).unwrap();
    let single = t0.elapsed().as_secs_f64();
    bb(full.len());
    b.record("table_build_n50k_L50_shards1", single * 1e9);
    println!("\nsharded table build, n={n} L=50:");
    println!("  shards=1  {single:.3}s (baseline)");
    for &s in &[2usize, 4, 8] {
        let plan = ShardPlan::round_robin(n, s).unwrap();
        let m = Metrics::new();
        let t0 = Instant::now();
        let built = build_shard_tables(&pre.hashed, &plan, false, &hasher, &m).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        bb(built.len());
        b.record(&format!("table_build_n50k_L50_shards{s}"), wall * 1e9);
        println!("  shards={s}  {wall:.3}s  ({:.2}x vs single)", single / wall);
    }

    // Streaming sharded ingest: same 4-shard tables built from a record
    // stream (Source → Preprocess → per-shard workers) instead of a
    // materialized matrix — the ingest-path cost of the live engine.
    let stream_ds = SynthSpec::power_law("shard", n, d, 21).generate().unwrap();
    let m = Metrics::new();
    let t0 = Instant::now();
    let (_pre_s, shards_s, rep) = streaming_build_sharded(
        stream_ds,
        hasher.clone(),
        4,
        false,
        &PipelineConfig::default(),
        &m,
    )
    .unwrap();
    let stream_wall = t0.elapsed().as_secs_f64();
    bb(shards_s.len());
    b.record("streaming_sharded_build_n50k_L50_shards4", stream_wall * 1e9);
    println!(
        "  streaming 4-shard ingest: {stream_wall:.3}s ({:.0} records/s)",
        rep.throughput
    );

    let theta = vec![0.01f32; d];
    let mut lgd1 =
        LgdEstimator::new(&pre, DenseSrp::new(hd, 5, 25, 11), 13, LgdOptions::default()).unwrap();
    b.bench("lgd_draw_n50k_shards1", || {
        bb(lgd1.draw(&theta));
    });
    let mut lgd4 = ShardedLgdEstimator::new(
        &pre,
        DenseSrp::new(hd, 5, 25, 11),
        13,
        LgdOptions::default(),
        4,
    )
    .unwrap();
    b.bench("lgd_draw_n50k_shards4", || {
        bb(lgd4.draw(&theta));
    });

    b.report();
    println!("\npaper claim: LGD iteration ~= 1.5x SGD iteration; check");
    println!("(lgd_draw + grad_update) / (sgd_draw + grad_update) per d above.");
}
