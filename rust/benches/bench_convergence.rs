//! Time-to-target-loss: the headline metric behind Figures 3/10 — how long
//! each estimator takes to push training loss below a fixed target on the
//! power-law workload (and the parity check on the uniform control).

use lgd::benchkit::Bench;
use lgd::config::spec::{EstimatorKind, RunConfig};
use lgd::coordinator::trainer::{train, GradSource};
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::SynthSpec;
use lgd::optim::Schedule;

fn time_to_target(
    spec: &SynthSpec,
    est: EstimatorKind,
    target_frac: f64,
    seed: u64,
) -> (f64, f64, f64) {
    let ds = spec.generate().unwrap();
    let (tr, te) = ds.split(0.9, seed).unwrap();
    let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
    let mut cfg = RunConfig::default();
    cfg.train.estimator = est;
    cfg.train.epochs = 6;
    cfg.train.schedule = Schedule::Const(0.05);
    cfg.train.eval_every = (pre.data.len() / 4).max(1);
    cfg.lsh.l = 50;
    cfg.train.seed = seed;
    let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
    let first = out.curve.first().unwrap().train_loss;
    let target = first * target_frac;
    let hit = out
        .curve
        .iter()
        .find(|p| p.train_loss <= target)
        .map(|p| p.wall)
        .unwrap_or(f64::INFINITY);
    (hit, out.curve.last().unwrap().train_loss, out.wall_secs)
}

fn main() {
    let mut b = Bench::new("convergence (time-to-target)");
    let n = 6_000;
    for (regime, spec) in [
        ("powerlaw", SynthSpec::power_law("powerlaw", n, 90, 5)),
        ("uniform", SynthSpec::uniform_control("uniform", n, 90, 5)),
    ] {
        for est in [EstimatorKind::Lgd, EstimatorKind::Sgd] {
            let (t_hit, final_loss, total) = time_to_target(&spec, est, 0.75, 42);
            let name = format!(
                "{regime}_{}",
                if est == EstimatorKind::Lgd { "lgd" } else { "sgd" }
            );
            println!(
                "  {name}: reached 75% of initial loss at {t_hit:.3}s; final {final_loss:.5} \
                 (total train {total:.3}s)"
            );
            b.record(&format!("{name}_time_to_0.75_loss_s"), t_hit * 1e9);
        }
    }
    b.report();
    println!("\nexpected shape: lgd < sgd on powerlaw; parity (within noise) on uniform.");
}
