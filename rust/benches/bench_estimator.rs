//! Full-iteration cost (sample + gradient estimate + update) of LGD vs SGD
//! at batch 1 and 32, plus the variance measurement throughput — the
//! end-to-end per-iteration numbers behind the wall-clock curves.

use lgd::benchkit::{bb, Bench};
use lgd::config::spec::{EstimatorKind, HasherKind, RunConfig};
use lgd::coordinator::trainer::build_estimator;
use lgd::core::matrix::axpy;
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::SynthSpec;
use lgd::estimator::WeightedDraw;
use lgd::model::{LinReg, Model};
use lgd::optim::{Optimizer, Sgd};

fn main() {
    let mut bench = Bench::new("estimator iteration");
    for &(n, d) in &[(8_000usize, 90usize), (2_000, 529)] {
        let ds = SynthSpec::power_law(&format!("d{d}"), n, d, 11).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let model = LinReg;

        for est_kind in [EstimatorKind::Sgd, EstimatorKind::Lgd] {
            let mut cfg = RunConfig::default();
            cfg.lsh.hasher = HasherKind::Sparse;
            cfg.train.estimator = est_kind;
            let mut est = build_estimator(&cfg, &pre).unwrap();
            let name = match est_kind {
                EstimatorKind::Sgd => "sgd",
                EstimatorKind::Lgd => "lgd",
            };

            // batch = 1 (the paper's plain setting)
            let mut theta = vec![0.0f32; d];
            let mut g = vec![0.0f32; d];
            let mut opt = Sgd::constant(1e-3);
            bench.bench(&format!("{name}_iter_b1_d{d}"), || {
                let dr = est.draw(&theta);
                let (x, y) = pre.data.example(dr.index);
                model.grad(x, y, &theta, &mut g);
                lgd::core::matrix::scale(dr.weight as f32, &mut g);
                opt.step(&mut theta, &g);
                bb(theta[0]);
            });

            // batch = 32 (Appendix B.2)
            let mut draws: Vec<WeightedDraw> = Vec::new();
            let mut acc = vec![0.0f32; d];
            bench.bench(&format!("{name}_iter_b32_d{d}"), || {
                est.draw_batch(&theta, 32, &mut draws);
                acc.iter_mut().for_each(|v| *v = 0.0);
                for dr in &draws {
                    let (x, y) = pre.data.example(dr.index);
                    model.grad(x, y, &theta, &mut g);
                    axpy(dr.weight as f32 / 32.0, &g, &mut acc);
                }
                opt.step(&mut theta, &acc);
                bb(theta[0]);
            });
        }
    }
    bench.report();
}
