//! Minimal JSON parser and writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`) written by the
//! Python AOT pipeline and read by the Rust runtime. `serde` is unavailable
//! in the offline build, so this is a small recursive-descent parser covering
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::core::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64, like JavaScript).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view of a number (errors elsewhere handle fractional cases).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object view.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json: {msg} at byte {}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{s}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // NOTE: surrogate pairs unsupported (not emitted
                            // by our python writer).
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"num":-7}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
        assert_eq!(out, src);
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let j = Json::parse(r#""héllo A \"q\"""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo A \"q\""));
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn usize_view() {
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("4.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
