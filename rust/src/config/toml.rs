//! TOML-subset parser for experiment configuration files.
//!
//! Supported grammar (the subset the repo's configs use):
//! * `[section]` headers (one level)
//! * `key = value` with string (`"…"`), integer, float, boolean and
//!   homogeneous array (`[1, 2, 3]`) values
//! * `#` comments, blank lines
//!
//! Values are exposed through typed getters with good error messages.

use std::collections::BTreeMap;

use crate::core::error::{Error, Result};

/// A TOML scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Array of values.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// Float view (ints widen).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

/// A parsed config: `sections[section][key] = value`. Keys before any
/// section header land in section `""`.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {}: unclosed section", ln + 1)))?
                    .trim();
                if name.is_empty() {
                    return Err(Error::Config(format!("line {}: empty section name", ln + 1)));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", ln + 1)))?;
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", ln + 1)));
            }
            let value = parse_value(val)
                .map_err(|e| Error::Config(format!("line {}: {e}", ln + 1)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Parse a file.
    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
        Self::parse(&text)
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// Section names.
    pub fn section_names(&self) -> Vec<&str> {
        self.sections.keys().map(|s| s.as_str()).collect()
    }

    /// All keys of a section.
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|m| m.keys().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// Typed getters: error when present-but-wrong-type, `default` when absent.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(TomlValue::Str(s)) => Ok(s.clone()),
            Some(v) => Err(type_err(section, key, "string", v)),
        }
    }

    /// Integer getter with default.
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> Result<i64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(TomlValue::Int(i)) => Ok(*i),
            Some(v) => Err(type_err(section, key, "integer", v)),
        }
    }

    /// Float getter with default (ints widen).
    pub fn float_or(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| type_err(section, key, "float", v)),
        }
    }

    /// Bool getter with default.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some(TomlValue::Bool(b)) => Ok(*b),
            Some(v) => Err(type_err(section, key, "bool", v)),
        }
    }

    /// Float-array getter (empty when absent).
    pub fn floats(&self, section: &str, key: &str) -> Result<Vec<f64>> {
        match self.get(section, key) {
            None => Ok(Vec::new()),
            Some(TomlValue::Arr(a)) => a
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| type_err(section, key, "float array", v)))
                .collect(),
            Some(v) => Err(type_err(section, key, "array", v)),
        }
    }
}

fn type_err(section: &str, key: &str, want: &str, got: &TomlValue) -> Error {
    Error::Config(format!("[{section}] {key}: expected {want}, got {got:?}"))
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(Vec::new()));
        }
        let items: std::result::Result<Vec<TomlValue>, String> =
            split_top_level(inner).into_iter().map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    // number: int unless it has . e E
    if s.contains('.') || s.contains('e') || s.contains('E') {
        s.parse::<f64>().map(TomlValue::Float).map_err(|_| format!("bad float '{s}'"))
    } else {
        s.parse::<i64>()
            .map(TomlValue::Int)
            .or_else(|_| s.parse::<f64>().map(TomlValue::Float))
            .map_err(|_| format!("bad number '{s}'"))
    }
}

/// Split an array body on commas not nested in brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig10"          # inline comment
seed = 42

[lsh]
k = 5
l = 100
density = 0.033333
sparse = true

[train]
lr_sweep = [1e-5, 1e-3, 1e-1]
epochs = 10
dataset = "yearmsd-like"
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("", "name", "x").unwrap(), "fig10");
        assert_eq!(d.int_or("", "seed", 0).unwrap(), 42);
        assert_eq!(d.int_or("lsh", "k", 0).unwrap(), 5);
        assert!(d.bool_or("lsh", "sparse", false).unwrap());
        assert!((d.float_or("lsh", "density", 0.0).unwrap() - 0.033333).abs() < 1e-9);
        assert_eq!(d.floats("train", "lr_sweep").unwrap(), vec![1e-5, 1e-3, 1e-1]);
        assert_eq!(d.str_or("train", "dataset", "").unwrap(), "yearmsd-like");
    }

    #[test]
    fn defaults_on_missing() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.int_or("a", "b", 7).unwrap(), 7);
        assert_eq!(d.str_or("a", "b", "dft").unwrap(), "dft");
        assert!(d.floats("a", "b").unwrap().is_empty());
    }

    #[test]
    fn type_errors_are_reported() {
        let d = TomlDoc::parse("k = \"five\"").unwrap();
        assert!(d.int_or("", "k", 0).is_err());
        let d = TomlDoc::parse("k = 5").unwrap();
        assert!(d.str_or("", "k", "").is_err());
    }

    #[test]
    fn int_widens_to_float() {
        let d = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(d.float_or("", "x", 0.0).unwrap(), 3.0);
    }

    #[test]
    fn bad_syntax_rejected_with_line_numbers() {
        for bad in ["[unclosed", "novalue", "= 3", "x = ", "x = [1, 2"] {
            let e = TomlDoc::parse(bad).unwrap_err().to_string();
            assert!(e.contains("line 1"), "error '{e}' for '{bad}'");
        }
    }

    #[test]
    fn comments_respect_strings() {
        let d = TomlDoc::parse(r##"s = "a#b" # real comment"##).unwrap();
        assert_eq!(d.str_or("", "s", "").unwrap(), "a#b");
    }

    #[test]
    fn nested_arrays() {
        let d = TomlDoc::parse("a = [[1, 2], [3]]").unwrap();
        match d.get("", "a").unwrap() {
            TomlValue::Arr(outer) => {
                assert_eq!(outer.len(), 2);
                assert_eq!(outer[0], TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Int(2)]));
            }
            v => panic!("wrong value {v:?}"),
        }
    }
}
