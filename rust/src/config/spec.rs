//! Typed experiment/run configuration with defaults matching the paper
//! (§3.1: K=5, L=100, sparse projections at density 1/30) and validation.

use std::path::PathBuf;

use crate::core::error::{Error, Result};
use crate::config::toml::TomlDoc;
use crate::core::numerics::KernelMode;
use crate::optim::Schedule;

/// Which hash family backs the sampler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HasherKind {
    /// Dense N(0,1) SimHash.
    Dense,
    /// Very sparse ±1 projections (paper default).
    Sparse,
    /// Implicit quadratic feature-map SRP (targets |inner product| exactly).
    Quadratic,
}

impl HasherKind {
    /// THE canonical kind name — the string used by the TOML config, the
    /// snapshot metadata and every user-facing report. One definition so a
    /// new family cannot drift across the config parser, the resume gate
    /// and the snapshot inspector.
    pub fn name(self) -> &'static str {
        match self {
            HasherKind::Dense => "dense",
            HasherKind::Sparse => "sparse",
            HasherKind::Quadratic => "quadratic",
        }
    }
}

/// Which gradient estimator a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorKind {
    /// Uniform sampling (plain SGD).
    Sgd,
    /// LSH-sampled (the paper's LGD).
    Lgd,
}

/// Which update rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Plain schedule-driven GD update.
    Sgd,
    /// AdaGrad.
    AdaGrad,
    /// Adam.
    Adam,
}

/// Gradient execution backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust gradient math (the wall-clock figures; both samplers share
    /// it, keeping comparisons fair).
    Native,
    /// AOT-compiled HLO executed through the PJRT runtime (proves the
    /// three-layer composition; used by the e2e examples).
    Pjrt,
}

/// LSH block of a run config.
#[derive(Debug, Clone)]
pub struct LshConfig {
    /// Bits per table.
    pub k: usize,
    /// Number of tables.
    pub l: usize,
    /// Hash family.
    pub hasher: HasherKind,
    /// Nonzero density for sparse/quadratic families.
    pub density: f64,
    /// Center stored hash vectors (§2.2 ablation).
    pub center: bool,
    /// Mirrored storage (hash v and −v; |·| monotonicity — see
    /// `estimator::lgd::LgdOptions::mirror`).
    pub mirror: bool,
    /// Optional importance-weight cap.
    pub weight_clip: Option<f64>,
    /// Hasher seed.
    pub seed: u64,
    /// Data shards for the parallel sampling engine: tables are built
    /// concurrently (one worker per shard) and draws come from the exact
    /// shard-mixture proposal. 1 = the single-threaded `LgdEstimator`.
    pub shards: usize,
    /// Live-shard rebalance trigger for the sharded engine: after a
    /// streaming insert/remove pushes the per-shard example imbalance
    /// (max/mean) above this, examples migrate between shard tables until
    /// it is back under — the mixture weights `R_s/R` are recomputed so
    /// draws stay exactly unbiased. 0 = rebalancing off (static builds
    /// never need it); enabled values must be ≥ 1.0 (1.0 = keep shards
    /// within one example of perfectly balanced) and require `shards > 1`
    /// — validation rejects the knob on a single shard rather than
    /// silently ignoring it.
    pub rebalance_threshold: f64,
    /// Seal LSH tables into the CSR bucket arena after the build (O(1)
    /// probe, cache-linear bucket reads on the draw path; live mutations
    /// go through a delta overlay that rebalancing compacts). Draw-for-draw
    /// identical to the Vec layout under the same seed — default on;
    /// `sealed = false` A/Bs the layouts.
    pub sealed: bool,
    /// Async pipelined draw engine (`coordinator::draw_engine`): 0 =
    /// synchronous draws (default — byte-identical to the pre-engine
    /// behavior), 1 = one pipelined sampler thread whose stream is
    /// draw-for-draw identical to the synchronous path, >= 2 = one
    /// dedicated sampler worker per shard feeding bounded candidate
    /// queues, mixed into exact shard-mixture batches while the trainer's
    /// gradient step runs. Note the knob selects a *mode*, not a thread
    /// count: every value >= 2 is equivalent — sampler parallelism tracks
    /// the shard count (each shard's queue has a single writer).
    pub async_workers: usize,
    /// Bound on the engine's pre-drawn work, in draws (per-shard candidate
    /// queue capacity; assembled batches are capped at `queue_depth /
    /// batch`). Must be >= 1; irrelevant when `async_workers = 0`.
    pub queue_depth: usize,
    /// Kernel dispatch for the aligned numerics layer (`core::numerics`):
    /// `auto` (default) uses the SIMD path when the CPU supports it,
    /// `scalar` forces the portable loops. The two are bitwise-identical —
    /// this knob exists purely for A/B debugging of the dispatch path; see
    /// docs/numerics.md.
    pub kernel: KernelMode,
}

impl Default for LshConfig {
    fn default() -> Self {
        // §3.1 sets K=5, L=100 with sparse projections at density 1/30.
        // We keep K and L but default to DENSE hyperplanes: the
        // `variance-ablation` experiment shows very sparse ±1 projections
        // have per-point collision rates that are not a function of cosine
        // similarity, so Algorithm 1's probability (and hence Thm 1's
        // weights) is mis-calibrated by orders of magnitude and the
        // estimator variance explodes (ratios up to ~10^4 vs SGD; dense is
        // 0.3–0.7). Sparse remains available (`hasher = "sparse"`) with an
        // empirically calibrated collision curve for the paper's cost
        // ablations — see DESIGN.md §Deviations.
        //
        // weight_clip: linear SimHash on [x, y] is monotone in the *signed*
        // residual, so large-negative-residual points pair huge gradients
        // with vanishing collision probability — the exact-Thm-1 weights
        // 1/(pN) then have unbounded variance (the |·| subtlety §2.1 fixes
        // with the quadratic map T; our mirrored storage addresses the
        // same). A cap of 5 cuts the residual heavy tail of the weights
        // (ablate with `weight_clip = 0` for the exact unbiased regime).
        LshConfig {
            k: 5,
            l: 100,
            hasher: HasherKind::Dense,
            density: 1.0 / 30.0,
            center: false,
            mirror: true,
            weight_clip: Some(5.0),
            seed: 0x15A11,
            shards: 1,
            rebalance_threshold: 0.0,
            sealed: true,
            async_workers: 0,
            queue_depth: 1024,
            kernel: KernelMode::Auto,
        }
    }
}

/// Training block of a run config.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Estimator under test.
    pub estimator: EstimatorKind,
    /// Update rule.
    pub optimizer: OptimizerKind,
    /// Learning-rate schedule.
    pub schedule: Schedule,
    /// Epochs to run (an epoch = N iterations at batch 1).
    pub epochs: usize,
    /// Minibatch size (1 = the paper's plain setting).
    pub batch: usize,
    /// Evaluate train/test loss every this many iterations (0 = per epoch).
    pub eval_every: usize,
    /// RNG seed for sampling.
    pub seed: u64,
    /// Gradient execution backend.
    pub backend: Backend,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            estimator: EstimatorKind::Lgd,
            optimizer: OptimizerKind::Sgd,
            schedule: Schedule::Const(1e-2),
            epochs: 5,
            batch: 1,
            eval_every: 0,
            seed: 7,
            backend: Backend::Native,
        }
    }
}

/// Dataset block of a run config.
#[derive(Debug, Clone)]
pub struct DataConfig {
    /// Synthetic spec name (`yearmsd-like`, `slice-like`, `ujiindoor-like`,
    /// `pareto`, `uniform`) or a CSV path when `csv = true`.
    pub name: String,
    /// Scale factor on the paper's N for synthetic specs.
    pub scale: f64,
    /// Train fraction of the split.
    pub train_frac: f64,
    /// Generator / split seed.
    pub seed: u64,
    /// Load from CSV instead of generating.
    pub csv: Option<PathBuf>,
    /// Accept non-finite (`nan`/`inf`) feature/target cells when loading
    /// CSV data. Off by default: one NaN row silently poisons row norms,
    /// hash codes and every gradient downstream, so the loader rejects it
    /// with a line-numbered error unless this escape hatch is set.
    pub allow_nonfinite: bool,
    /// Example ids to evict from the LGD engine before training — the
    /// operator-facing twin of the health supervisor's automatic
    /// quarantine (comma-separated in TOML/CLI: `quarantine = "3,17"`).
    /// Evicted rows can never be drawn. LGD estimator only.
    pub quarantine: Vec<usize>,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            name: "yearmsd-like".into(),
            scale: 0.02,
            train_frac: 0.9,
            seed: 99,
            csv: None,
            allow_nonfinite: false,
            quarantine: Vec::new(),
        }
    }
}

/// Snapshot-store block of a run config (`store::snapshot` persistence).
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Snapshot path. When set, the trainer saves the full engine state
    /// here at the end of the run (and at the autosave cadence below);
    /// `lgd train --resume` warm-starts from it, skipping the table build
    /// entirely.
    pub path: Option<PathBuf>,
    /// Save every this many completed epochs (0 = only the final save).
    /// Epoch boundaries are the only legal save points: draw sessions hold
    /// the estimator borrow, so the shard-set generation counter cannot
    /// move mid-save — the same invariant that makes mutation a
    /// session-boundary event for the async engine.
    pub autosave_epochs: usize,
    /// Warm-start from `path` instead of building tables (CLI `--resume`).
    pub resume: bool,
    /// Rotated snapshot generations kept on disk (1..=64). Autosaves shift
    /// `path` → `path.1` → … → `path.{keep-1}` before writing, so a crash
    /// mid-save (or a corrupt newest file) still leaves the previous
    /// generation for `--resume`'s newest-valid-wins recovery scan.
    pub keep: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { path: None, autosave_epochs: 0, resume: false, keep: 2 }
    }
}

impl StoreConfig {
    /// True when any persistence behavior is requested.
    pub fn is_active(&self) -> bool {
        self.path.is_some() || self.resume
    }
}

/// Training-health block of a run config (`coordinator::health` — the
/// NaN/divergence sentinels, poisoned-input quarantine and
/// rollback-to-last-good supervisor). Disabled by default; when enabled
/// but never tripped the training stream is bit-for-bit identical to a
/// run without it (the sentinels only *read* the batch gradient, θ and
/// the loss — they never touch an RNG).
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// Arm the sentinels (CLI `--health on`).
    pub enabled: bool,
    /// Trailing window (in θ-norm observations / loss evals) the
    /// divergence detectors baseline against (2..=1024).
    pub window: usize,
    /// Loss-spike trip: train loss > `spike_factor ×` the windowed
    /// minimum for `patience` consecutive evals (> 1).
    pub spike_factor: f64,
    /// Consecutive spiking evals tolerated before tripping (>= 1).
    pub patience: u32,
    /// θ-explosion trip: ‖θ‖ > `theta_factor ×` the windowed baseline
    /// norm (floored at 1.0 so a near-zero start cannot trip it) (> 1).
    pub theta_factor: f64,
    /// Learning-rate multiplier applied after each rollback ((0,1]; 1.0
    /// is bitwise a no-op — used by the determinism gates).
    pub rollback_lr_factor: f64,
    /// Rollbacks allowed before the run aborts with a clean
    /// `Error::Health` (0..=64; 0 = detect-and-abort, never roll back).
    pub max_rollbacks: u32,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            enabled: false,
            window: 16,
            spike_factor: 10.0,
            patience: 2,
            theta_factor: 1e4,
            rollback_lr_factor: 0.5,
            max_rollbacks: 3,
        }
    }
}

/// Concurrent-serving block of a run config (`runtime::serving` — the
/// epoch-based shared-read engine behind `lgd serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Concurrent client sessions the harness drives (each gets its own
    /// forked RNG stream, query-code buffers and draw queue; all share one
    /// immutable published generation).
    pub clients: usize,
    /// Draws per request batch.
    pub batch: usize,
    /// Request batches each client issues.
    pub requests: usize,
    /// TCP listen address (`host:port`) for the length-prefixed wire front.
    /// Empty = in-process harness only (the default; nothing listens).
    pub addr: String,
    /// Connection-slot bound for the supervised TCP front (1..=4096). The
    /// `max_clients + 1`-th concurrent connection gets a best-effort error
    /// frame and is dropped, counted in `rejected_at_capacity`.
    pub max_clients: usize,
    /// Milliseconds a connection may sit idle between requests before the
    /// server closes it (1..=3_600_000).
    pub idle_timeout_ms: u64,
    /// Milliseconds allowed for a single mid-frame read or write before the
    /// connection is counted as errored and dropped (1..=3_600_000).
    pub io_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            clients: 4,
            batch: 32,
            requests: 200,
            addr: String::new(),
            max_clients: 64,
            idle_timeout_ms: 30_000,
            io_timeout_ms: 5_000,
        }
    }
}

/// Telemetry block of a run config (`core::telemetry` — registry, spans,
/// sampling-quality probes).
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Master switch: arm the sampling-quality probes and per-epoch
    /// registry snapshots. Telemetry is passive — armed or not, a seeded
    /// run is bitwise identical (enforced by the determinism gates) — so
    /// it defaults on.
    pub enabled: bool,
    /// Append JSONL span events to a rotating trace file (see
    /// `docs/observability.md`). Off by default: tracing writes to disk.
    pub trace: bool,
    /// Trace file path (rotates to `<path>.1` past `trace_max_bytes`).
    pub trace_path: PathBuf,
    /// Rotation threshold for the trace file, in bytes (>= 4096).
    pub trace_max_bytes: u64,
    /// Sliding-window size (draws) for the TV-distance sketch (16..=2^20).
    pub probe_window: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            enabled: true,
            trace: false,
            trace_path: PathBuf::from("lgd-trace.jsonl"),
            trace_max_bytes: 16 << 20,
            probe_window: 4096,
        }
    }
}

/// Parse a comma-separated example-id list (`"3,17"`) — the TOML/CLI
/// surface for [`DataConfig::quarantine`] (the hand-rolled TOML layer has
/// no arrays). Empty string = empty list; blank segments are ignored so
/// trailing commas are harmless.
pub fn parse_quarantine(s: &str) -> Result<Vec<usize>> {
    let mut ids = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let id = tok.parse::<usize>().map_err(|_| {
            Error::Config(format!("data.quarantine: '{tok}' is not an example id"))
        })?;
        ids.push(id);
    }
    Ok(ids)
}

/// A full run configuration.
#[derive(Debug, Clone, Default)]
pub struct RunConfig {
    /// Run label (CSV file prefixes).
    pub name: String,
    /// Dataset.
    pub data: DataConfig,
    /// LSH family/tables.
    pub lsh: LshConfig,
    /// Training loop.
    pub train: TrainConfig,
    /// Snapshot persistence.
    pub store: StoreConfig,
    /// Training-health supervisor.
    pub health: HealthConfig,
    /// Concurrent serving (`lgd serve`).
    pub serve: ServeConfig,
    /// Observability (`core::telemetry`).
    pub telemetry: TelemetryConfig,
    /// Output directory for result CSVs.
    pub out_dir: PathBuf,
}

impl RunConfig {
    /// Parse from a TOML document, applying defaults for missing keys.
    pub fn from_toml(doc: &TomlDoc) -> Result<RunConfig> {
        let mut cfg = RunConfig {
            name: doc.str_or("", "name", "run")?,
            out_dir: PathBuf::from(doc.str_or("", "out_dir", "results")?),
            ..Default::default()
        };

        // [data]
        cfg.data.name = doc.str_or("data", "name", &cfg.data.name)?;
        cfg.data.scale = doc.float_or("data", "scale", cfg.data.scale)?;
        cfg.data.train_frac = doc.float_or("data", "train_frac", cfg.data.train_frac)?;
        cfg.data.seed = doc.int_or("data", "seed", cfg.data.seed as i64)? as u64;
        let csv = doc.str_or("data", "csv", "")?;
        if !csv.is_empty() {
            cfg.data.csv = Some(PathBuf::from(csv));
        }
        cfg.data.allow_nonfinite =
            doc.bool_or("data", "allow_nonfinite", cfg.data.allow_nonfinite)?;
        let quarantine = doc.str_or("data", "quarantine", "")?;
        cfg.data.quarantine = parse_quarantine(&quarantine)?;

        // [lsh]
        cfg.lsh.k = doc.int_or("lsh", "k", cfg.lsh.k as i64)? as usize;
        cfg.lsh.l = doc.int_or("lsh", "l", cfg.lsh.l as i64)? as usize;
        cfg.lsh.density = doc.float_or("lsh", "density", cfg.lsh.density)?;
        cfg.lsh.center = doc.bool_or("lsh", "center", cfg.lsh.center)?;
        cfg.lsh.mirror = doc.bool_or("lsh", "mirror", cfg.lsh.mirror)?;
        cfg.lsh.seed = doc.int_or("lsh", "seed", cfg.lsh.seed as i64)? as u64;
        cfg.lsh.shards = doc.int_or("lsh", "shards", cfg.lsh.shards as i64)? as usize;
        cfg.lsh.rebalance_threshold =
            doc.float_or("lsh", "rebalance_threshold", cfg.lsh.rebalance_threshold)?;
        cfg.lsh.sealed = doc.bool_or("lsh", "sealed", cfg.lsh.sealed)?;
        cfg.lsh.async_workers =
            doc.int_or("lsh", "async_workers", cfg.lsh.async_workers as i64)? as usize;
        cfg.lsh.queue_depth =
            doc.int_or("lsh", "queue_depth", cfg.lsh.queue_depth as i64)? as usize;
        let kernel = doc.str_or("lsh", "kernel", cfg.lsh.kernel.name())?;
        cfg.lsh.kernel = KernelMode::from_name(&kernel)
            .ok_or_else(|| Error::Config(format!("unknown kernel '{kernel}' (auto|scalar)")))?;
        cfg.lsh.hasher = match doc.str_or("lsh", "hasher", "dense")?.as_str() {
            "dense" => HasherKind::Dense,
            "sparse" => HasherKind::Sparse,
            "quadratic" => HasherKind::Quadratic,
            other => return Err(Error::Config(format!("unknown hasher '{other}'"))),
        };
        let clip = doc.float_or(
            "lsh",
            "weight_clip",
            cfg.lsh.weight_clip.unwrap_or(0.0),
        )?;
        cfg.lsh.weight_clip = if clip > 0.0 { Some(clip) } else { None };

        // [train]
        cfg.train.estimator = match doc.str_or("train", "estimator", "lgd")?.as_str() {
            "sgd" => EstimatorKind::Sgd,
            "lgd" => EstimatorKind::Lgd,
            other => return Err(Error::Config(format!("unknown estimator '{other}'"))),
        };
        cfg.train.optimizer = match doc.str_or("train", "optimizer", "sgd")?.as_str() {
            "sgd" => OptimizerKind::Sgd,
            "adagrad" => OptimizerKind::AdaGrad,
            "adam" => OptimizerKind::Adam,
            other => return Err(Error::Config(format!("unknown optimizer '{other}'"))),
        };
        let lr = doc.float_or("train", "lr", 1e-2)?;
        cfg.train.schedule = match doc.str_or("train", "schedule", "const")?.as_str() {
            "const" => Schedule::Const(lr),
            "step" => Schedule::Step {
                base: lr,
                drop: doc.float_or("train", "drop", 0.5)?,
                every: doc.int_or("train", "every", 1000)? as u64,
            },
            "exp" => Schedule::Exp { base: lr, rate: doc.float_or("train", "rate", 1e-4)? },
            "invtime" => {
                Schedule::InvTime { base: lr, rate: doc.float_or("train", "rate", 1e-4)? }
            }
            other => return Err(Error::Config(format!("unknown schedule '{other}'"))),
        };
        cfg.train.epochs = doc.int_or("train", "epochs", cfg.train.epochs as i64)? as usize;
        cfg.train.batch = doc.int_or("train", "batch", cfg.train.batch as i64)? as usize;
        cfg.train.eval_every =
            doc.int_or("train", "eval_every", cfg.train.eval_every as i64)? as usize;
        cfg.train.seed = doc.int_or("train", "seed", cfg.train.seed as i64)? as u64;
        cfg.train.backend = match doc.str_or("train", "backend", "native")?.as_str() {
            "native" => Backend::Native,
            "pjrt" => Backend::Pjrt,
            other => return Err(Error::Config(format!("unknown backend '{other}'"))),
        };

        // [store]
        let store_path = doc.str_or("store", "path", "")?;
        if !store_path.is_empty() {
            cfg.store.path = Some(PathBuf::from(store_path));
        }
        cfg.store.autosave_epochs =
            doc.int_or("store", "autosave_epochs", cfg.store.autosave_epochs as i64)? as usize;
        cfg.store.keep = doc.int_or("store", "keep", cfg.store.keep as i64)? as usize;

        // [health]
        cfg.health.enabled = doc.bool_or("health", "enabled", cfg.health.enabled)?;
        cfg.health.window =
            doc.int_or("health", "window", cfg.health.window as i64)? as usize;
        cfg.health.spike_factor =
            doc.float_or("health", "spike_factor", cfg.health.spike_factor)?;
        cfg.health.patience =
            doc.int_or("health", "patience", cfg.health.patience as i64)? as u32;
        cfg.health.theta_factor =
            doc.float_or("health", "theta_factor", cfg.health.theta_factor)?;
        cfg.health.rollback_lr_factor =
            doc.float_or("health", "rollback_lr_factor", cfg.health.rollback_lr_factor)?;
        cfg.health.max_rollbacks =
            doc.int_or("health", "max_rollbacks", cfg.health.max_rollbacks as i64)? as u32;

        // [serve]
        cfg.serve.clients = doc.int_or("serve", "clients", cfg.serve.clients as i64)? as usize;
        cfg.serve.batch = doc.int_or("serve", "batch", cfg.serve.batch as i64)? as usize;
        cfg.serve.requests = doc.int_or("serve", "requests", cfg.serve.requests as i64)? as usize;
        cfg.serve.addr = doc.str_or("serve", "addr", &cfg.serve.addr)?;
        cfg.serve.max_clients =
            doc.int_or("serve", "max_clients", cfg.serve.max_clients as i64)? as usize;
        cfg.serve.idle_timeout_ms =
            doc.int_or("serve", "idle_timeout_ms", cfg.serve.idle_timeout_ms as i64)? as u64;
        cfg.serve.io_timeout_ms =
            doc.int_or("serve", "io_timeout_ms", cfg.serve.io_timeout_ms as i64)? as u64;

        // [telemetry]
        cfg.telemetry.enabled =
            doc.bool_or("telemetry", "enabled", cfg.telemetry.enabled)?;
        cfg.telemetry.trace = doc.bool_or("telemetry", "trace", cfg.telemetry.trace)?;
        let trace_path = doc.str_or("telemetry", "trace_path", "")?;
        if !trace_path.is_empty() {
            cfg.telemetry.trace_path = PathBuf::from(trace_path);
        }
        cfg.telemetry.trace_max_bytes = doc
            .int_or("telemetry", "trace_max_bytes", cfg.telemetry.trace_max_bytes as i64)?
            as u64;
        cfg.telemetry.probe_window =
            doc.int_or("telemetry", "probe_window", cfg.telemetry.probe_window as i64)?
                as usize;

        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check ranges.
    pub fn validate(&self) -> Result<()> {
        if self.lsh.k == 0 || self.lsh.k > 32 {
            return Err(Error::Config(format!("lsh.k = {} out of 1..=32", self.lsh.k)));
        }
        if self.lsh.l == 0 {
            return Err(Error::Config("lsh.l must be positive".into()));
        }
        if !(self.lsh.density > 0.0 && self.lsh.density <= 1.0) {
            return Err(Error::Config(format!("lsh.density = {} out of (0,1]", self.lsh.density)));
        }
        if self.lsh.shards == 0 || self.lsh.shards > 4096 {
            return Err(Error::Config(format!(
                "lsh.shards = {} out of 1..=4096",
                self.lsh.shards
            )));
        }
        let rt = self.lsh.rebalance_threshold;
        if rt != 0.0 && !(rt.is_finite() && rt >= 1.0) {
            return Err(Error::Config(format!(
                "lsh.rebalance_threshold = {rt} must be 0 (off) or >= 1.0"
            )));
        }
        if rt != 0.0 && self.lsh.shards == 1 {
            return Err(Error::Config(
                "lsh.rebalance_threshold requires lsh.shards > 1 (nothing to \
                 rebalance with a single shard)"
                    .into(),
            ));
        }
        if self.lsh.async_workers > 1024 {
            return Err(Error::Config(format!(
                "lsh.async_workers = {} out of 0..=1024",
                self.lsh.async_workers
            )));
        }
        if self.lsh.queue_depth == 0 || self.lsh.queue_depth > (1 << 20) {
            return Err(Error::Config(format!(
                "lsh.queue_depth = {} out of 1..=2^20",
                self.lsh.queue_depth
            )));
        }
        if self.train.epochs == 0 || self.train.batch == 0 {
            return Err(Error::Config("train.epochs and train.batch must be positive".into()));
        }
        if !(self.data.train_frac > 0.0 && self.data.train_frac < 1.0) {
            return Err(Error::Config(format!(
                "data.train_frac = {} out of (0,1)",
                self.data.train_frac
            )));
        }
        if self.data.scale <= 0.0 {
            return Err(Error::Config("data.scale must be positive".into()));
        }
        if self.train.schedule.base() <= 0.0 {
            return Err(Error::Config("learning rate must be positive".into()));
        }
        if self.store.autosave_epochs > 0 && self.store.path.is_none() {
            return Err(Error::Config(
                "store.autosave_epochs requires store.path (nowhere to save)".into(),
            ));
        }
        if self.store.resume && self.store.path.is_none() {
            return Err(Error::Config("--resume requires a snapshot path (store.path)".into()));
        }
        if self.store.is_active() && self.train.estimator != EstimatorKind::Lgd {
            return Err(Error::Config(
                "the snapshot store persists the LGD engine; it requires \
                 train.estimator = \"lgd\""
                    .into(),
            ));
        }
        if self.health.window < 2 || self.health.window > 1024 {
            return Err(Error::Config(format!(
                "health.window = {} out of 2..=1024",
                self.health.window
            )));
        }
        if !(self.health.spike_factor.is_finite() && self.health.spike_factor > 1.0) {
            return Err(Error::Config(format!(
                "health.spike_factor = {} must be finite and > 1",
                self.health.spike_factor
            )));
        }
        if self.health.patience == 0 {
            return Err(Error::Config("health.patience must be >= 1".into()));
        }
        if !(self.health.theta_factor.is_finite() && self.health.theta_factor > 1.0) {
            return Err(Error::Config(format!(
                "health.theta_factor = {} must be finite and > 1",
                self.health.theta_factor
            )));
        }
        let f = self.health.rollback_lr_factor;
        if !(f.is_finite() && f > 0.0 && f <= 1.0) {
            return Err(Error::Config(format!(
                "health.rollback_lr_factor = {f} out of (0,1]"
            )));
        }
        if self.health.max_rollbacks > 64 {
            return Err(Error::Config(format!(
                "health.max_rollbacks = {} out of 0..=64",
                self.health.max_rollbacks
            )));
        }
        if !self.data.quarantine.is_empty() && self.train.estimator != EstimatorKind::Lgd {
            return Err(Error::Config(
                "data.quarantine evicts rows from the LGD engine; it requires \
                 train.estimator = \"lgd\""
                    .into(),
            ));
        }
        if self.serve.clients == 0 || self.serve.clients > 1024 {
            return Err(Error::Config(format!(
                "serve.clients = {} out of 1..=1024",
                self.serve.clients
            )));
        }
        if self.serve.batch == 0 || self.serve.batch > (1 << 16) {
            return Err(Error::Config(format!(
                "serve.batch = {} out of 1..=2^16",
                self.serve.batch
            )));
        }
        if self.serve.requests == 0 {
            return Err(Error::Config("serve.requests must be positive".into()));
        }
        if self.store.keep == 0 || self.store.keep > 64 {
            return Err(Error::Config(format!(
                "store.keep = {} out of 1..=64",
                self.store.keep
            )));
        }
        if self.serve.max_clients == 0 || self.serve.max_clients > 4096 {
            return Err(Error::Config(format!(
                "serve.max_clients = {} out of 1..=4096",
                self.serve.max_clients
            )));
        }
        for (name, ms) in [
            ("serve.idle_timeout_ms", self.serve.idle_timeout_ms),
            ("serve.io_timeout_ms", self.serve.io_timeout_ms),
        ] {
            if ms == 0 || ms > 3_600_000 {
                return Err(Error::Config(format!("{name} = {ms} out of 1..=3_600_000")));
            }
        }
        if !self.serve.addr.is_empty() && !self.serve.addr.contains(':') {
            return Err(Error::Config(format!(
                "serve.addr = '{}' is not a host:port listen address",
                self.serve.addr
            )));
        }
        if self.telemetry.trace && !self.telemetry.enabled {
            return Err(Error::Config(
                "telemetry.trace requires telemetry.enabled = true".into(),
            ));
        }
        if self.telemetry.trace_max_bytes < 4096 {
            return Err(Error::Config(format!(
                "telemetry.trace_max_bytes = {} must be >= 4096",
                self.telemetry.trace_max_bytes
            )));
        }
        let pw = self.telemetry.probe_window;
        if pw < 16 || pw > (1 << 20) {
            return Err(Error::Config(format!(
                "telemetry.probe_window = {pw} out of 16..=2^20"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let cfg = RunConfig::from_toml(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.lsh.k, 5);
        assert_eq!(cfg.lsh.l, 100);
        assert_eq!(cfg.lsh.hasher, HasherKind::Dense, "dense default — see variance-ablation");
        assert!((cfg.lsh.density - 1.0 / 30.0).abs() < 1e-12);
        assert_eq!(cfg.lsh.weight_clip, Some(5.0));
        assert!(cfg.lsh.mirror);
        assert_eq!(cfg.lsh.shards, 1, "sharding is opt-in");
        assert_eq!(cfg.lsh.rebalance_threshold, 0.0, "rebalancing is opt-in");
        assert!(cfg.lsh.sealed, "the CSR arena serves draws by default");
        assert_eq!(cfg.lsh.async_workers, 0, "async serving is opt-in");
        assert_eq!(cfg.lsh.queue_depth, 1024);
        assert_eq!(cfg.lsh.kernel, KernelMode::Auto, "SIMD dispatch is the default");
        assert_eq!(cfg.train.estimator, EstimatorKind::Lgd);
        assert_eq!(cfg.train.backend, Backend::Native);
        assert!(cfg.store.path.is_none(), "persistence is opt-in");
        assert_eq!(cfg.store.autosave_epochs, 0);
        assert!(!cfg.store.resume);
        assert!(!cfg.store.is_active());
        assert_eq!(cfg.store.keep, 2, "one rotated fallback generation by default");
        assert_eq!(cfg.serve.clients, 4);
        assert_eq!(cfg.serve.batch, 32);
        assert_eq!(cfg.serve.requests, 200);
        assert!(cfg.serve.addr.is_empty(), "no TCP front unless asked");
        assert_eq!(cfg.serve.max_clients, 64);
        assert_eq!(cfg.serve.idle_timeout_ms, 30_000);
        assert_eq!(cfg.serve.io_timeout_ms, 5_000);
        assert!(!cfg.data.allow_nonfinite, "CSV non-finite cells rejected by default");
        assert!(cfg.data.quarantine.is_empty());
        assert!(!cfg.health.enabled, "the health supervisor is opt-in");
        assert_eq!(cfg.health.window, 16);
        assert_eq!(cfg.health.spike_factor, 10.0);
        assert_eq!(cfg.health.patience, 2);
        assert_eq!(cfg.health.theta_factor, 1e4);
        assert_eq!(cfg.health.rollback_lr_factor, 0.5);
        assert_eq!(cfg.health.max_rollbacks, 3);
        assert!(cfg.telemetry.enabled, "passive telemetry defaults on");
        assert!(!cfg.telemetry.trace, "trace files are opt-in");
        assert_eq!(cfg.telemetry.trace_path, PathBuf::from("lgd-trace.jsonl"));
        assert_eq!(cfg.telemetry.trace_max_bytes, 16 << 20);
        assert_eq!(cfg.telemetry.probe_window, 4096);
    }

    #[test]
    fn telemetry_block_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[telemetry]\nenabled = true\ntrace = true\ntrace_path = \"t.jsonl\"\n\
             trace_max_bytes = 8192\nprobe_window = 128\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert!(cfg.telemetry.trace);
        assert_eq!(cfg.telemetry.trace_path, PathBuf::from("t.jsonl"));
        assert_eq!(cfg.telemetry.trace_max_bytes, 8192);
        assert_eq!(cfg.telemetry.probe_window, 128);
        // trace without the master switch is a config error, not a no-op.
        let doc = TomlDoc::parse("[telemetry]\nenabled = false\ntrace = true\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[telemetry]\nprobe_window = 2\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[telemetry]\ntrace_max_bytes = 16\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn health_block_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[health]\nenabled = true\nwindow = 8\nspike_factor = 4.0\npatience = 1\n\
             theta_factor = 100.0\nrollback_lr_factor = 1.0\nmax_rollbacks = 2\n\
             [data]\nquarantine = \"3, 17,\"\nallow_nonfinite = true\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert!(cfg.health.enabled);
        assert_eq!(cfg.health.window, 8);
        assert_eq!(cfg.health.spike_factor, 4.0);
        assert_eq!(cfg.health.patience, 1);
        assert_eq!(cfg.health.theta_factor, 100.0);
        assert_eq!(cfg.health.rollback_lr_factor, 1.0);
        assert_eq!(cfg.health.max_rollbacks, 2);
        assert_eq!(cfg.data.quarantine, vec![3, 17]);
        assert!(cfg.data.allow_nonfinite);
        assert_eq!(parse_quarantine("").unwrap(), Vec::<usize>::new());
        assert!(parse_quarantine("3,x").is_err());
        // quarantine only makes sense for the LGD engine
        let doc = TomlDoc::parse(
            "[data]\nquarantine = \"1\"\n[train]\nestimator = \"sgd\"\n",
        )
        .unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn serve_block_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[serve]\nclients = 8\nbatch = 64\nrequests = 50\naddr = \"127.0.0.1:7979\"\n\
             max_clients = 16\nidle_timeout_ms = 1000\nio_timeout_ms = 250\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.serve.clients, 8);
        assert_eq!(cfg.serve.batch, 64);
        assert_eq!(cfg.serve.requests, 50);
        assert_eq!(cfg.serve.addr, "127.0.0.1:7979");
        assert_eq!(cfg.serve.max_clients, 16);
        assert_eq!(cfg.serve.idle_timeout_ms, 1000);
        assert_eq!(cfg.serve.io_timeout_ms, 250);
        for bad in [
            "[serve]\nclients = 0",
            "[serve]\nclients = 2000",
            "[serve]\nbatch = 0",
            "[serve]\nrequests = 0",
            "[serve]\naddr = \"nocolon\"",
            "[serve]\nmax_clients = 0",
            "[serve]\nmax_clients = 5000",
            "[serve]\nidle_timeout_ms = 0",
            "[serve]\nio_timeout_ms = 4000000",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(RunConfig::from_toml(&doc).is_err(), "accepted bad config: {bad}");
        }
    }

    #[test]
    fn store_block_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[store]\npath = \"idx/run.lgdsnap\"\nautosave_epochs = 2\nkeep = 3\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.store.path.as_deref(), Some(std::path::Path::new("idx/run.lgdsnap")));
        assert_eq!(cfg.store.autosave_epochs, 2);
        assert_eq!(cfg.store.keep, 3);
        assert!(cfg.store.is_active());
        // autosave without a path is rejected
        let doc = TomlDoc::parse("[store]\nautosave_epochs = 2\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // rotation depth is bounded
        for bad in ["[store]\nkeep = 0", "[store]\nkeep = 100"] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(RunConfig::from_toml(&doc).is_err(), "accepted bad config: {bad}");
        }
        // the store persists the LGD engine only
        let doc = TomlDoc::parse(
            "[store]\npath = \"x.lgdsnap\"\n[train]\nestimator = \"sgd\"\n",
        )
        .unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // resume needs a path
        let mut cfg = RunConfig::default();
        cfg.store.resume = true;
        assert!(cfg.validate().is_err());
        cfg.store.path = Some(PathBuf::from("x.lgdsnap"));
        cfg.validate().unwrap();
    }

    #[test]
    fn full_parse() {
        let doc = TomlDoc::parse(
            r#"
name = "fig12"
out_dir = "results/fig12"
[data]
name = "slice-like"
scale = 0.05
[lsh]
k = 7
l = 10
hasher = "dense"
weight_clip = 8.0
shards = 4
rebalance_threshold = 1.5
sealed = false
async_workers = 4
queue_depth = 256
kernel = "scalar"
[train]
estimator = "sgd"
optimizer = "adagrad"
lr = 0.05
schedule = "exp"
rate = 0.001
epochs = 3
batch = 32
backend = "pjrt"
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.name, "fig12");
        assert_eq!(cfg.data.name, "slice-like");
        assert_eq!(cfg.lsh.k, 7);
        assert_eq!(cfg.lsh.hasher, HasherKind::Dense);
        assert_eq!(cfg.lsh.weight_clip, Some(8.0));
        assert_eq!(cfg.lsh.shards, 4);
        assert_eq!(cfg.lsh.rebalance_threshold, 1.5);
        assert!(!cfg.lsh.sealed);
        assert_eq!(cfg.lsh.async_workers, 4);
        assert_eq!(cfg.lsh.queue_depth, 256);
        assert_eq!(cfg.lsh.kernel, KernelMode::Scalar);
        assert_eq!(cfg.train.estimator, EstimatorKind::Sgd);
        assert_eq!(cfg.train.optimizer, OptimizerKind::AdaGrad);
        assert!(matches!(cfg.train.schedule, Schedule::Exp { .. }));
        assert_eq!(cfg.train.batch, 32);
        assert_eq!(cfg.train.backend, Backend::Pjrt);
    }

    #[test]
    fn invalid_values_rejected() {
        for bad in [
            "[lsh]\nk = 0",
            "[lsh]\nk = 40",
            "[lsh]\ndensity = 1.5",
            "[lsh]\nshards = 0",
            "[lsh]\nshards = 4\nrebalance_threshold = 0.5",
            "[lsh]\nshards = 4\nrebalance_threshold = -1.0",
            "[lsh]\nrebalance_threshold = 1.5",
            "[lsh]\nqueue_depth = 0",
            "[lsh]\nasync_workers = 2000",
            "[lsh]\nkernel = \"avx512\"",
            "[train]\nepochs = 0",
            "[train]\nestimator = \"bogus\"",
            "[train]\nlr = -0.1",
            "[data]\ntrain_frac = 1.0",
            "[data]\nquarantine = \"1,abc\"",
            "[health]\nwindow = 1",
            "[health]\nwindow = 2048",
            "[health]\nspike_factor = 1.0",
            "[health]\npatience = 0",
            "[health]\ntheta_factor = 0.5",
            "[health]\nrollback_lr_factor = 0.0",
            "[health]\nrollback_lr_factor = 1.5",
            "[health]\nmax_rollbacks = 100",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(RunConfig::from_toml(&doc).is_err(), "accepted bad config: {bad}");
        }
    }
}
