//! Configuration substrate: JSON (artifact manifest), TOML-subset
//! (experiment configs) and the typed run specification.

pub mod json;
pub mod spec;
pub mod toml;

pub use json::Json;
pub use spec::{
    Backend, DataConfig, EstimatorKind, HasherKind, LshConfig, OptimizerKind, RunConfig,
    ServeConfig, TelemetryConfig, TrainConfig,
};
pub use toml::{TomlDoc, TomlValue};
