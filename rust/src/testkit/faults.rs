//! Zero-dependency failpoint registry — deterministic fault injection for
//! the robustness suite.
//!
//! A **failpoint** is a named site in production code where a test can
//! schedule a failure: an injected `Err`, a panic, or an early `None`,
//! depending on what a *real* failure looks like at that site (the site
//! decides the failure shape; the registry only answers "fail now?").
//! This generalizes the draw engine's original one-off `#[cfg(test)]`
//! kill hook into one catalog covering the snapshot writer, the draw
//! queues, the sampler workers, generation flips and the TCP wire.
//!
//! **Gating.** The registry is compiled under
//! `cfg(any(test, feature = "failpoints"))`: unit tests get it for free,
//! integration/chaos binaries opt in with `--features failpoints`, and
//! release builds compile every `should_fail` call to a constant `false`
//! with zero data. Even when compiled in, the disarmed fast path is one
//! relaxed atomic load — cheap enough to sit on the draw hot path, which
//! is what lets the determinism gates run bit-for-bit identical with
//! failpoints compiled in but disarmed.
//!
//! **Concurrency caveat.** The registry is process-global. Arming a real
//! site from a test that shares its process with unrelated concurrent
//! tests (the default `cargo test` threading) can fire the fault inside
//! *their* code. Real sites are therefore armed only from the dedicated
//! `tests/chaos.rs` binary, which serializes its tests; unit tests in
//! this module use private site names that no production code checks.
//!
//! ```ignore
//! faults::arm(faults::SNAPSHOT_WRITE, faults::Mode::Once);
//! assert!(snapshot::save(&path, &est, None).is_err()); // injected
//! assert_eq!(faults::fires(faults::SNAPSHOT_WRITE), 1);
//! faults::disarm_all();
//! ```

/// Snapshot writer, mid-write: the tmp file is left truncated (a crash
/// while streaming bytes). The target file is never touched.
pub const SNAPSHOT_WRITE: &str = "store.snapshot.write";
/// Snapshot writer, post-write: the tmp file is complete but the fsync
/// "fails" (a crash before durability). The target file is never touched.
pub const SNAPSHOT_FSYNC: &str = "store.snapshot.fsync";
/// Snapshot writer, pre-rename: the tmp file is durable but never renamed
/// into place (a crash between fsync and rename).
pub const SNAPSHOT_RENAME: &str = "store.snapshot.rename";
/// `DrawQueue::push` panics at entry — a producer (sampler/mixer) thread
/// dying mid-pipeline.
pub const QUEUE_PUSH: &str = "coordinator.queue.push";
/// `DrawQueue::pop` returns an early `None` — the consumer observes a
/// queue that looks closed/dead.
pub const QUEUE_POP: &str = "coordinator.queue.pop";
/// Sampler-worker start: the worker panics while holding its queue mutex
/// (genuinely poisoning it). The check passes the shard index as the
/// filter argument ([`arm_at`]); the serving-session producer passes 0.
pub const WORKER_START: &str = "runtime.worker.start";
/// `ServingCore::mutate` fails after taking the writer lock, before
/// cloning or publishing anything — a flip that never happens.
pub const GENERATION_FLIP: &str = "runtime.generation.flip";
/// Wire read (server `read_full` / client `read_frame`) fails at entry.
/// The filter argument is the side: [`SIDE_CLIENT`] or [`SIDE_SERVER`].
pub const TCP_READ: &str = "runtime.tcp.read";
/// Wire `write_frame` fails at entry (either side).
pub const TCP_WRITE: &str = "runtime.tcp.write";
/// Batch-gradient accumulation: the contribution of one drawn example is
/// poisoned to NaN — a persistently corrupt input row. The check passes the
/// example id as the filter argument, so `arm_at(GRAD_NAN, Always, id)`
/// models "row `id` is poison every time it is drawn", and the health
/// supervisor's per-example attribution (which re-checks the same site)
/// sees the same poison the accumulator saw.
pub const GRAD_NAN: &str = "coordinator.health.grad_nan";
/// Parameter vector, post-optimizer-step: θ[0] is poisoned to NaN — a
/// divergent/corrupted update the θ sentinel must catch.
pub const THETA_POISON: &str = "coordinator.health.theta_poison";
/// Loss evaluation: the train loss is corrupted to NaN — a broken eval the
/// loss sentinel must catch.
pub const LOSS_CORRUPT: &str = "coordinator.health.loss_corrupt";

/// Filter argument for [`TCP_READ`] checks on the client side.
pub const SIDE_CLIENT: u64 = 0;
/// Filter argument for [`TCP_READ`] checks on the server side.
pub const SIDE_SERVER: u64 = 1;

/// Every registered production site — the chaos suite iterates this to
/// prove each one actually fires.
pub const SITES: &[&str] = &[
    SNAPSHOT_WRITE,
    SNAPSHOT_FSYNC,
    SNAPSHOT_RENAME,
    QUEUE_PUSH,
    QUEUE_POP,
    WORKER_START,
    GENERATION_FLIP,
    TCP_READ,
    TCP_WRITE,
    GRAD_NAN,
    THETA_POISON,
    LOSS_CORRUPT,
];

#[cfg(any(test, feature = "failpoints"))]
mod imp {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Mutex, MutexGuard, PoisonError};

    /// When an armed site fires.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Mode {
        /// Never (the disarmed state; arming with `Off` is a no-op).
        Off,
        /// The next matching check fires, then the site disarms.
        Once,
        /// The next `k` matching checks fire, then the site disarms.
        Times(u64),
        /// The `n`-th matching check (1-based) fires, then the site
        /// disarms — earlier checks pass through untouched. This is how a
        /// fault lands *mid-stream* (e.g. the third queue push).
        Nth(u64),
        /// Every matching check fires until [`disarm`](super::disarm).
        Always,
    }

    struct Entry {
        site: &'static str,
        mode: Mode,
        when: Option<u64>,
        fires: u64,
    }

    /// Count of non-`Off` entries, mirrored outside the lock so the
    /// disarmed hot path is a single relaxed load.
    static ARMED: AtomicUsize = AtomicUsize::new(0);
    static REG: Mutex<Vec<Entry>> = Mutex::new(Vec::new());

    fn reg() -> MutexGuard<'static, Vec<Entry>> {
        // A test that panicked mid-check poisons nothing structurally —
        // the entries are plain data — so recover like the draw queues do.
        REG.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn sync_armed(entries: &[Entry]) {
        let n = entries.iter().filter(|e| e.mode != Mode::Off).count();
        ARMED.store(n, Ordering::Relaxed);
    }

    /// Arm `site` to fail per `mode`, firing on every check regardless of
    /// the check's filter argument. Re-arming replaces the previous mode
    /// (fire counts are kept).
    pub fn arm(site: &'static str, mode: Mode) {
        arm_entry(site, mode, None);
    }

    /// Arm `site` to fail per `mode`, but only for checks whose filter
    /// argument equals `when` (e.g. one specific shard's worker). Checks
    /// that pass no argument never match a filtered arm.
    pub fn arm_at(site: &'static str, mode: Mode, when: u64) {
        arm_entry(site, mode, Some(when));
    }

    fn arm_entry(site: &'static str, mode: Mode, when: Option<u64>) {
        let mode = match mode {
            Mode::Times(0) | Mode::Nth(0) => Mode::Off,
            m => m,
        };
        let mut entries = reg();
        match entries.iter_mut().find(|e| e.site == site) {
            Some(e) => {
                e.mode = mode;
                e.when = when;
            }
            None => entries.push(Entry { site, mode, when, fires: 0 }),
        }
        sync_armed(&entries);
    }

    /// Disarm `site` (its fire count is kept for inspection).
    pub fn disarm(site: &str) {
        let mut entries = reg();
        if let Some(e) = entries.iter_mut().find(|e| e.site == site) {
            e.mode = Mode::Off;
            e.when = None;
        }
        sync_armed(&entries);
    }

    /// Disarm everything and reset all fire counts — the clean-slate the
    /// chaos suite's drop guard restores between tests.
    pub fn disarm_all() {
        let mut entries = reg();
        entries.clear();
        sync_armed(&entries);
    }

    /// How many times `site` has fired since the last [`disarm_all`].
    pub fn fires(site: &str) -> u64 {
        reg().iter().find(|e| e.site == site).map_or(0, |e| e.fires)
    }

    fn check(site: &str, arg: Option<u64>) -> bool {
        if ARMED.load(Ordering::Relaxed) == 0 {
            return false;
        }
        let mut entries = reg();
        let Some(e) = entries.iter_mut().find(|e| e.site == site) else {
            return false;
        };
        match (e.when, arg) {
            (None, _) => {}
            (Some(w), Some(a)) if w == a => {}
            _ => return false,
        }
        let fire = match e.mode {
            Mode::Off => false,
            Mode::Once => {
                e.mode = Mode::Off;
                true
            }
            Mode::Times(k) => {
                e.mode = if k <= 1 { Mode::Off } else { Mode::Times(k - 1) };
                true
            }
            Mode::Nth(n) => {
                if n <= 1 {
                    e.mode = Mode::Off;
                    true
                } else {
                    e.mode = Mode::Nth(n - 1);
                    false
                }
            }
            Mode::Always => true,
        };
        if fire {
            e.fires += 1;
        }
        sync_armed(&entries);
        fire
    }

    /// Should this (argless) check of `site` fail? Sites armed with a
    /// filter ([`arm_at`]) never match an argless check.
    #[inline]
    pub fn should_fail(site: &str) -> bool {
        if ARMED.load(Ordering::Relaxed) == 0 {
            return false;
        }
        check(site, None)
    }

    /// Should this check of `site` (with filter argument `arg`) fail?
    #[inline]
    pub fn should_fail_at(site: &str, arg: u64) -> bool {
        if ARMED.load(Ordering::Relaxed) == 0 {
            return false;
        }
        check(site, Some(arg))
    }
}

/// Compiled-out stubs: release builds pay nothing and can never fire.
#[cfg(not(any(test, feature = "failpoints")))]
mod imp {
    /// Always false — the registry is compiled out.
    #[inline(always)]
    pub fn should_fail(_site: &str) -> bool {
        false
    }

    /// Always false — the registry is compiled out.
    #[inline(always)]
    pub fn should_fail_at(_site: &str, _arg: u64) -> bool {
        false
    }

    /// Always 0 — the registry is compiled out.
    #[inline(always)]
    pub fn fires(_site: &str) -> u64 {
        0
    }
}

pub use imp::*;

#[cfg(test)]
mod tests {
    use super::*;

    // Private site names no production code checks: these tests share the
    // process-global registry with every other concurrently running unit
    // test, so they must never arm a real site from SITES.
    const FAKE_A: &str = "testkit.faults.fake_a";
    const FAKE_B: &str = "testkit.faults.fake_b";

    #[test]
    fn disarmed_sites_never_fire() {
        assert!(!should_fail(FAKE_A));
        assert!(!should_fail_at(FAKE_A, 7));
        assert_eq!(fires(FAKE_A), 0);
    }

    #[test]
    fn once_fires_exactly_once_then_disarms() {
        arm(FAKE_A, Mode::Once);
        assert!(should_fail(FAKE_A));
        assert!(!should_fail(FAKE_A), "Once must self-disarm");
        assert_eq!(fires(FAKE_A), 1);
        disarm(FAKE_A);
    }

    #[test]
    fn times_and_nth_count_checks() {
        arm(FAKE_B, Mode::Times(2));
        assert!(should_fail(FAKE_B));
        assert!(should_fail(FAKE_B));
        assert!(!should_fail(FAKE_B));
        assert_eq!(fires(FAKE_B), 2);
        // Nth(3): two pass-throughs, then the third check fires
        arm(FAKE_B, Mode::Nth(3));
        assert!(!should_fail(FAKE_B));
        assert!(!should_fail(FAKE_B));
        assert!(should_fail(FAKE_B));
        assert!(!should_fail(FAKE_B), "Nth self-disarms after firing");
        assert_eq!(fires(FAKE_B), 3, "pass-through checks do not count as fires");
        disarm(FAKE_B);
    }

    #[test]
    fn filter_argument_scopes_the_fault() {
        arm_at(FAKE_A, Mode::Always, 3);
        assert!(!should_fail_at(FAKE_A, 2), "non-matching arg must pass");
        assert!(should_fail_at(FAKE_A, 3));
        assert!(should_fail_at(FAKE_A, 3), "Always keeps firing");
        assert!(!should_fail(FAKE_A), "argless checks never match a filtered arm");
        disarm(FAKE_A);
        assert!(!should_fail_at(FAKE_A, 3), "disarm stops it");
    }

    #[test]
    fn rearm_replaces_mode_and_zero_counts_are_off() {
        arm(FAKE_B, Mode::Times(0));
        assert!(!should_fail(FAKE_B), "Times(0) normalizes to Off");
        arm(FAKE_B, Mode::Nth(0));
        assert!(!should_fail(FAKE_B), "Nth(0) normalizes to Off");
        arm(FAKE_B, Mode::Once);
        arm(FAKE_B, Mode::Off);
        assert!(!should_fail(FAKE_B), "re-arming with Off disarms");
        disarm(FAKE_B);
    }
}
