//! Variance analysis of gradient estimators (§2.3, Theorem 2, Lemma 1).
//!
//! `Tr(Σ(Est)) = E‖Est‖² − ‖E Est‖²`: we measure the first term by Monte
//! Carlo over the estimator's randomness and compute the second exactly
//! from the full gradient. For uniform SGD the closed form (eq. 18)
//! `Tr = (1/N)Σ‖g_i‖² − ‖ḡ‖²` is also provided, and the Monte-Carlo
//! machinery is validated against it in tests.

use crate::core::matrix::norm2;
use crate::data::dataset::Dataset;
use crate::estimator::GradientEstimator;
use crate::model::Model;

/// Result of a variance measurement.
#[derive(Debug, Clone, Copy)]
pub struct VarianceReport {
    /// Monte-Carlo estimate of `E‖Est‖²`.
    pub second_moment: f64,
    /// `‖E Est‖²` (exact, from the full gradient).
    pub mean_norm_sq: f64,
    /// Trace of the covariance = second_moment − mean_norm_sq.
    pub trace_cov: f64,
    /// Number of Monte-Carlo trials.
    pub trials: usize,
}

/// Closed-form trace of covariance for uniform single-sample SGD (eq. 18).
pub fn sgd_trace_closed_form(model: &dyn Model, ds: &Dataset, theta: &[f32]) -> f64 {
    let n = ds.len() as f64;
    let mut sum_norm_sq = 0.0f64;
    let mut full = vec![0.0f32; theta.len()];
    model.full_grad(ds, theta, &mut full);
    for i in 0..ds.len() {
        let (x, y) = ds.example(i);
        let g = model.grad_norm(x, y, theta);
        sum_norm_sq += g * g;
    }
    sum_norm_sq / n - norm2(&full).powi(2)
}

/// Monte-Carlo trace of covariance of any estimator at fixed `theta`.
pub fn empirical_trace(
    est: &mut dyn GradientEstimator,
    model: &dyn Model,
    ds: &Dataset,
    theta: &[f32],
    trials: usize,
) -> VarianceReport {
    let d = theta.len();
    let mut full = vec![0.0f32; d];
    model.full_grad(ds, theta, &mut full);
    let mean_norm_sq = norm2(&full).powi(2);

    let mut g = vec![0.0f32; d];
    let mut second = 0.0f64;
    for _ in 0..trials {
        let w = est.draw(theta);
        let (x, y) = ds.example(w.index);
        model.grad(x, y, theta, &mut g);
        let est_norm = w.weight * norm2(&g);
        second += est_norm * est_norm;
    }
    let second_moment = second / trials as f64;
    VarianceReport {
        second_moment,
        mean_norm_sq,
        trace_cov: second_moment - mean_norm_sq,
        trials,
    }
}

/// Lemma 1 condition, evaluated empirically: returns
/// `(lhs, rhs)` where LGD beats SGD iff `lhs < rhs`:
/// `lhs = E‖Est_LGD‖²`, `rhs = (1/N)Σ‖g_i‖²` (both sides of eq. 8 after
/// adding the common `‖ḡ‖²` term).
pub fn lemma1_sides(
    lgd: &mut dyn GradientEstimator,
    model: &dyn Model,
    ds: &Dataset,
    theta: &[f32],
    trials: usize,
) -> (f64, f64) {
    let rep = empirical_trace(lgd, model, ds, theta, trials);
    let n = ds.len() as f64;
    let mut sum_norm_sq = 0.0;
    for i in 0..ds.len() {
        let (x, y) = ds.example(i);
        let g = model.grad_norm(x, y, theta);
        sum_norm_sq += g * g;
    }
    (rep.second_moment, sum_norm_sq / n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::estimator::{LgdEstimator, UniformEstimator};
    use crate::estimator::lgd::LgdOptions;
    use crate::lsh::srp::DenseSrp;
    use crate::model::LinReg;

    fn theta_after_warmup(pre: &crate::data::preprocess::Preprocessed, steps: usize) -> Vec<f32> {
        let model = LinReg;
        let d = pre.data.dim();
        let mut theta = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        let mut uni = UniformEstimator::new(pre.data.len(), 99);
        for _ in 0..steps {
            let w = uni.draw(&theta);
            let (x, y) = pre.data.example(w.index);
            model.grad(x, y, &theta, &mut g);
            crate::core::matrix::axpy(-0.05, &g, &mut theta);
        }
        theta
    }

    /// The Monte-Carlo machinery must reproduce the closed form for SGD.
    #[test]
    fn empirical_sgd_trace_matches_closed_form() {
        let ds = SynthSpec::power_law("t", 300, 8, 1).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let model = LinReg;
        let theta = theta_after_warmup(&pre, 100);
        let closed = sgd_trace_closed_form(&model, &pre.data, &theta);
        let mut uni = UniformEstimator::new(pre.data.len(), 3);
        let rep = empirical_trace(&mut uni, &model, &pre.data, &theta, 200_000);
        let rel = (rep.trace_cov - closed).abs() / closed.max(1e-12);
        assert!(rel < 0.1, "empirical {} vs closed {closed}", rep.trace_cov);
    }

    /// §2.3's headline: on power-law data LGD's trace of covariance is
    /// smaller than SGD's.
    #[test]
    fn lgd_variance_below_sgd_on_power_law() {
        let ds = SynthSpec::power_law("pl", 500, 10, 5).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let model = LinReg;
        let theta = theta_after_warmup(&pre, 150);
        let hd = pre.hashed.cols();
        // repo-default configuration (dense + clip 5 + mirror) — the one
        // the trainer uses; exact-weight regimes are covered by the
        // variance-ablation experiment
        let opts = LgdOptions { weight_clip: Some(5.0), ..LgdOptions::default() };
        let mut lgd = LgdEstimator::new(&pre, DenseSrp::new(hd, 5, 32, 7), 9, opts).unwrap();
        let mut sgd = UniformEstimator::new(pre.data.len(), 11);
        let trials = 120_000;
        let lgd_rep = empirical_trace(&mut lgd, &model, &pre.data, &theta, trials);
        let sgd_rep = empirical_trace(&mut sgd, &model, &pre.data, &theta, trials);
        assert!(
            lgd_rep.trace_cov < sgd_rep.trace_cov,
            "LGD trace {} not below SGD {}",
            lgd_rep.trace_cov,
            sgd_rep.trace_cov
        );
    }

    /// Lemma 1 evaluated: condition holds on power-law data.
    #[test]
    fn lemma1_condition_on_power_law() {
        let ds = SynthSpec::power_law("pl", 400, 8, 13).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let model = LinReg;
        let theta = theta_after_warmup(&pre, 120);
        let hd = pre.hashed.cols();
        let opts = LgdOptions { weight_clip: Some(5.0), ..LgdOptions::default() };
        let mut lgd =
            LgdEstimator::new(&pre, DenseSrp::new(hd, 5, 32, 17), 19, opts).unwrap();
        let (lhs, rhs) = lemma1_sides(&mut lgd, &model, &pre.data, &theta, 100_000);
        assert!(lhs < rhs, "Lemma 1 violated: lhs {lhs} rhs {rhs}");
    }
}
