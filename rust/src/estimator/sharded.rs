//! Sharded LGD: the parallel sampling engine.
//!
//! The dataset is partitioned across shards with
//! [`crate::data::shard::ShardPlan`]; each shard owns the stored rows of its
//! member examples (plus their mirrors) and its own [`LshTables`], built
//! concurrently by [`crate::coordinator::pipeline::build_shard_tables`].
//! Draws come from a *shard-mixture* proposal with exact probabilities:
//!
//! ```text
//! p(row) = (R_s / R) · p_shard(row)
//! ```
//!
//! where `R_s` is the shard's stored-row count, `R = Σ R_s`, and
//! `p_shard` is the exact Algorithm-1 probability within the shard. The
//! shard is picked ∝ its row count and Algorithm 1 runs inside it, so the
//! mixture probability is known exactly and Theorem-1 unbiasedness carries
//! over unchanged: `E[∇f / (p·R)]` is still the full average gradient.
//!
//! Every shard clones the *same* hasher family, so the query's K-bit table
//! codes are identical across shards — the estimator therefore hashes each
//! query **exactly once** (one fused `codes_all` sweep per cache refresh
//! for single draws, one per batch) and hands the precomputed codes to
//! every shard through [`QueryCache`] / the coded sampler entry points.
//! No shard ever re-hashes, regardless of shard count (asserted via the
//! hasher's invocation counters). With `shards = 1` the engine reduces to
//! [`LgdEstimator`] draw-for-draw under the same seed (tested below) — the
//! knob is purely a scaling dial.
//!
//! The shards are *live*: they sit in a [`ShardSet`], so the estimator
//! supports streaming [`ShardedLgdEstimator::insert`] /
//! [`ShardedLgdEstimator::remove`] after the build, and — when
//! `lsh.rebalance_threshold` enables it — automatic
//! [`crate::data::shard::ShardPlan::rebalance`]-driven migration under
//! skewed growth. `R_s/R` is recomputed after every mutation, so the
//! mixture probability every draw reports stays exact throughout.

use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::{build_shard_tables, ShardSet, ShardTables};
use crate::core::error::Result;
use crate::core::rng::{Pcg64, Rng};
use crate::core::telemetry::probes;
use crate::data::preprocess::Preprocessed;
use crate::data::shard::ShardPlan;
use crate::estimator::lgd::LgdOptions;
use crate::estimator::{EstimatorStats, GradientEstimator, WeightedDraw};
use crate::lsh::sampler::{Draw, LshSampler, QueryCache, SampleCost, Sampled};
use crate::lsh::srp::SrpHasher;
use crate::lsh::tables::{BucketRead, TableStore};

/// Timing/shape report of a sharded table build.
#[derive(Debug, Clone)]
pub struct ShardedBuildReport {
    /// Per-shard build seconds (each measured on its own worker thread).
    pub per_shard_secs: Vec<f64>,
    /// End-to-end wall seconds of the concurrent build.
    pub wall_secs: f64,
    /// Stored rows per shard.
    pub shard_rows: Vec<usize>,
}

/// Borrow bundle the async draw engine
/// ([`crate::coordinator::draw_engine`]) works through: the frozen shard
/// set shared by every sampler worker, plus the mutable estimator state
/// (RNG, counters) the session takes over and hands back.
pub(crate) struct EngineParts<'s, 'a, H: SrpHasher> {
    pub(crate) set: &'s ShardSet<H>,
    pub(crate) pre: &'a Preprocessed,
    pub(crate) opts: LgdOptions,
    pub(crate) rng: &'s mut Pcg64,
    pub(crate) stats: &'s mut EstimatorStats,
}

/// Per-shard Algorithm-1 sampler over a shard's tables/stored rows, with
/// the probe cap from `opts` — the single construction shared by the
/// single-draw path, the batch core and the async engine's workers.
pub(crate) fn shard_sampler<'s, H: SrpHasher>(
    shard: &'s ShardTables<H>,
    opts: &LgdOptions,
) -> LshSampler<'s, TableStore<H>> {
    let sp = LshSampler::with_norms(
        &shard.tables,
        &shard.stored,
        std::borrow::Cow::Borrowed(&shard.norms),
    );
    if opts.max_probes > 0 {
        sp.with_max_probes(opts.max_probes)
    } else {
        sp
    }
}

/// Fold one raw within-shard draw into its exact-mixture weighted draw:
/// `p = (R_s/R)·p_shard`, Theorem-1 weight `1/(p·R)` (optionally
/// clipped), mirror rows folded back to their example id. THE single
/// definition of the mixture math — the synchronous single/batch paths
/// and the async mixer all call this, so the sync-vs-async draw-for-draw
/// and unbiasedness contracts cannot drift apart.
pub(crate) fn mixture_weigh<H: SrpHasher>(
    set: &ShardSet<H>,
    s: usize,
    d: &Draw,
    opts: &LgdOptions,
    n: usize,
) -> WeightedDraw {
    let shard = set.shard(s);
    let frac = shard.stored.rows() as f64 / set.total_rows() as f64;
    let prob = d.prob * frac;
    let w = 1.0 / (prob * set.total_rows() as f64);
    let weight = match opts.weight_clip {
        Some(c) => w.min(c),
        None => w,
    };
    let global = shard.rows[d.index] as usize;
    let index = if global >= n { global - n } else { global };
    // Passive probe: records rates/occupancy/TV when armed, single relaxed
    // load when not; never touches the RNG or the draw order.
    probes::observe_hit(s, index, prob, d.probes, d.bucket_size);
    WeightedDraw { index, weight, prob }
}

/// Membership-aware degenerate uniform fallback over a (possibly partial)
/// shard set — the single definition shared by the synchronous estimator
/// and the async draw engine's mixer. See
/// [`ShardedLgdEstimator::uniform_fallback`] for the semantics; `n` is the
/// base example count of the backing matrix.
pub(crate) fn uniform_fallback_from<H: SrpHasher>(
    set: &ShardSet<H>,
    n: usize,
    rng: &mut Pcg64,
    fallbacks: &mut u64,
) -> WeightedDraw {
    *fallbacks += 1;
    probes::observe_fallback();
    let present = set.present_len();
    if present == 0 || present == n {
        return WeightedDraw { index: rng.index(n), weight: 1.0, prob: 1.0 / n as f64 };
    }
    let r = rng.index(set.total_rows());
    let s = set.shard_of_row(r);
    let start = if s == 0 { 0 } else { set.cum_rows()[s - 1] };
    let row = set.shard(s).rows[r - start] as usize;
    let index = if row >= n { row - n } else { row };
    WeightedDraw { index, weight: 1.0, prob: 1.0 / present as f64 }
}

/// The Appendix-B.2 shard-mixture minibatch core: multinomial shard
/// allocation (∝ stored rows), per-shard B.2 batch sampling through the
/// precomputed query `codes`, exact mixture probabilities
/// `p = (R_s/R)·p_shard`, and membership-aware uniform top-ups for
/// exhausted quotas. This is the *single* definition of the batch draw
/// stream: [`ShardedLgdEstimator::draw_batch`] delegates here, and so does
/// the async draw engine's single-worker replay mode — which is what makes
/// `async_workers = 1` draw-for-draw identical to the synchronous path by
/// construction. Query hashing is the caller's job (`codes` is unused on a
/// drained set); `stats` receives draws/fallbacks/cost.
pub(crate) fn mixture_draw_batch<H: SrpHasher>(
    set: &ShardSet<H>,
    n: usize,
    opts: &LgdOptions,
    codes: &[u32],
    query: &[f32],
    m: usize,
    rng: &mut Pcg64,
    stats: &mut EstimatorStats,
    scratch: &mut Vec<Draw>,
    out: &mut Vec<WeightedDraw>,
) {
    out.clear();
    // Drained set (streaming removals): all-uniform fallback batch.
    if set.total_rows() == 0 {
        for _ in 0..m {
            let d = uniform_fallback_from(set, n, rng, &mut stats.fallbacks);
            out.push(d);
        }
        stats.draws += m as u64;
        return;
    }
    let mut cost = SampleCost::default();
    let mut want = vec![0usize; set.shard_count()];
    if set.shard_count() > 1 {
        for _ in 0..m {
            let r = rng.index(set.total_rows());
            cost.randoms += 1;
            want[set.shard_of_row(r)] += 1;
        }
    } else {
        want[0] = m;
    }
    let mut short = 0usize;
    for (s, &quota) in want.iter().enumerate() {
        if quota == 0 {
            continue;
        }
        let sampler = shard_sampler(set.shard(s), opts);
        sampler.sample_batch_coded(codes, query, quota, rng, &mut cost, scratch);
        for d in scratch.iter() {
            out.push(mixture_weigh(set, s, d, opts, n));
        }
        // B.2 exhaustion: remember the shortfall; the uniform top-ups go
        // in after the loop, restricted to the present membership like the
        // single-draw fallback.
        short += quota - scratch.len();
    }
    probes::observe_exhausted(short);
    for _ in 0..short {
        let d = uniform_fallback_from(set, n, rng, &mut stats.fallbacks);
        out.push(d);
    }
    stats.draws += m as u64;
    stats.cost.absorb(&cost);
}

/// LGD estimator over sharded tables: shard-mixture proposal with exact
/// probabilities (see module docs). The shards live inside a
/// [`ShardSet`], so the estimator also supports *streaming mutation* —
/// [`Self::insert`]/[`Self::remove`]/[`Self::rebalance_to`] — with the
/// mixture weights `R_s/R` recomputed after every change.
pub struct ShardedLgdEstimator<'a, H: SrpHasher> {
    pre: &'a Preprocessed,
    set: ShardSet<H>,
    rng: Pcg64,
    opts: LgdOptions,
    stats: EstimatorStats,
    query: Vec<f32>,
    cache: QueryCache,
    /// Reusable buffer for the per-batch fused query codes (shared by
    /// every shard — the query is hashed exactly once per batch).
    codes: Vec<u32>,
    /// Reusable per-shard raw-draw buffer for the batch core.
    batch: Vec<Draw>,
    report: ShardedBuildReport,
}

impl<'a, H: SrpHasher> ShardedLgdEstimator<'a, H> {
    /// Partition `pre` into `shards` round-robin shards and build each
    /// shard's tables concurrently. Records per-shard build timing into a
    /// private registry; use [`Self::new_with_metrics`] to capture it.
    pub fn new(
        pre: &'a Preprocessed,
        hasher: H,
        seed: u64,
        opts: LgdOptions,
        shards: usize,
    ) -> Result<Self>
    where
        H: Clone,
    {
        Self::new_with_metrics(pre, hasher, seed, opts, shards, &Metrics::new())
    }

    /// [`Self::new`], recording per-shard build time under the
    /// `pipeline.shard_build` timer of `metrics`.
    pub fn new_with_metrics(
        pre: &'a Preprocessed,
        hasher: H,
        seed: u64,
        opts: LgdOptions,
        shards: usize,
        metrics: &Metrics,
    ) -> Result<Self>
    where
        H: Clone,
    {
        let n = pre.data.len();
        let plan = ShardPlan::round_robin(n, shards)?;
        let t0 = Instant::now();
        let built = build_shard_tables(&pre.hashed, &plan, opts.mirror, &hasher, metrics)?;
        let wall_secs = t0.elapsed().as_secs_f64();
        Ok(Self::from_shards_inner(pre, built, seed, opts, wall_secs))
    }

    /// Wrap pre-built shards (e.g. from a streaming build). Each shard's
    /// tables must index exactly its `stored` rows, and `rows` must map the
    /// local rows into the virtual stored matrix `[pre.hashed; −pre.hashed]`
    /// (row `i + N` = negation of row `i`) when `opts.mirror`, or plain
    /// `pre.hashed` row ids otherwise.
    pub fn from_shards(
        pre: &'a Preprocessed,
        shards: Vec<ShardTables<H>>,
        seed: u64,
        opts: LgdOptions,
    ) -> Self {
        Self::from_shards_inner(pre, shards, seed, opts, 0.0)
    }

    fn from_shards_inner(
        pre: &'a Preprocessed,
        shards: Vec<ShardTables<H>>,
        seed: u64,
        opts: LgdOptions,
        wall_secs: f64,
    ) -> Self {
        let report = ShardedBuildReport {
            per_shard_secs: shards.iter().map(|s| s.build_secs).collect(),
            wall_secs,
            shard_rows: shards.iter().map(|s| s.stored.rows()).collect(),
        };
        // `lsh.sealed`: flatten each shard's freshly built tables into the
        // CSR arena. Bucket order is preserved, so draws are identical to
        // the Vec layout (tested below); live mutation lands in the delta
        // overlay and rebalancing compacts it.
        let shards: Vec<ShardTables<H>> = if opts.sealed {
            shards.into_iter().map(ShardTables::seal).collect()
        } else {
            shards
        };
        let set = ShardSet::from_shards(shards, pre.data.len(), opts.mirror, 0.0);
        ShardedLgdEstimator {
            pre,
            set,
            // Same stream as LgdEstimator so shards = 1 is draw-for-draw
            // identical under the same seed.
            rng: Pcg64::new(seed, 0x4c474400),
            opts,
            stats: EstimatorStats::default(),
            query: Vec::new(),
            cache: QueryCache::default(),
            codes: Vec::new(),
            batch: Vec::new(),
            report,
        }
    }

    /// The preprocessed dataset backing this estimator.
    pub fn preprocessed(&self) -> &'a Preprocessed {
        self.pre
    }

    /// Raw RNG position (snapshot payload — see [`Pcg64::raw_state`]).
    pub(crate) fn rng_raw(&self) -> (u128, u128) {
        self.rng.raw_state()
    }

    /// The estimator's own draw-path counters (snapshot payload). Unlike
    /// [`GradientEstimator::stats`] this does *not* fold in the shard set's
    /// migration counters — those are persisted with the set itself.
    pub(crate) fn raw_stats(&self) -> EstimatorStats {
        self.stats
    }

    /// The single-draw query cache (snapshot payload).
    pub(crate) fn cache_view(&self) -> &QueryCache {
        &self.cache
    }

    /// The sampler options this estimator runs with (snapshot payload).
    pub(crate) fn options(&self) -> &LgdOptions {
        &self.opts
    }

    /// Reassemble an estimator from snapshot-restored parts. No tables are
    /// built and no query is hashed — the restored engine continues the
    /// saved one's draw stream bit-for-bit (RNG position, cache window and
    /// counters all round-trip). The build report is all zeros: a warm
    /// start performs zero table-build work, and that is observable.
    pub(crate) fn from_restored(
        pre: &'a Preprocessed,
        set: ShardSet<H>,
        rng: Pcg64,
        stats: EstimatorStats,
        cache: QueryCache,
        opts: LgdOptions,
    ) -> Self {
        let report = ShardedBuildReport {
            per_shard_secs: vec![0.0; set.shard_count()],
            wall_secs: 0.0,
            shard_rows: (0..set.shard_count()).map(|s| set.shard(s).stored.rows()).collect(),
        };
        ShardedLgdEstimator {
            pre,
            set,
            rng,
            opts,
            stats,
            query: Vec::new(),
            cache,
            codes: Vec::new(),
            batch: Vec::new(),
            report,
        }
    }

    /// Split the estimator into the borrow bundle the async draw engine
    /// drives a session through.
    pub(crate) fn engine_parts(&mut self) -> EngineParts<'_, 'a, H> {
        EngineParts {
            set: &self.set,
            pre: self.pre,
            opts: self.opts.clone(),
            rng: &mut self.rng,
            stats: &mut self.stats,
        }
    }

    /// Build timing/shape report.
    pub fn build_report(&self) -> &ShardedBuildReport {
        &self.report
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.set.shard_count()
    }

    /// The live shard set backing the mixture (membership, imbalance,
    /// migration counters).
    pub fn shard_set(&self) -> &ShardSet<H> {
        &self.set
    }

    /// Mutable access to the live shard set (e.g. to route a skewed
    /// arrival with [`ShardSet::insert_into`]). All `ShardSet` mutators
    /// maintain the prefix sums the mixture reads, so draws stay exact.
    pub fn shard_set_mut(&mut self) -> &mut ShardSet<H> {
        &mut self.set
    }

    /// Streaming insert: add example `id` of the backing `pre` to the
    /// least-loaded shard (its hash row plus the mirror when enabled).
    /// Returns the shard chosen. May trigger an automatic rebalance.
    pub fn insert(&mut self, id: usize) -> Result<usize> {
        self.set.insert(id, &self.pre.hashed)
    }

    /// Streaming remove: evict example `id` from its shard. Returns false
    /// if it was not present. May trigger an automatic rebalance.
    pub fn remove(&mut self, id: usize) -> Result<bool> {
        self.set.remove(id, &self.pre.hashed)
    }

    /// Migrate examples between shards until the imbalance is ≤ `target`.
    /// Returns the number of examples moved.
    pub fn rebalance_to(&mut self, target: f64) -> Result<usize> {
        self.set.rebalance_to(target, &self.pre.hashed)
    }

    /// Enable automatic rebalancing: after any insert/remove pushing the
    /// base-row imbalance (max/mean) above `t`, shards migrate examples
    /// until it is back under. 0 disables (the default).
    pub fn set_rebalance_threshold(&mut self, t: f64) {
        self.set.set_threshold(t);
    }

    /// Degenerate uniform fallback. While any example is present it is
    /// restricted to the present membership, so streaming removals are
    /// respected (evicted examples carry zero probability even on the
    /// fallback path): a partial set picks a uniform *stored row* and maps
    /// it back (each present example owns exactly one row, or two when
    /// mirrored — uniform over present examples in O(shards), no rejection
    /// loop). A full set is one uniform draw over all n, keeping the
    /// `shards = 1` stream identical to `LgdEstimator`'s fallback. A
    /// *fully drained* set has no valid support at all; rather than
    /// panicking mid-training it deliberately degenerates to uniform over
    /// all n (weight 1 — a plain SGD step), the documented escape hatch
    /// `drained_set_falls_back_uniform` pins down.
    fn uniform_fallback(&mut self) -> WeightedDraw {
        let n = self.pre.data.len();
        uniform_fallback_from(&self.set, n, &mut self.rng, &mut self.stats.fallbacks)
    }
}

impl<'a, H: SrpHasher> GradientEstimator for ShardedLgdEstimator<'a, H> {
    fn draw(&mut self, theta: &[f32]) -> WeightedDraw {
        self.stats.draws += 1;
        let l_tables = self.set.shard(0).tables.hasher().l();
        let refresh = if self.opts.query_refresh == 0 {
            8 * l_tables
        } else {
            self.opts.query_refresh
        };
        if self.cache.is_empty() || self.cache.age >= refresh {
            // The cache is shared by every shard, so the query is hashed
            // once per (window, table) regardless of shard count. Long
            // windows (default 8·L) take one fused codes_all sweep — same
            // mults the lazy fill would pay, one sequential pass; short
            // windows (query_refresh < L) keep the lazy fill, which only
            // hashes the tables actually probed before the window expires.
            let mut query = std::mem::take(&mut self.query);
            self.pre.query(theta, &mut query);
            if refresh >= l_tables {
                let mut rcost = SampleCost::default();
                self.cache.refresh_fused(&query, self.set.shard(0).tables.hasher(), &mut rcost);
                self.stats.cost.absorb(&rcost);
            } else {
                self.cache.refresh(&query, l_tables);
            }
            self.query = query;
        }
        // Streaming removals can drain the set entirely: degenerate
        // uniform fallback, same as an exhausted probe.
        if self.set.total_rows() == 0 {
            return self.uniform_fallback();
        }
        // Shard ∝ stored rows. With one shard no randomness is consumed,
        // keeping the draw stream identical to LgdEstimator.
        let s = if self.set.shard_count() > 1 {
            let r = self.rng.index(self.set.total_rows());
            self.stats.cost.randoms += 1;
            self.set.shard_of_row(r)
        } else {
            0
        };
        let mut cost = SampleCost::default();
        let mut cache = std::mem::take(&mut self.cache);
        let sampler = shard_sampler(self.set.shard(s), &self.opts);
        let n = self.pre.data.len();
        let hit = match sampler.sample_cached(&mut cache, &mut self.rng, &mut cost) {
            // Exact mixture probability: shard pick (R_s/R) × exact
            // Algorithm-1 probability within the shard.
            Sampled::Hit(d) => Some(mixture_weigh(&self.set, s, &d, &self.opts, n)),
            // Same degenerate fallback as LgdEstimator (one uniform draw
            // at weight 1, counted exactly once) — restricted to the
            // present membership; resolved below, after the shard borrow.
            Sampled::Exhausted { .. } => None,
        };
        self.cache = cache;
        self.stats.cost.absorb(&cost);
        match hit {
            Some(d) => d,
            None => self.uniform_fallback(),
        }
    }

    /// Appendix-B.2 minibatch sampling over the shard mixture: one
    /// row-proportional shard pick per requested draw (the multinomial
    /// allocation), then each shard's batch sampler fills its quota with
    /// replacement, so every returned draw carries its exact mixture
    /// probability. Under-filled quotas (exhausted shards) top up with
    /// uniform fallbacks, one counted fallback each. With `shards = 1`
    /// this is `LgdEstimator::draw_batch` draw-for-draw.
    fn draw_batch(&mut self, theta: &[f32], m: usize, out: &mut Vec<WeightedDraw>) {
        let n = self.pre.data.len();
        let mut query = std::mem::take(&mut self.query);
        let mut codes = std::mem::take(&mut self.codes);
        let mut scratch = std::mem::take(&mut self.batch);
        if self.set.total_rows() > 0 {
            self.pre.query(theta, &mut query);
            // The S×-redundancy fix: hash the query ONCE per batch (fused
            // sweep) and hand the same codes to every shard's coded
            // sampler — no shard re-hashes, and probe-heavy batches stop
            // paying one code computation per probe. A drained set skips
            // the hash entirely (the core serves uniform fallbacks).
            let hasher = self.set.shard(0).tables.hasher();
            hasher.codes_all(&query, &mut codes);
            self.stats.cost.codes += hasher.l();
            self.stats.cost.mults += hasher.mults_all();
        }
        mixture_draw_batch(
            &self.set,
            n,
            &self.opts,
            &codes,
            &query,
            m,
            &mut self.rng,
            &mut self.stats,
            &mut scratch,
            out,
        );
        self.query = query;
        self.codes = codes;
        self.batch = scratch;
    }

    fn stats(&self) -> EstimatorStats {
        let mut s = self.stats;
        let live = self.set.stats();
        s.migrations = live.migrations;
        s.rebalances = live.rebalances;
        s.rebalance_secs = live.rebalance_secs;
        s
    }

    fn name(&self) -> &'static str {
        "lgd-sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::Matrix;
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::estimator::lgd::LgdEstimator;
    use crate::lsh::srp::DenseSrp;
    use crate::lsh::tables::LshTables;
    use crate::model::{LinReg, Model};

    fn setup(n: usize, d: usize, seed: u64) -> Preprocessed {
        let ds = SynthSpec::power_law("t", n, d, seed).generate().unwrap();
        preprocess(ds, &PreprocessOptions::default()).unwrap()
    }

    /// The headline regression: `shards = 1` is LgdEstimator draw-for-draw
    /// under the same seed — same indices, weights and probabilities.
    #[test]
    fn single_shard_matches_lgd_draw_for_draw() {
        let pre = setup(300, 10, 31);
        let hd = pre.hashed.cols();
        let mut lgd =
            LgdEstimator::new(&pre, DenseSrp::new(hd, 4, 16, 33), 35, LgdOptions::default())
                .unwrap();
        let mut sharded = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 4, 16, 33),
            35,
            LgdOptions::default(),
            1,
        )
        .unwrap();
        let theta: Vec<f32> = (0..10).map(|j| 0.03 * (j as f32 - 4.0)).collect();
        for i in 0..500 {
            let a = lgd.draw(&theta);
            let b = sharded.draw(&theta);
            assert_eq!(a.index, b.index, "draw {i}: index diverged");
            assert_eq!(a.weight, b.weight, "draw {i}: weight diverged");
            assert_eq!(a.prob, b.prob, "draw {i}: prob diverged");
        }
        assert_eq!(lgd.stats().fallbacks, sharded.stats().fallbacks);
    }

    /// Theorem 1 for the shard mixture: averaged over the hash-function
    /// ensemble, `weight · ∇f(x_draw)` is the full average gradient — the
    /// same empirical-unbiasedness check `LgdEstimator` passes.
    #[test]
    fn sharded_estimator_is_unbiased_over_hash_ensemble() {
        let pre = setup(400, 10, 1);
        let hd = pre.hashed.cols();
        let model = LinReg;
        let theta: Vec<f32> = (0..10).map(|j| 0.05 * (j as f32 - 5.0)).collect();

        let mut full = vec![0.0f32; 10];
        model.full_grad(&pre.data, &theta, &mut full);
        let full_norm = crate::core::matrix::norm2(&full);

        let families = 60;
        let draws_per = 4_000;
        let mut acc = vec![0.0f64; 10];
        let mut g = vec![0.0f32; 10];
        let mut total = 0u64;
        for f in 0..families {
            let hasher = DenseSrp::new(hd, 4, 24, 500 + f as u64);
            let mut est = ShardedLgdEstimator::new(
                &pre,
                hasher,
                700 + f as u64,
                LgdOptions::default(),
                3,
            )
            .unwrap();
            for _ in 0..draws_per {
                let d = est.draw(&theta);
                let (x, y) = pre.data.example(d.index);
                model.grad(x, y, &theta, &mut g);
                for j in 0..10 {
                    acc[j] += d.weight * g[j] as f64;
                }
                total += 1;
            }
            assert_eq!(est.stats().fallbacks, 0, "fallbacks should not fire at K=4");
        }
        for a in acc.iter_mut() {
            *a /= total as f64;
        }
        let mut err = 0.0f64;
        for j in 0..10 {
            err += (acc[j] - full[j] as f64).powi(2);
        }
        let rel = err.sqrt() / full_norm.max(1e-12);
        assert!(rel < 0.15, "sharded LGD estimator biased: relative error {rel}");
    }

    /// Draws stay valid and the mixture actually reaches every shard.
    #[test]
    fn draws_valid_and_mixture_covers_all_shards() {
        let pre = setup(240, 8, 41);
        let hd = pre.hashed.cols();
        let shards = 4usize;
        let mut est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 12, 43),
            45,
            LgdOptions::default(),
            shards,
        )
        .unwrap();
        assert_eq!(est.shards(), shards);
        let rep = est.build_report().clone();
        assert_eq!(rep.per_shard_secs.len(), shards);
        assert_eq!(rep.shard_rows.iter().sum::<usize>(), 2 * 240, "mirrored rows");
        let theta = vec![0.05f32; 8];
        // round-robin: example i lives on shard i % 4
        let mut hit = vec![false; shards];
        for _ in 0..4_000 {
            let d = est.draw(&theta);
            assert!(d.index < 240);
            assert!(d.prob > 0.0 && d.prob <= 1.0, "prob {}", d.prob);
            assert!(d.weight > 0.0);
            hit[d.index % shards] = true;
        }
        assert!(hit.iter().all(|&h| h), "some shard never produced a draw: {hit:?}");
    }

    /// `shards = 1` batch draws are LgdEstimator::draw_batch draw-for-draw
    /// under the same seed.
    #[test]
    fn single_shard_batch_matches_lgd() {
        let pre = setup(200, 8, 61);
        let hd = pre.hashed.cols();
        let mut lgd =
            LgdEstimator::new(&pre, DenseSrp::new(hd, 3, 10, 63), 65, LgdOptions::default())
                .unwrap();
        let mut sharded = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 10, 63),
            65,
            LgdOptions::default(),
            1,
        )
        .unwrap();
        let theta = vec![0.02f32; 8];
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for round in 0..5 {
            lgd.draw_batch(&theta, 32, &mut a);
            sharded.draw_batch(&theta, 32, &mut b);
            assert_eq!(a, b, "batch round {round} diverged");
        }
        assert_eq!(lgd.stats().fallbacks, sharded.stats().fallbacks);
    }

    /// Sharded batch draws return exactly `m` valid weighted draws.
    #[test]
    fn sharded_batch_returns_m_valid_draws() {
        let pre = setup(180, 8, 71);
        let hd = pre.hashed.cols();
        let mut est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 10, 73),
            75,
            LgdOptions::default(),
            3,
        )
        .unwrap();
        let theta = vec![0.05f32; 8];
        let mut out = Vec::new();
        for _ in 0..4 {
            est.draw_batch(&theta, 48, &mut out);
            assert_eq!(out.len(), 48);
            for d in &out {
                assert!(d.index < 180);
                assert!(d.prob > 0.0 && d.prob <= 1.0);
                assert!(d.weight > 0.0);
            }
        }
        assert_eq!(est.stats().draws, 4 * 48);
    }

    /// Live mutation: draws stay valid (in-range index, exact positive
    /// probability, no draws of removed examples) across an
    /// insert/remove/rebalance stream, and the estimator reports the
    /// migration counters.
    #[test]
    fn draws_stay_valid_across_live_mutation() {
        let pre = setup(200, 8, 81);
        let hd = pre.hashed.cols();
        let mut est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 10, 83),
            85,
            LgdOptions::default(),
            4,
        )
        .unwrap();
        let theta = vec![0.05f32; 8];
        for id in 0..50 {
            assert!(est.remove(id).unwrap());
        }
        for _ in 0..500 {
            let d = est.draw(&theta);
            assert!(d.index >= 50 && d.index < 200, "drew a removed example: {}", d.index);
            assert!(d.prob > 0.0 && d.prob <= 1.0);
            assert!(d.weight > 0.0);
        }
        assert_eq!(est.stats().fallbacks, 0, "dense buckets at K=3 must not exhaust");
        // skew one shard, enable auto-rebalance, and stream the ids back in
        est.set_rebalance_threshold(1.25);
        for id in 0..50 {
            est.shard_set_mut().insert_into(0, id, &pre.hashed).unwrap();
        }
        assert!(est.shard_set().imbalance() <= 1.25);
        let st = est.stats();
        assert!(st.migrations > 0, "skewed re-inserts must migrate");
        assert!(st.rebalances > 0);
        for _ in 0..500 {
            let d = est.draw(&theta);
            assert!(d.index < 200);
            assert!(d.prob > 0.0 && d.prob <= 1.0);
        }
        let mut out = Vec::new();
        est.draw_batch(&theta, 64, &mut out);
        assert_eq!(out.len(), 64);
        assert!(out.iter().all(|d| d.index < 200 && d.weight > 0.0));
    }

    /// Fallbacks respect live membership: even when probes exhaust (K far
    /// too large for the data, one probe only), the uniform fallback must
    /// never resurrect an evicted example.
    #[test]
    fn fallback_respects_live_membership() {
        let pre = setup(120, 8, 97);
        let hd = pre.hashed.cols();
        let opts = LgdOptions { max_probes: 1, ..LgdOptions::default() };
        let mut est =
            ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 8, 4, 98), 99, opts, 3).unwrap();
        for id in 0..40 {
            assert!(est.remove(id).unwrap());
        }
        let theta = vec![0.05f32; 8];
        for _ in 0..2000 {
            let d = est.draw(&theta);
            assert!(
                d.index >= 40 && d.index < 120,
                "draw returned evicted example {}",
                d.index
            );
            assert!(d.prob > 0.0 && d.weight > 0.0);
        }
        let mut out = Vec::new();
        est.draw_batch(&theta, 64, &mut out);
        assert!(out.iter().all(|d| d.index >= 40 && d.index < 120));
        assert!(
            est.stats().fallbacks > 0,
            "K=8 with a single probe must exhaust sometimes — test setup is wrong otherwise"
        );
    }

    /// Removing everything degenerates to counted uniform fallbacks
    /// instead of panicking, for both single and batch draws.
    #[test]
    fn drained_set_falls_back_uniform() {
        let pre = setup(60, 6, 87);
        let hd = pre.hashed.cols();
        let mut est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 6, 88),
            89,
            LgdOptions::default(),
            2,
        )
        .unwrap();
        for id in 0..60 {
            assert!(est.remove(id).unwrap());
        }
        assert_eq!(est.shard_set().total_rows(), 0);
        let theta = vec![0.1f32; 6];
        for i in 1..=40u64 {
            let d = est.draw(&theta);
            assert!(d.index < 60);
            assert_eq!(d.weight, 1.0);
            assert_eq!(est.stats().fallbacks, i);
        }
        let mut out = Vec::new();
        est.draw_batch(&theta, 16, &mut out);
        assert_eq!(out.len(), 16);
        assert_eq!(est.stats().fallbacks, 40 + 16);
        assert!(out.iter().all(|d| d.index < 60 && d.weight == 1.0));
    }

    /// Acceptance: sealed and unsealed sharded estimators produce
    /// identical draw sequences under the same seed — single draws,
    /// batches, and after a scripted insert/remove/rebalance burst
    /// (overlay writes + post-rebalance compaction covered).
    #[test]
    fn sealed_matches_unsealed_draw_for_draw_with_mutation() {
        let pre = setup(240, 10, 55);
        let hd = pre.hashed.cols();
        let mk = |sealed: bool| {
            let opts = LgdOptions { sealed, ..LgdOptions::default() };
            ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 3, 12, 56), 57, opts, 3).unwrap()
        };
        let mut a = mk(true);
        let mut b = mk(false);
        assert!(a.shard_set().shard(0).tables.is_sealed());
        assert!(!b.shard_set().shard(0).tables.is_sealed());
        let theta: Vec<f32> = (0..10).map(|j| 0.03 * (j as f32 - 5.0)).collect();
        for i in 0..400 {
            assert_eq!(a.draw(&theta), b.draw(&theta), "draw {i} diverged across layouts");
        }
        let (mut xa, mut xb) = (Vec::new(), Vec::new());
        for round in 0..3 {
            a.draw_batch(&theta, 32, &mut xa);
            b.draw_batch(&theta, 32, &mut xb);
            assert_eq!(xa, xb, "batch round {round} diverged across layouts");
        }
        // scripted mutation: evict a block, re-admit into one shard
        // (overlay appends on the sealed side), then rebalance (compacts)
        for id in 0..60 {
            assert!(a.remove(id).unwrap());
            assert!(b.remove(id).unwrap());
        }
        for id in 0..60 {
            a.shard_set_mut().insert_into(0, id, &pre.hashed).unwrap();
            b.shard_set_mut().insert_into(0, id, &pre.hashed).unwrap();
        }
        assert_eq!(a.rebalance_to(1.05).unwrap(), b.rebalance_to(1.05).unwrap());
        for i in 0..400 {
            assert_eq!(a.draw(&theta), b.draw(&theta), "post-mutation draw {i} diverged");
        }
        for round in 0..3 {
            a.draw_batch(&theta, 32, &mut xa);
            b.draw_batch(&theta, 32, &mut xb);
            assert_eq!(xa, xb, "post-mutation batch round {round} diverged");
        }
        assert_eq!(a.stats().fallbacks, b.stats().fallbacks);
    }

    /// Acceptance: the estimator hashes each query exactly once per batch
    /// (and once per refresh window for single draws), *regardless of
    /// shard count* — asserted via the hasher family's shared invocation
    /// counters. Per-table `code()` is never called on the draw path.
    #[test]
    fn query_hashed_once_regardless_of_shard_count() {
        let pre = setup(200, 8, 65);
        let hd = pre.hashed.cols();
        let theta = vec![0.04f32; 8];
        let mut per_shards = Vec::new();
        for &shards in &[1usize, 4] {
            let hasher = DenseSrp::new(hd, 3, 10, 66);
            let handle = hasher.clone(); // clones share the counters
            let mut est =
                ShardedLgdEstimator::new(&pre, hasher, 67, LgdOptions::default(), shards).unwrap();
            let after_build = handle.hash_stats();
            assert_eq!(after_build.fused_calls, 0, "builds hash rows, not queries");
            // batches: exactly one fused sweep per draw_batch call
            let mut out = Vec::new();
            for _ in 0..5 {
                est.draw_batch(&theta, 16, &mut out);
            }
            // single draws: exactly one fused sweep per refresh window
            for _ in 0..30 {
                est.draw(&theta);
            }
            let s = handle.hash_stats();
            assert_eq!(
                s.code_calls, after_build.code_calls,
                "{shards} shard(s): the draw path must never invoke per-table code()"
            );
            per_shards.push(s.fused_calls - after_build.fused_calls);
        }
        assert_eq!(
            per_shards[0], per_shards[1],
            "query hash invocations must not scale with shard count"
        );
        assert_eq!(per_shards[0], 5 + 1, "5 batches + 1 cache refresh");
    }

    /// Exhaustion falls back to a uniform draw with weight 1, counted
    /// exactly once per draw — deterministic via empty per-shard tables.
    #[test]
    fn exhausted_fallback_counts_once_per_draw() {
        let pre = setup(100, 6, 51);
        let hd = pre.hashed.cols();
        let opts = LgdOptions { mirror: false, ..LgdOptions::default() };
        let mut shards = Vec::new();
        for s in 0..2 {
            let mut stored = Matrix::zeros(0, 0);
            let mut rows = Vec::new();
            for i in (s..100).step_by(2) {
                rows.push(i as u32);
                stored.push_row(pre.hashed.row(i)).unwrap();
            }
            let norms: Vec<f64> = stored.row_norms();
            let tables =
                crate::lsh::tables::TableStore::Vec(LshTables::new(DenseSrp::new(hd, 3, 4, 53)));
            shards.push(ShardTables { rows, stored, norms, tables, build_secs: 0.0 });
        }
        let mut est = ShardedLgdEstimator::from_shards(&pre, shards, 55, opts);
        let theta = vec![0.1f32; 6];
        for i in 1..=150u64 {
            let d = est.draw(&theta);
            assert!(d.index < 100);
            assert_eq!(d.weight, 1.0);
            assert_eq!(est.stats().fallbacks, i, "exactly one fallback per draw");
        }
    }
}
