//! The O(N)-per-iteration *oracle* adaptive estimator — the paper's
//! chicken-and-egg baseline (§1.1).
//!
//! Samples exactly from the optimal distribution `w*_i ∝ ‖∇f(x_i, θ_t)‖`
//! [Alain et al. 2015], recomputing every weight each draw because θ_t
//! changed — precisely the O(N) maintenance cost the paper's whole
//! contribution avoids. Included so the benchmarks can demonstrate the
//! loop quantitatively: oracle draws cost N·d work, LGD draws cost O(d).
//! Its estimates are minimum-variance (a useful lower-bound reference in
//! the variance experiments).

use crate::core::rng::{Pcg64, Rng};
use crate::data::dataset::Dataset;
use crate::estimator::{EstimatorStats, GradientEstimator, WeightedDraw};
use crate::model::Model;

/// Exact gradient-norm-proportional sampler (O(N·d) per draw).
pub struct OracleEstimator<'a> {
    ds: &'a Dataset,
    model: Box<dyn Model>,
    rng: Pcg64,
    stats: EstimatorStats,
    /// scratch: per-example norms + cumulative distribution
    norms: Vec<f64>,
}

impl<'a> OracleEstimator<'a> {
    /// Oracle over a dataset with its native model.
    pub fn new(ds: &'a Dataset, model: Box<dyn Model>, seed: u64) -> Self {
        OracleEstimator {
            ds,
            model,
            rng: Pcg64::new(seed, 0x04AC1E),
            stats: EstimatorStats::default(),
            norms: vec![0.0; ds.len()],
        }
    }
}

impl<'a> GradientEstimator for OracleEstimator<'a> {
    fn draw(&mut self, theta: &[f32]) -> WeightedDraw {
        self.stats.draws += 1;
        // The O(N) loop: recompute every gradient norm at the current θ.
        let n = self.ds.len();
        let mut total = 0.0f64;
        for i in 0..n {
            let (x, y) = self.ds.example(i);
            let g = self.model.grad_norm(x, y, theta);
            self.norms[i] = g;
            total += g;
        }
        self.stats.cost.mults += (n * theta.len()) as f64;
        if total <= 0.0 {
            // all-zero gradients: any example works, weight 1
            let i = self.rng.index(n);
            return WeightedDraw { index: i, weight: 1.0, prob: 1.0 / n as f64 };
        }
        // inverse-CDF draw
        let u = self.rng.next_f64() * total;
        self.stats.cost.randoms += 1;
        let mut acc = 0.0f64;
        let mut idx = n - 1;
        for i in 0..n {
            acc += self.norms[i];
            if u <= acc {
                idx = i;
                break;
            }
        }
        let prob = self.norms[idx] / total;
        // unbiased weight: (1/N) / p_i
        WeightedDraw { index: idx, weight: 1.0 / (prob * n as f64), prob }
    }

    fn stats(&self) -> EstimatorStats {
        self.stats
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::norm2;
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::estimator::variance::{empirical_trace, sgd_trace_closed_form};
    use crate::estimator::UniformEstimator;
    use crate::model::LinReg;

    fn setup(n: usize, seed: u64) -> crate::data::preprocess::Preprocessed {
        let ds = SynthSpec::power_law("o", n, 8, seed).generate().unwrap();
        preprocess(ds, &PreprocessOptions::default()).unwrap()
    }

    #[test]
    fn draw_frequency_proportional_to_grad_norm() {
        let pre = setup(50, 1);
        let mut est = OracleEstimator::new(&pre.data, Box::new(LinReg), 3);
        let theta: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let model = LinReg;
        let trials = 40_000;
        let mut counts = vec![0usize; 50];
        for _ in 0..trials {
            counts[est.draw(&theta).index] += 1;
        }
        let norms: Vec<f64> = (0..50)
            .map(|i| {
                let (x, y) = pre.data.example(i);
                model.grad_norm(x, y, &theta)
            })
            .collect();
        let total: f64 = norms.iter().sum();
        for i in 0..50 {
            let want = norms[i] / total;
            let got = counts[i] as f64 / trials as f64;
            if want > 0.02 {
                assert!(
                    (got - want).abs() / want < 0.15,
                    "example {i}: freq {got:.4} vs optimal {want:.4}"
                );
            }
        }
    }

    #[test]
    fn oracle_is_unbiased() {
        let pre = setup(120, 5);
        let mut est = OracleEstimator::new(&pre.data, Box::new(LinReg), 7);
        let model = LinReg;
        let theta = vec![0.05f32; 8];
        let mut full = vec![0.0f32; 8];
        model.full_grad(&pre.data, &theta, &mut full);
        let mut acc = vec![0.0f64; 8];
        let mut g = vec![0.0f32; 8];
        let trials = 80_000;
        for _ in 0..trials {
            let dr = est.draw(&theta);
            let (x, y) = pre.data.example(dr.index);
            model.grad(x, y, &theta, &mut g);
            for j in 0..8 {
                acc[j] += dr.weight * g[j] as f64 / trials as f64;
            }
        }
        let mut err = 0.0;
        for j in 0..8 {
            err += (acc[j] - full[j] as f64).powi(2);
        }
        assert!(
            err.sqrt() / norm2(&full).max(1e-12) < 0.05,
            "oracle biased: {err}"
        );
    }

    /// The optimal distribution achieves the minimum variance — below
    /// uniform SGD (and the benchmark shows it costs O(N) per draw).
    #[test]
    fn oracle_variance_below_sgd() {
        let pre = setup(300, 9);
        let model = LinReg;
        let theta = vec![0.05f32; 8];
        let mut oracle = OracleEstimator::new(&pre.data, Box::new(LinReg), 11);
        let rep = empirical_trace(&mut oracle, &model, &pre.data, &theta, 60_000);
        let sgd = sgd_trace_closed_form(&model, &pre.data, &theta);
        assert!(
            rep.trace_cov < sgd,
            "oracle trace {} not below SGD {sgd}",
            rep.trace_cov
        );
        // sanity: uniform empirical matches too
        let mut uni = UniformEstimator::new(pre.data.len(), 13);
        let uni_rep = empirical_trace(&mut uni, &model, &pre.data, &theta, 60_000);
        assert!(rep.trace_cov < uni_rep.trace_cov);
    }

    /// Cost accounting: each oracle draw does N·d mult-equivalents — the
    /// chicken-and-egg loop made concrete.
    #[test]
    fn oracle_cost_is_linear_in_n() {
        let pre = setup(200, 13);
        let mut est = OracleEstimator::new(&pre.data, Box::new(LinReg), 15);
        let theta = vec![0.1f32; 8];
        for _ in 0..10 {
            est.draw(&theta);
        }
        let s = est.stats();
        assert_eq!(s.draws, 10);
        assert!((s.cost.mults - (10 * 200 * 8) as f64).abs() < 1e-9);
    }
}
