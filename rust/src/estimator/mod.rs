//! Gradient estimators: the uniform SGD baseline and the paper's LGD
//! (LSH-sampled) estimator, behind one trait so every optimizer and
//! experiment treats them interchangeably — exactly the paper's framing
//! ("the only difference in the gradient algorithm was the gradient
//! estimator").

pub mod lgd;
pub mod oracle;
pub mod sharded;
pub mod uniform;
pub mod variance;

use crate::lsh::sampler::SampleCost;

/// One weighted draw: the estimator of the full gradient is
/// `weight · ∇f(x_index, θ)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightedDraw {
    /// Index of the drawn example.
    pub index: usize,
    /// Importance weight making the single-sample estimator unbiased for
    /// the *average* gradient: 1 for uniform sampling, `1/(p·N)` for LGD.
    pub weight: f64,
    /// Probability with which the example was drawn (1/N for uniform).
    pub prob: f64,
}

/// Cumulative cost/diagnostic counters an estimator exposes.
#[derive(Debug, Clone, Copy, Default)]
pub struct EstimatorStats {
    /// Draws served.
    pub draws: u64,
    /// Uniform fallbacks (LGD only: all probed buckets empty).
    pub fallbacks: u64,
    /// Aggregate hash-lookup cost.
    pub cost: SampleCost,
    /// Examples migrated between shards by live rebalancing (sharded
    /// engine only; 0 elsewhere).
    pub migrations: u64,
    /// Rebalance passes that moved at least one example.
    pub rebalances: u64,
    /// Wall seconds spent in rebalance passes.
    pub rebalance_secs: f64,
    /// Async draw engine: batches that were already assembled when the
    /// consumer asked for them (the pipeline kept ahead of compute).
    pub prefetch_hits: u64,
    /// Async draw engine: batch requests that had to wait on an empty
    /// queue (sampling was the bottleneck at that moment).
    pub queue_stalls: u64,
}

impl EstimatorStats {
    /// Fold the *draw-path* counters of a worker/session accumulator into
    /// this one (draws, fallbacks, sample cost, queue counters). Shard
    /// migration counters are set-level state, not per-worker work, so they
    /// are deliberately not summed here.
    pub fn merge_draws(&mut self, other: &EstimatorStats) {
        self.draws += other.draws;
        self.fallbacks += other.fallbacks;
        self.cost.absorb(&other.cost);
        self.prefetch_hits += other.prefetch_hits;
        self.queue_stalls += other.queue_stalls;
    }
}

/// An adaptive (or not) sampler of training examples.
pub trait GradientEstimator {
    /// Draw one example given the current parameters.
    fn draw(&mut self, theta: &[f32]) -> WeightedDraw;

    /// Draw a minibatch of `m` examples (Appendix B.2 semantics for LGD).
    fn draw_batch(&mut self, theta: &[f32], m: usize, out: &mut Vec<WeightedDraw>) {
        out.clear();
        for _ in 0..m {
            out.push(self.draw(theta));
        }
    }

    /// Cumulative counters.
    fn stats(&self) -> EstimatorStats;

    /// Estimator name for logs / CSV columns.
    fn name(&self) -> &'static str;
}

pub use lgd::LgdEstimator;
pub use oracle::OracleEstimator;
pub use sharded::{ShardedBuildReport, ShardedLgdEstimator};
pub use uniform::UniformEstimator;
