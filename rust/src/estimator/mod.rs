//! Gradient estimators: the uniform SGD baseline and the paper's LGD
//! (LSH-sampled) estimator, behind one trait so every optimizer and
//! experiment treats them interchangeably — exactly the paper's framing
//! ("the only difference in the gradient algorithm was the gradient
//! estimator").

pub mod lgd;
pub mod oracle;
pub mod sharded;
pub mod uniform;
pub mod variance;

use crate::lsh::sampler::SampleCost;

/// One weighted draw: the estimator of the full gradient is
/// `weight · ∇f(x_index, θ)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightedDraw {
    /// Index of the drawn example.
    pub index: usize,
    /// Importance weight making the single-sample estimator unbiased for
    /// the *average* gradient: 1 for uniform sampling, `1/(p·N)` for LGD.
    pub weight: f64,
    /// Probability with which the example was drawn (1/N for uniform).
    pub prob: f64,
}

/// Cumulative cost/diagnostic counters an estimator exposes.
#[derive(Debug, Clone, Copy, Default)]
pub struct EstimatorStats {
    /// Draws served.
    pub draws: u64,
    /// Uniform fallbacks (LGD only: all probed buckets empty).
    pub fallbacks: u64,
    /// Aggregate hash-lookup cost.
    pub cost: SampleCost,
    /// Examples migrated between shards by live rebalancing (sharded
    /// engine only; 0 elsewhere).
    pub migrations: u64,
    /// Rebalance passes that moved at least one example.
    pub rebalances: u64,
    /// Wall seconds spent in rebalance passes.
    pub rebalance_secs: f64,
}

/// An adaptive (or not) sampler of training examples.
pub trait GradientEstimator {
    /// Draw one example given the current parameters.
    fn draw(&mut self, theta: &[f32]) -> WeightedDraw;

    /// Draw a minibatch of `m` examples (Appendix B.2 semantics for LGD).
    fn draw_batch(&mut self, theta: &[f32], m: usize, out: &mut Vec<WeightedDraw>) {
        out.clear();
        for _ in 0..m {
            out.push(self.draw(theta));
        }
    }

    /// Cumulative counters.
    fn stats(&self) -> EstimatorStats;

    /// Estimator name for logs / CSV columns.
    fn name(&self) -> &'static str;
}

pub use lgd::LgdEstimator;
pub use oracle::OracleEstimator;
pub use sharded::{ShardedBuildReport, ShardedLgdEstimator};
pub use uniform::UniformEstimator;
