//! The SGD baseline: uniform sampling with weight 1.

use crate::core::rng::{Pcg64, Rng};
use crate::estimator::{EstimatorStats, GradientEstimator, WeightedDraw};

/// Uniform sampler over `n` examples — plain SGD's estimator. Costs one
/// random number per draw (§2.2's cost baseline).
pub struct UniformEstimator {
    n: usize,
    rng: Pcg64,
    stats: EstimatorStats,
}

impl UniformEstimator {
    /// Sampler over `n` examples.
    pub fn new(n: usize, seed: u64) -> Self {
        assert!(n > 0, "empty dataset");
        UniformEstimator { n, rng: Pcg64::new(seed, 0x53474400), stats: EstimatorStats::default() }
    }
}

impl GradientEstimator for UniformEstimator {
    #[inline]
    fn draw(&mut self, _theta: &[f32]) -> WeightedDraw {
        self.stats.draws += 1;
        self.stats.cost.randoms += 1;
        WeightedDraw {
            index: self.rng.index(self.n),
            weight: 1.0,
            prob: 1.0 / self.n as f64,
        }
    }

    fn stats(&self) -> EstimatorStats {
        self.stats
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_cover_range_uniformly() {
        let mut e = UniformEstimator::new(10, 1);
        let mut counts = [0usize; 10];
        let trials = 50_000;
        for _ in 0..trials {
            let d = e.draw(&[]);
            assert_eq!(d.weight, 1.0);
            assert!((d.prob - 0.1).abs() < 1e-12);
            counts[d.index] += 1;
        }
        for &c in &counts {
            let f = c as f64 / trials as f64;
            assert!((f - 0.1).abs() < 0.01, "freq {f}");
        }
        assert_eq!(e.stats().draws, trials as u64);
        assert_eq!(e.stats().fallbacks, 0);
    }

    #[test]
    fn batch_draw_has_m_entries() {
        let mut e = UniformEstimator::new(5, 2);
        let mut out = Vec::new();
        e.draw_batch(&[], 16, &mut out);
        assert_eq!(out.len(), 16);
    }
}
