//! The LGD estimator: Algorithm 2's sampling step.
//!
//! Owns the (K, L) tables built over the preprocessed hash-space vectors;
//! each `draw` builds the query `[θ_t, −1]` (or `−θ` for logistic), runs
//! Algorithm 1, and converts the returned probability into the unbiased
//! importance weight `1/(p·N)` of Theorem 1.

use crate::core::rng::{Pcg64, Rng};
use crate::core::telemetry::probes;
use crate::data::preprocess::Preprocessed;
use crate::estimator::{EstimatorStats, GradientEstimator, WeightedDraw};
use crate::lsh::sampler::{LshSampler, QueryCache, SampleCost, Sampled};
use crate::lsh::srp::SrpHasher;
use crate::lsh::tables::{BucketRead, LshTables, TableStore};

/// Tuning knobs for the LGD estimator.
#[derive(Debug, Clone)]
pub struct LgdOptions {
    /// Cap on importance weights (`None` = exact Thm-1 weights). A finite
    /// cap trades a little bias for variance control on tiny buckets; the
    /// paper uses exact weights, so the default is `None` and the cap is an
    /// ablation knob.
    pub weight_clip: Option<f64>,
    /// Probe cap before falling back to a uniform draw (weight 1).
    pub max_probes: usize,
    /// Reuse the query's table codes for this many consecutive draws
    /// before recomputing them from the current θ ("stale query", see
    /// [`crate::lsh::sampler::QueryCache`]). 0 = auto (8·L — long enough
    /// that most probes hit cached codes, amortising the K·d hash cost to
    /// ≈K·d/8 per draw; still well under the half-epoch refresh Appendix E
    /// uses for BERT); 1 = recompute every draw (Algorithm 1 verbatim).
    /// Staleness never biases the estimator — the stale proposal's
    /// probabilities are exact, it only lags the adaptivity slightly.
    pub query_refresh: usize,
    /// Mirrored storage: hash both `v_i` and `−v_i` (2N stored rows). The
    /// per-example retrieval probability becomes `∝ cp^K + (1−cp)^K`,
    /// symmetric in the sign of ⟨v_i, q⟩ — i.e. monotone in the *absolute*
    /// inner product, which is exactly the §2.1 requirement the quadratic
    /// map T(·) establishes, at linear-hash cost (2× memory). The estimator
    /// stays exactly unbiased: each stored row's draw probability is known,
    /// and both rows of example i contribute ∇f_i, so weighting by
    /// `1/(p_row·2N)` preserves Thm 1. Default on; disable to reproduce the
    /// signed-residual pathology as an ablation.
    pub mirror: bool,
    /// Seal the tables into the CSR bucket arena after the build
    /// ([`crate::lsh::tables::SealedTables`]): O(1)-probe, cache-linear
    /// bucket reads on the draw path. Draw-for-draw identical to the Vec
    /// layout under the same seed (tested); default on — disable
    /// (`lsh.sealed = false`) to A/B the layouts.
    pub sealed: bool,
}

impl Default for LgdOptions {
    fn default() -> Self {
        LgdOptions {
            weight_clip: None,
            max_probes: 0, // 0 = 4·L
            query_refresh: 0, // 0 = 8·L
            mirror: true,
            sealed: true,
        }
    }
}

/// LGD estimator over a preprocessed dataset.
pub struct LgdEstimator<'a, H: SrpHasher> {
    pre: &'a Preprocessed,
    tables: TableStore<H>,
    /// The vectors actually inserted into the tables: `pre.hashed` rows,
    /// followed by their negations when `opts.mirror` (2N rows; row i+N is
    /// −v_i and maps back to example i).
    stored: crate::core::matrix::Matrix,
    rng: Pcg64,
    opts: LgdOptions,
    stats: EstimatorStats,
    /// Precomputed ‖stored_i‖ for the cp hot path.
    stored_norms: Vec<f64>,
    query: Vec<f32>,
    cache: QueryCache,
    batch: Vec<crate::lsh::sampler::Draw>,
    /// Reusable buffer for the per-batch fused query codes.
    codes: Vec<u32>,
}

fn stored_matrix(pre: &Preprocessed, mirror: bool) -> crate::core::matrix::Matrix {
    let n = pre.data.len();
    let mut m = pre.hashed.clone();
    if mirror {
        for i in 0..n {
            let neg: Vec<f32> = pre.hashed.row(i).iter().map(|v| -v).collect();
            m.push_row(&neg).expect("same width");
        }
    }
    m
}

impl<'a, H: SrpHasher> LgdEstimator<'a, H> {
    /// Build tables over `pre.hashed` (the one-time preprocessing cost of
    /// LGD — measured and reported by the benchmarks).
    pub fn new(
        pre: &'a Preprocessed,
        hasher: H,
        seed: u64,
        opts: LgdOptions,
    ) -> crate::core::error::Result<Self> {
        let stored = stored_matrix(pre, opts.mirror);
        let tables = LshTables::build(hasher, (0..stored.rows()).map(|i| stored.row(i)))?;
        let tables = if opts.sealed {
            TableStore::Sealed(tables.seal())
        } else {
            TableStore::Vec(tables)
        };
        let stored_norms = stored.row_norms();
        Ok(LgdEstimator {
            pre,
            tables,
            stored,
            stored_norms,
            rng: Pcg64::new(seed, 0x4c474400), // "LGD"
            opts,
            stats: EstimatorStats::default(),
            query: Vec::new(),
            cache: QueryCache::default(),
            batch: Vec::new(),
            codes: Vec::new(),
        })
    }

    /// Wrap *pre-built* tables (e.g. from the streaming pipeline) instead of
    /// building them here (sealing them per `opts.sealed`). The tables must
    /// have been built over exactly `pre.hashed` (no mirroring — the
    /// streaming pipeline inserts N rows).
    pub fn from_parts(
        pre: &'a Preprocessed,
        tables: LshTables<H>,
        seed: u64,
        opts: LgdOptions,
    ) -> Self {
        let opts = LgdOptions { mirror: false, ..opts };
        let tables = if opts.sealed {
            TableStore::Sealed(tables.seal())
        } else {
            TableStore::Vec(tables)
        };
        let stored = pre.hashed.clone();
        let stored_norms = stored.row_norms();
        LgdEstimator {
            pre,
            tables,
            stored,
            stored_norms,
            rng: Pcg64::new(seed, 0x4c474400),
            opts,
            stats: EstimatorStats::default(),
            query: Vec::new(),
            cache: QueryCache::default(),
            batch: Vec::new(),
            codes: Vec::new(),
        }
    }

    /// Bucket-occupancy statistics of the underlying tables.
    pub fn table_stats(&self) -> crate::lsh::tables::TableStats {
        self.tables.stats()
    }

    fn sampler<'s>(
        tables: &'s TableStore<H>,
        stored: &'s crate::core::matrix::Matrix,
        norms: &'s [f64],
        opts: &LgdOptions,
    ) -> LshSampler<'s, TableStore<H>> {
        let s = LshSampler::with_norms(tables, stored, std::borrow::Cow::Borrowed(norms));
        if opts.max_probes > 0 {
            s.with_max_probes(opts.max_probes)
        } else {
            s
        }
    }

    /// Importance weight for a drawn *row*: `1/(p·R)` where R is the number
    /// of stored rows (2N when mirrored — each example contributes two
    /// rows, so the row-estimator mean over 2N rows is still the full
    /// average gradient).
    #[inline]
    fn weight_of(&self, prob: f64) -> f64 {
        let rows = self.stored.rows() as f64;
        let w = 1.0 / (prob * rows);
        match self.opts.weight_clip {
            Some(c) => w.min(c),
            None => w,
        }
    }

    /// Map a stored-row index back to its example index.
    #[inline]
    fn example_of(&self, row: usize) -> usize {
        let n = self.pre.data.len();
        if row >= n {
            row - n
        } else {
            row
        }
    }
}

impl<'a, H: SrpHasher> GradientEstimator for LgdEstimator<'a, H> {
    fn draw(&mut self, theta: &[f32]) -> WeightedDraw {
        self.stats.draws += 1;
        let refresh = if self.opts.query_refresh == 0 {
            8 * self.tables.hasher().l()
        } else {
            self.opts.query_refresh
        };
        let mut cost = SampleCost::default();
        if self.cache.is_empty() || self.cache.age >= refresh {
            let mut query = std::mem::take(&mut self.query);
            self.pre.query(theta, &mut query);
            let l = self.tables.hasher().l();
            if refresh >= l {
                // Long window (default 8·L): nearly every table gets probed
                // before the next refresh, so one fused codes_all sweep
                // costs the same mults the lazy fill would pay — as one
                // sequential pass (§2.2 cost model).
                self.cache.refresh_fused(&query, self.tables.hasher(), &mut cost);
            } else {
                // Short window (e.g. query_refresh = 1): most tables are
                // never probed before the refresh expires — lazy fill
                // hashes only the probed ones.
                self.cache.refresh(&query, l);
            }
            self.query = query;
        }
        let mut cache = std::mem::take(&mut self.cache);
        let sampler = Self::sampler(&self.tables, &self.stored, &self.stored_norms, &self.opts);
        let out = match sampler.sample_cached(&mut cache, &mut self.rng, &mut cost) {
            Sampled::Hit(d) => {
                let index = self.example_of(d.index);
                probes::observe_hit(0, index, d.prob, d.probes, d.bucket_size);
                WeightedDraw { index, weight: self.weight_of(d.prob), prob: d.prob }
            }
            Sampled::Exhausted { .. } => {
                // Degenerate fallback: uniform draw, weight 1 (plain SGD
                // step). Counted so experiments can verify it never fires
                // under paper-default K.
                self.stats.fallbacks += 1;
                probes::observe_exhausted(1);
                probes::observe_fallback();
                let n = self.pre.data.len();
                WeightedDraw { index: self.rng.index(n), weight: 1.0, prob: 1.0 / n as f64 }
            }
        };
        self.cache = cache;
        self.stats.cost.absorb(&cost);
        out
    }

    fn draw_batch(&mut self, theta: &[f32], m: usize, out: &mut Vec<WeightedDraw>) {
        out.clear();
        let mut query = std::mem::take(&mut self.query);
        let mut batch = std::mem::take(&mut self.batch);
        let mut codes = std::mem::take(&mut self.codes);
        self.pre.query(theta, &mut query);
        let mut cost = SampleCost::default();
        {
            // Hash the query once per batch (fused), then fill the whole
            // batch through the coded sampler — probe-heavy batches no
            // longer pay one code computation per probe.
            let hasher = self.tables.hasher();
            hasher.codes_all(&query, &mut codes);
            cost.codes += hasher.l();
            cost.mults += hasher.mults_all();
            let sampler = Self::sampler(&self.tables, &self.stored, &self.stored_norms, &self.opts);
            sampler.sample_batch_coded(&codes, &query, m, &mut self.rng, &mut cost, &mut batch);
        }
        for d in &batch {
            let index = self.example_of(d.index);
            probes::observe_hit(0, index, d.prob, d.probes, d.bucket_size);
            out.push(WeightedDraw { index, weight: self.weight_of(d.prob), prob: d.prob });
        }
        // B.2 exhaustion: top up with uniform fallbacks.
        let n = self.pre.data.len();
        probes::observe_exhausted(m.saturating_sub(out.len()));
        while out.len() < m {
            self.stats.fallbacks += 1;
            probes::observe_fallback();
            out.push(WeightedDraw { index: self.rng.index(n), weight: 1.0, prob: 1.0 / n as f64 });
        }
        self.stats.draws += m as u64;
        self.stats.cost.absorb(&cost);
        self.query = query;
        self.batch = batch;
        self.codes = codes;
    }

    fn stats(&self) -> EstimatorStats {
        self.stats
    }

    fn name(&self) -> &'static str {
        "lgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::lsh::srp::DenseSrp;
    use crate::model::{LinReg, Model};

    fn setup(n: usize, d: usize, seed: u64) -> Preprocessed {
        let ds = SynthSpec::power_law("t", n, d, seed).generate().unwrap();
        preprocess(ds, &PreprocessOptions::default()).unwrap()
    }

    /// Theorem 1 (empirical): the expectation of `weight · ∇f(x_draw)` over
    /// the *hash-function ensemble* is the full average gradient. We average
    /// over many independently drawn hash families (the theorem's
    /// probability space) with many draws each.
    #[test]
    fn estimator_is_unbiased_over_hash_ensemble() {
        let pre = setup(400, 10, 1);
        let hd = pre.hashed.cols();
        let model = LinReg;
        let theta: Vec<f32> = (0..10).map(|j| 0.05 * (j as f32 - 5.0)).collect();

        let mut full = vec![0.0f32; 10];
        model.full_grad(&pre.data, &theta, &mut full);
        let full_norm = crate::core::matrix::norm2(&full);

        let families = 60;
        let draws_per = 4_000;
        let mut acc = vec![0.0f64; 10];
        let mut g = vec![0.0f32; 10];
        let mut total = 0u64;
        for f in 0..families {
            let hasher = DenseSrp::new(hd, 4, 24, 500 + f as u64);
            let mut est =
                LgdEstimator::new(&pre, hasher, 700 + f as u64, LgdOptions::default()).unwrap();
            for _ in 0..draws_per {
                let d = est.draw(&theta);
                let (x, y) = pre.data.example(d.index);
                model.grad(x, y, &theta, &mut g);
                for j in 0..10 {
                    acc[j] += d.weight * g[j] as f64;
                }
                total += 1;
            }
            assert_eq!(est.stats().fallbacks, 0, "fallbacks should not fire at K=4");
        }
        for a in acc.iter_mut() {
            *a /= total as f64;
        }
        let mut err = 0.0f64;
        for j in 0..10 {
            err += (acc[j] - full[j] as f64).powi(2);
        }
        let rel = err.sqrt() / full_norm.max(1e-12);
        assert!(rel < 0.15, "LGD estimator biased: relative error {rel}");
    }

    /// Figure 9's first claim: the average gradient norm of LGD draws
    /// exceeds that of uniform draws (LGD prefers large-gradient points).
    #[test]
    fn lgd_draws_have_larger_gradient_norms() {
        let pre = setup(600, 12, 5);
        let hd = pre.hashed.cols();
        let hasher = DenseSrp::new(hd, 5, 32, 6);
        let mut est = LgdEstimator::new(&pre, hasher, 7, LgdOptions::default()).unwrap();
        let model = LinReg;
        // intermediate theta: take a few SGD steps from zero
        let mut theta = vec![0.0f32; 12];
        let mut g = vec![0.0f32; 12];
        let mut uni = crate::estimator::UniformEstimator::new(600, 9);
        for _ in 0..150 {
            let d = uni.draw(&theta);
            let (x, y) = pre.data.example(d.index);
            model.grad(x, y, &theta, &mut g);
            crate::core::matrix::axpy(-0.05, &g, &mut theta);
        }
        let trials = 20_000;
        let mut lgd_norm = 0.0;
        let mut sgd_norm = 0.0;
        for _ in 0..trials {
            let d = est.draw(&theta);
            let (x, y) = pre.data.example(d.index);
            lgd_norm += model.grad_norm(x, y, &theta);
            let u = uni.draw(&theta);
            let (x, y) = pre.data.example(u.index);
            sgd_norm += model.grad_norm(x, y, &theta);
        }
        assert!(
            lgd_norm > sgd_norm * 1.1,
            "LGD mean grad norm {} not larger than SGD {}",
            lgd_norm / trials as f64,
            sgd_norm / trials as f64
        );
    }

    #[test]
    fn weight_clip_caps_weights() {
        let pre = setup(200, 8, 11);
        let hd = pre.hashed.cols();
        let hasher = DenseSrp::new(hd, 5, 16, 12);
        let opts = LgdOptions {
            weight_clip: Some(2.0),
            max_probes: 0,
            query_refresh: 8,
            ..LgdOptions::default()
        };
        let mut est = LgdEstimator::new(&pre, hasher, 13, opts).unwrap();
        let theta = vec![0.1f32; 8];
        for _ in 0..2000 {
            let d = est.draw(&theta);
            assert!(d.weight <= 2.0 + 1e-12);
        }
    }

    /// Regression: the `Exhausted` → uniform fallback path must count
    /// exactly one fallback per draw and return a valid uniform draw.
    /// Empty tables (via `from_parts`) make every probe exhaust
    /// deterministically.
    #[test]
    fn exhausted_fallback_counts_once_per_draw() {
        let pre = setup(120, 8, 21);
        let hd = pre.hashed.cols();
        let hasher = DenseSrp::new(hd, 4, 6, 22);
        let empty = crate::lsh::tables::LshTables::new(hasher);
        let mut est = LgdEstimator::from_parts(&pre, empty, 23, LgdOptions::default());
        let theta = vec![0.1f32; 8];
        for i in 1..=200u64 {
            let d = est.draw(&theta);
            assert!(d.index < 120);
            assert_eq!(d.weight, 1.0);
            assert!((d.prob - 1.0 / 120.0).abs() < 1e-12);
            assert_eq!(est.stats().fallbacks, i, "exactly one fallback per draw");
        }
        assert_eq!(est.stats().draws, 200);
    }

    /// Regression: `draw_batch`'s uniform top-up must never emit
    /// out-of-range indices or non-positive weights, and counts one
    /// fallback per topped-up draw.
    #[test]
    fn batch_topup_indices_and_weights_valid() {
        let pre = setup(90, 6, 25);
        let hd = pre.hashed.cols();
        let hasher = DenseSrp::new(hd, 3, 8, 26);
        let empty = crate::lsh::tables::LshTables::new(hasher);
        let mut est = LgdEstimator::from_parts(&pre, empty, 27, LgdOptions::default());
        let theta = vec![0.05f32; 6];
        let mut out = Vec::new();
        est.draw_batch(&theta, 48, &mut out);
        assert_eq!(out.len(), 48);
        for d in &out {
            assert!(d.index < 90, "top-up produced out-of-range index {}", d.index);
            assert!(d.weight > 0.0, "top-up produced zero weight");
            assert!(d.prob > 0.0);
        }
        assert_eq!(est.stats().fallbacks, 48);
        assert_eq!(est.stats().draws, 48);
    }

    /// The sealed CSR arena and the Vec layout produce identical draw
    /// sequences under the same seed — single draws and batches.
    #[test]
    fn sealed_matches_unsealed_draw_for_draw() {
        let pre = setup(250, 10, 61);
        let hd = pre.hashed.cols();
        let mk = |sealed: bool| {
            let opts = LgdOptions { sealed, ..LgdOptions::default() };
            LgdEstimator::new(&pre, DenseSrp::new(hd, 4, 14, 62), 63, opts).unwrap()
        };
        let mut a = mk(true);
        let mut b = mk(false);
        assert!(matches!(a.tables, TableStore::Sealed(_)));
        assert!(matches!(b.tables, TableStore::Vec(_)));
        let theta: Vec<f32> = (0..10).map(|j| 0.02 * (j as f32 - 4.0)).collect();
        for i in 0..600 {
            assert_eq!(a.draw(&theta), b.draw(&theta), "draw {i} diverged across layouts");
        }
        let (mut xa, mut xb) = (Vec::new(), Vec::new());
        for round in 0..4 {
            a.draw_batch(&theta, 32, &mut xa);
            b.draw_batch(&theta, 32, &mut xb);
            assert_eq!(xa, xb, "batch round {round} diverged across layouts");
        }
        assert_eq!(a.stats().fallbacks, b.stats().fallbacks);
        assert_eq!(a.table_stats(), b.table_stats());
    }

    #[test]
    fn batch_draw_returns_m() {
        let pre = setup(150, 6, 15);
        let hd = pre.hashed.cols();
        let hasher = DenseSrp::new(hd, 3, 10, 16);
        let mut est = LgdEstimator::new(&pre, hasher, 17, LgdOptions::default()).unwrap();
        let theta = vec![0.0f32; 6];
        let mut out = Vec::new();
        est.draw_batch(&theta, 32, &mut out);
        assert_eq!(out.len(), 32);
        assert!(out.iter().all(|d| d.index < 150 && d.weight > 0.0));
    }
}
