//! The on-disk snapshot container: magic/version header, CRC-protected
//! section table, and crash-safe atomic writes.
//!
//! ```text
//! offset 0   magic    "LGDSNAP\0"                      (8 bytes)
//!         8   version  u32 LE                           (= 1)
//!        12   sections u32 LE                           (count)
//!        16   reserved u64 LE                           (flags, 0)
//!        24   table    sections × 32 bytes:
//!               kind u32 | reserved u32 | offset u64 | len u64 |
//!               crc32 u32 | reserved u32
//!        24+32·S  header_crc u32 LE  — CRC-32 of bytes [0, 24+32·S)
//!        ...  payloads, back to back, in table order
//! ```
//!
//! Integrity model: the header CRC covers the magic, version, count and the
//! whole section table, so *any* single-byte corruption of the header or
//! table fails loudly; each payload carries its own CRC-32, so any
//! single-byte payload corruption fails before its section is decoded.
//! Truncation fails the bounds checks. The result is the tentpole
//! guarantee: a damaged file is always a clean
//! [`Error::Store`](crate::core::error::Error::Store), never UB and never a
//! silently wrong index.
//!
//! Writes go to `<path>.tmp`, are fsynced, then renamed over `<path>` (and
//! the parent directory is fsynced best-effort), so a crash mid-save leaves
//! either the old snapshot or the new one — never a half-written file at
//! the serving path.

use std::ffi::OsString;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::core::error::{Error, Result};
use crate::store::checksum::crc32;
use crate::testkit::faults;

/// File magic ("LGD snapshot", NUL-terminated).
pub const MAGIC: [u8; 8] = *b"LGDSNAP\0";

/// Container format version. Bump on any incompatible layout change; the
/// loader rejects versions it does not know (forward compatibility is a
/// re-index, not a guess).
pub const VERSION: u32 = 1;

/// Fixed header bytes before the section table.
const HEADER_FIXED: usize = 24;
/// Bytes per section-table entry.
const TABLE_ENTRY: usize = 32;
/// Sanity cap on the section count (a corrupted count must not drive a
/// huge table read).
const MAX_SECTIONS: usize = 256;

/// Section identifiers. Values are stable on-disk tags — never reuse one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SectionKind {
    /// Summary metadata (shape, hasher family, flags) — decoded by inspect.
    Meta,
    /// The preprocessed dataset (features, targets, hash-space matrix).
    Data,
    /// Hash-family state (planes / postings / calibration).
    Hasher,
    /// Per-shard stored rows + table dumps (Vec or sealed CSR arena).
    Shards,
    /// Estimator state: RNG position, counters, query cache.
    Estimator,
    /// Optional training state: θ, iteration, optimizer moments.
    Train,
    /// Optional health stamp: the supervisor's verdict on the training
    /// state at save time (`coordinator::health`). Recovery in
    /// newest-*healthy*-wins mode skips snapshots whose stamp says
    /// unhealthy; unstamped snapshots (every pre-health save path) are
    /// treated as healthy.
    Health,
}

impl SectionKind {
    /// Stable on-disk tag.
    pub fn tag(self) -> u32 {
        match self {
            SectionKind::Meta => 1,
            SectionKind::Data => 2,
            SectionKind::Hasher => 3,
            SectionKind::Shards => 4,
            SectionKind::Estimator => 5,
            SectionKind::Train => 6,
            SectionKind::Health => 7,
        }
    }

    /// Parse a tag.
    pub fn from_tag(tag: u32) -> Result<SectionKind> {
        Ok(match tag {
            1 => SectionKind::Meta,
            2 => SectionKind::Data,
            3 => SectionKind::Hasher,
            4 => SectionKind::Shards,
            5 => SectionKind::Estimator,
            6 => SectionKind::Train,
            7 => SectionKind::Health,
            other => return Err(Error::Store(format!("unknown section kind {other}"))),
        })
    }

    /// Human-readable name (inspect output).
    pub fn name(self) -> &'static str {
        match self {
            SectionKind::Meta => "meta",
            SectionKind::Data => "data",
            SectionKind::Hasher => "hasher",
            SectionKind::Shards => "shards",
            SectionKind::Estimator => "estimator",
            SectionKind::Train => "train",
            SectionKind::Health => "health",
        }
    }
}

/// One decoded section-table entry.
#[derive(Debug, Clone)]
pub struct SectionEntry {
    /// What the payload holds.
    pub kind: SectionKind,
    /// Absolute payload offset in the file.
    pub offset: usize,
    /// Payload length in bytes.
    pub len: usize,
    /// Stored payload CRC-32.
    pub crc: u32,
}

/// Assemble a snapshot file image from `(kind, payload)` sections.
pub fn assemble(sections: &[(SectionKind, Vec<u8>)]) -> Vec<u8> {
    let table_len = sections.len() * TABLE_ENTRY;
    let payload_base = HEADER_FIXED + table_len + 4; // + header crc
    let mut header = Vec::with_capacity(payload_base);
    header.extend_from_slice(&MAGIC);
    header.extend_from_slice(&VERSION.to_le_bytes());
    header.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    header.extend_from_slice(&0u64.to_le_bytes());
    let mut offset = payload_base;
    for (kind, payload) in sections {
        header.extend_from_slice(&kind.tag().to_le_bytes());
        header.extend_from_slice(&0u32.to_le_bytes());
        header.extend_from_slice(&(offset as u64).to_le_bytes());
        header.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        header.extend_from_slice(&crc32(payload).to_le_bytes());
        header.extend_from_slice(&0u32.to_le_bytes());
        offset += payload.len();
    }
    let hcrc = crc32(&header);
    header.extend_from_slice(&hcrc.to_le_bytes());
    let mut out = header;
    out.reserve(offset - out.len());
    for (_, payload) in sections {
        out.extend_from_slice(payload);
    }
    out
}

/// Parse and fully verify a snapshot image: magic, version, header CRC,
/// section bounds and every payload CRC. Returns the verified entries; use
/// [`section`] to borrow a payload.
pub fn parse(bytes: &[u8]) -> Result<Vec<SectionEntry>> {
    if bytes.len() < HEADER_FIXED + 4 {
        return Err(Error::Store(format!("file of {} bytes is too short", bytes.len())));
    }
    if bytes[..8] != MAGIC {
        return Err(Error::Store("bad magic — not an LGD snapshot".into()));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != VERSION {
        return Err(Error::Store(format!(
            "unsupported snapshot version {version} (this build reads {VERSION})"
        )));
    }
    let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    if count > MAX_SECTIONS {
        return Err(Error::Store(format!("section count {count} exceeds cap {MAX_SECTIONS}")));
    }
    let table_end = HEADER_FIXED + count * TABLE_ENTRY;
    if bytes.len() < table_end + 4 {
        return Err(Error::Store("truncated section table".into()));
    }
    let stored_hcrc = u32::from_le_bytes(bytes[table_end..table_end + 4].try_into().unwrap());
    if crc32(&bytes[..table_end]) != stored_hcrc {
        return Err(Error::Store("header/section-table CRC mismatch".into()));
    }
    let payload_base = table_end + 4;
    let mut entries = Vec::with_capacity(count);
    let mut expect_offset = payload_base;
    for s in 0..count {
        let at = HEADER_FIXED + s * TABLE_ENTRY;
        let e = &bytes[at..at + TABLE_ENTRY];
        let kind = SectionKind::from_tag(u32::from_le_bytes(e[0..4].try_into().unwrap()))?;
        let offset = u64::from_le_bytes(e[8..16].try_into().unwrap()) as usize;
        let len = u64::from_le_bytes(e[16..24].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(e[24..28].try_into().unwrap());
        if offset != expect_offset {
            return Err(Error::Store(format!(
                "section {s} ({}) at offset {offset}, expected {expect_offset}",
                kind.name()
            )));
        }
        let end = offset.checked_add(len).ok_or_else(|| {
            Error::Store(format!("section {s} ({}) length overflows", kind.name()))
        })?;
        if end > bytes.len() {
            return Err(Error::Store(format!(
                "section {s} ({}) runs past end of file ({end} > {})",
                kind.name(),
                bytes.len()
            )));
        }
        if crc32(&bytes[offset..end]) != crc {
            return Err(Error::Store(format!(
                "section {s} ({}) payload CRC mismatch — snapshot is corrupted",
                kind.name()
            )));
        }
        expect_offset = end;
        entries.push(SectionEntry { kind, offset, len, crc });
    }
    if expect_offset != bytes.len() {
        return Err(Error::Store(format!(
            "{} trailing bytes after the last section",
            bytes.len() - expect_offset
        )));
    }
    Ok(entries)
}

/// Borrow the payload of the first section of `kind`, or `None`.
pub fn section<'a>(
    bytes: &'a [u8],
    entries: &[SectionEntry],
    kind: SectionKind,
) -> Option<&'a [u8]> {
    entries
        .iter()
        .find(|e| e.kind == kind)
        .map(|e| &bytes[e.offset..e.offset + e.len])
}

/// Like [`section`] but required.
pub fn require_section<'a>(
    bytes: &'a [u8],
    entries: &[SectionEntry],
    kind: SectionKind,
) -> Result<&'a [u8]> {
    section(bytes, entries, kind)
        .ok_or_else(|| Error::Store(format!("snapshot is missing the {} section", kind.name())))
}

/// Sibling path with `.tmp` appended to the full file name (not an
/// extension swap — `snap.lgdsnap` → `snap.lgdsnap.tmp`).
fn tmp_path(path: &Path) -> PathBuf {
    let mut name = OsString::from(path.as_os_str());
    name.push(".tmp");
    PathBuf::from(name)
}

/// Crash-safe write: `<path>.tmp` + fsync + rename over `<path>`, parent
/// directory fsynced best-effort. A crash at any point leaves either the
/// previous file or the complete new one.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_path(path);
    let wrap = |e: std::io::Error, what: &str| {
        Error::Store(format!("{what} {}: {e}", tmp.display()))
    };
    {
        let mut f = std::fs::File::create(&tmp).map_err(|e| wrap(e, "create"))?;
        if faults::should_fail(faults::SNAPSHOT_WRITE) {
            // Simulated crash mid-stream: leave a truncated tmp behind.
            let _ = f.write_all(&bytes[..bytes.len() / 2]);
            return Err(wrap(
                std::io::Error::new(std::io::ErrorKind::Other, "failpoint"),
                "write",
            ));
        }
        f.write_all(bytes).map_err(|e| wrap(e, "write"))?;
        if faults::should_fail(faults::SNAPSHOT_FSYNC) {
            return Err(wrap(
                std::io::Error::new(std::io::ErrorKind::Other, "failpoint"),
                "fsync",
            ));
        }
        f.sync_all().map_err(|e| wrap(e, "fsync"))?;
    }
    if faults::should_fail(faults::SNAPSHOT_RENAME) {
        return Err(Error::Store(format!("rename into {}: failpoint", path.display())));
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| Error::Store(format!("rename into {}: {e}", path.display())))?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all(); // best effort; not supported on all platforms
        }
    }
    Ok(())
}

/// Read a snapshot file fully into memory.
pub fn read_file(path: &Path) -> Result<Vec<u8>> {
    std::fs::read(path).map_err(|e| Error::Store(format!("read {}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        assemble(&[
            (SectionKind::Meta, vec![1, 2, 3]),
            (SectionKind::Data, vec![]),
            (SectionKind::Shards, vec![9; 100]),
        ])
    }

    #[test]
    fn assemble_parse_roundtrip() {
        let img = sample();
        let entries = parse(&img).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(section(&img, &entries, SectionKind::Meta), Some(&[1u8, 2, 3][..]));
        assert_eq!(section(&img, &entries, SectionKind::Data), Some(&[][..]));
        assert_eq!(require_section(&img, &entries, SectionKind::Shards).unwrap().len(), 100);
        assert!(section(&img, &entries, SectionKind::Train).is_none());
        assert!(require_section(&img, &entries, SectionKind::Train).is_err());
    }

    /// Every single-byte corruption anywhere in the image — header, table,
    /// payloads — is rejected with `Error::Store`, and every truncation too.
    #[test]
    fn every_corruption_position_rejected() {
        let img = sample();
        for pos in 0..img.len() {
            let mut c = img.clone();
            c[pos] ^= 0x40;
            match parse(&c) {
                Err(crate::core::error::Error::Store(_)) => {}
                Err(e) => panic!("flip at {pos}: wrong error kind {e}"),
                Ok(_) => panic!("flip at byte {pos} was not detected"),
            }
        }
        for cut in 0..img.len() {
            assert!(parse(&img[..cut]).is_err(), "truncation at {cut} accepted");
        }
        // trailing garbage is also rejected
        let mut long = img.clone();
        long.push(0);
        assert!(parse(&long).is_err());
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("lgd-store-format");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("probe.lgdsnap");
        write_atomic(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        write_atomic(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        assert!(!tmp_path(&path).exists(), "tmp file must not survive a save");
        assert!(matches!(
            read_file(&dir.join("missing.lgdsnap")),
            Err(crate::core::error::Error::Store(_))
        ));
        std::fs::remove_file(&path).unwrap();
    }
}
