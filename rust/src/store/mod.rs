//! `store` — versioned, zero-dependency persistence for the full engine
//! state.
//!
//! The paper's running-time argument (§2.2) treats the LSH preprocessing as
//! a **one-time cost amortized across all subsequent adaptive draws** — an
//! argument that collapses if every process start re-pays it. This
//! subsystem makes the index outlive the process: the dataset matrix, the
//! hash family's plane/posting state, every shard's sealed CSR arena (or
//! Vec buckets) with its delta overlay, the live shard-set membership and
//! generation counter, the estimator's RNG position and query cache, model
//! weights and optimizer moments all round-trip through one binary file, so
//! a restarted server serves the *identical* draw stream the stopped one
//! would have — with zero table-build work and zero extra hash
//! invocations.
//!
//! Layer map:
//! * [`checksum`] — CRC-32 (compile-time table, no deps).
//! * [`codec`] — bounds-checked little-endian primitives; truncation is
//!   always a clean [`Error::Store`](crate::core::error::Error::Store).
//! * [`format`] — the magic/version header, CRC-protected section table and
//!   crash-safe atomic writes (`*.tmp` + fsync + rename).
//! * [`snapshot`] — engine-level encode/decode/restore, the
//!   [`SnapshotHasher`](snapshot::SnapshotHasher) family trait, and rotated
//!   autosaves ([`save_rotated`](snapshot::save_rotated)) with
//!   newest-valid-wins crash recovery ([`recover`](snapshot::recover)).
//!
//! See `docs/persistence.md` for the on-disk layout and the compatibility
//! policy.

pub mod checksum;
pub mod codec;
pub mod format;
pub mod snapshot;

pub use checksum::crc32;
pub use format::{write_atomic, SectionKind, MAGIC, VERSION};
pub use snapshot::{
    load, recover, restore_boxed, restore_estimator, rotated_path, save, save_rotated,
    snapshot_bytes, EngineDump, LoadedSnapshot, Recovered, SnapshotHasher, SnapshotInfo,
    SnapshotMeta, TrainState,
};
