//! Engine-level snapshot encode/decode/restore.
//!
//! A snapshot holds everything required to serve draws without touching the
//! raw data again: the preprocessed dataset, the hash family's complete
//! state (planes / postings / calibration — restored **bit-exact**, so
//! codes and Algorithm-1 probabilities are identical to the saved family's),
//! every shard's stored rows and table layout (the PR-3 sealed CSR arena is
//! dumped section by section — codes, offsets, live prefixes, id slab,
//! overlay — never re-serialized bucket by bucket), the live shard-set
//! membership with its generation counter, the estimator's RNG position,
//! counters and query-cache window, and (optionally) training state: θ,
//! iteration and optimizer moments.
//!
//! The restore contract, tested below and in the integration suite:
//!
//! * **Draw-for-draw identity** — a restored estimator continues the saved
//!   engine's exact draw stream (single draws, batches, async sessions),
//!   across Vec and sealed layouts, any shard count, and live overlay
//!   state.
//! * **Zero rebuild** — restoring performs no table build and no hash
//!   invocation; the family's shared counters read zero right after a
//!   load, and the rebuilt build report carries all-zero timings.
//! * **Loud corruption** — any single-byte corruption or truncation is a
//!   clean [`Error::Store`] (header CRC + per-section CRCs + bounds-checked
//!   decode + structural re-validation), never UB or a silently wrong
//!   index.

use std::path::Path;

use crate::config::spec::{HasherKind, OptimizerKind};
use crate::coordinator::pipeline::{ShardSet, ShardSetStats, ShardTables};
use crate::core::error::{Error, Result};
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg64;
use crate::data::dataset::{Dataset, Task};
use crate::data::preprocess::{HashSpace, Preprocessed};
use crate::estimator::lgd::LgdOptions;
use crate::estimator::sharded::ShardedLgdEstimator;
use crate::estimator::{EstimatorStats, GradientEstimator};
use crate::lsh::sampler::{QueryCache, SampleCost};
use crate::lsh::srp::{DenseSrp, SparseSrp, SrpHasher};
use crate::lsh::tables::{BucketRead, TableDump, TableDumpView, TableStore};
use crate::lsh::{AnyHasher, HasherVisitor, QuadraticSrp};
use crate::optim::OptimState;
use crate::store::codec::{Reader, Writer};
use crate::store::format::{self, SectionKind};

/// A hash family that knows how to serialize its complete state. All
/// families ship an implementation; the bound rides along
/// [`HasherVisitor`], so every monomorphized engine can snapshot itself.
pub trait SnapshotHasher: SrpHasher {
    /// Stable on-disk family tag.
    fn hasher_tag(&self) -> u8;
    /// Serialize the family's full state (planes / postings / calibration).
    fn encode_state(&self, w: &mut Writer);
}

impl SnapshotHasher for DenseSrp {
    fn hasher_tag(&self) -> u8 {
        0
    }

    fn encode_state(&self, w: &mut Writer) {
        w.u64(self.dim() as u64);
        w.u32(self.k() as u32);
        w.u32(self.l() as u32);
        w.f32s(&self.planes_raw());
    }
}

impl SnapshotHasher for SparseSrp {
    fn hasher_tag(&self) -> u8 {
        1
    }

    fn encode_state(&self, w: &mut Writer) {
        w.u64(self.dim() as u64);
        w.u32(self.k() as u32);
        w.u32(self.l() as u32);
        w.f64(self.density());
        let rows = self.row_entries();
        w.u64(rows.len() as u64);
        for r in rows {
            w.u32s(r);
        }
        w.f64s(self.calib_bins());
    }
}

impl SnapshotHasher for QuadraticSrp {
    fn hasher_tag(&self) -> u8 {
        2
    }

    fn encode_state(&self, w: &mut Writer) {
        w.u64(self.dim() as u64);
        w.u32(self.k() as u32);
        w.u32(self.l() as u32);
        w.f64(self.density());
        let planes = self.plane_parts();
        w.u64(planes.len() as u64);
        for (ii, jj, sign) in planes {
            w.u32s(ii);
            w.u32s(jj);
            w.f32s(sign);
        }
    }
}

fn decode_hasher(r: &mut Reader<'_>) -> Result<AnyHasher> {
    let tag = r.u8()?;
    let dim = r.u64()? as usize;
    let k = r.u32()? as usize;
    let l = r.u32()? as usize;
    match tag {
        0 => {
            let planes = r.f32s()?;
            Ok(AnyHasher::Dense(DenseSrp::from_parts(dim, k, l, planes)?))
        }
        1 => {
            let density = r.f64()?;
            let rows = r.u64()? as usize;
            if rows != l.saturating_mul(k) {
                return Err(Error::Store(format!("sparse hasher row count {rows} != L·K")));
            }
            let entries = (0..rows).map(|_| r.u32s()).collect::<Result<Vec<_>>>()?;
            let bins = r.f64s()?;
            Ok(AnyHasher::Sparse(SparseSrp::from_parts(dim, k, l, density, entries, bins)?))
        }
        2 => {
            let density = r.f64()?;
            let count = r.u64()? as usize;
            if count != l.saturating_mul(k) {
                return Err(Error::Store(format!("quadratic plane count {count} != L·K")));
            }
            let planes = (0..count)
                .map(|_| Ok((r.u32s()?, r.u32s()?, r.f32s()?)))
                .collect::<Result<Vec<_>>>()?;
            Ok(AnyHasher::Quadratic(QuadraticSrp::from_parts(dim, k, l, density, planes)?))
        }
        other => Err(Error::Store(format!("unknown hasher family tag {other}"))),
    }
}

/// Summary metadata decoded by `lgd snapshot inspect` without touching the
/// bulk sections.
#[derive(Debug, Clone)]
pub struct SnapshotMeta {
    /// Examples in the persisted dataset.
    pub n: usize,
    /// Feature dimensionality.
    pub d: usize,
    /// Hash-space dimensionality.
    pub hash_dim: usize,
    /// Task tag ("regression"/"classification").
    pub task: &'static str,
    /// Hash family tag ("dense"/"sparse"/"quadratic").
    pub hasher: &'static str,
    /// Meta-hash width.
    pub k: usize,
    /// Table count.
    pub l: usize,
    /// Shard count of the persisted engine.
    pub shards: usize,
    /// Mirrored storage flag.
    pub mirror: bool,
    /// Whether shard tables are the sealed CSR arena layout.
    pub sealed: bool,
    /// Shard-set mutation generation at save time.
    pub generation: u64,
    /// Total stored rows `R` across shards.
    pub total_rows: usize,
    /// Present examples at save time.
    pub present: usize,
    /// Whether a training-state section is present.
    pub has_train: bool,
}

fn encode_meta<H: SnapshotHasher>(est: &ShardedLgdEstimator<'_, H>, has_train: bool) -> Vec<u8> {
    let pre = est.preprocessed();
    let set = est.shard_set();
    let hasher = set.shard(0).tables.hasher();
    let mut w = Writer::new();
    w.u64(pre.data.len() as u64);
    w.u64(pre.data.dim() as u64);
    w.u64(pre.hashed.cols() as u64);
    w.u8(match pre.data.task {
        Task::Regression => 0,
        Task::Classification => 1,
    });
    w.u8(hasher.hasher_tag());
    w.u32(hasher.k() as u32);
    w.u32(hasher.l() as u32);
    w.u32(set.shard_count() as u32);
    w.u8(est.options().mirror as u8);
    w.u8(set.shard(0).tables.is_sealed() as u8);
    w.u64(set.generation());
    w.u64(set.total_rows() as u64);
    w.u64(set.present_len() as u64);
    w.u8(has_train as u8);
    w.into_bytes()
}

fn decode_meta(bytes: &[u8]) -> Result<SnapshotMeta> {
    let mut r = Reader::new(bytes);
    let n = r.u64()? as usize;
    let d = r.u64()? as usize;
    let hash_dim = r.u64()? as usize;
    let task = match r.u8()? {
        0 => "regression",
        1 => "classification",
        t => return Err(Error::Store(format!("unknown task tag {t}"))),
    };
    let hasher = match r.u8()? {
        0 => HasherKind::Dense.name(),
        1 => HasherKind::Sparse.name(),
        2 => HasherKind::Quadratic.name(),
        t => return Err(Error::Store(format!("unknown hasher family tag {t}"))),
    };
    let k = r.u32()? as usize;
    let l = r.u32()? as usize;
    let shards = r.u32()? as usize;
    let mirror = r.u8()? != 0;
    let sealed = r.u8()? != 0;
    let generation = r.u64()?;
    let total_rows = r.u64()? as usize;
    let present = r.u64()? as usize;
    let has_train = r.u8()? != 0;
    r.expect_end("meta section")?;
    Ok(SnapshotMeta {
        n,
        d,
        hash_dim,
        task,
        hasher,
        k,
        l,
        shards,
        mirror,
        sealed,
        generation,
        total_rows,
        present,
        has_train,
    })
}

fn encode_data(pre: &Preprocessed) -> Vec<u8> {
    let mut w = Writer::new();
    w.str_(&pre.data.name);
    w.u8(match pre.data.task {
        Task::Regression => 0,
        Task::Classification => 1,
    });
    w.matrix(&pre.data.x);
    w.f32s(&pre.data.y);
    w.u8(match pre.space {
        HashSpace::LinRegAugmented => 0,
        HashSpace::LogRegSigned => 1,
    });
    w.f32s(&pre.center);
    w.f64s(&pre.norms);
    w.matrix(&pre.hashed);
    w.into_bytes()
}

fn decode_data(bytes: &[u8]) -> Result<Preprocessed> {
    let mut r = Reader::new(bytes);
    let name = r.str_()?;
    let task = match r.u8()? {
        0 => Task::Regression,
        1 => Task::Classification,
        t => return Err(Error::Store(format!("unknown task tag {t}"))),
    };
    let x = r.matrix()?;
    let y = r.f32s()?;
    let space = match r.u8()? {
        0 => HashSpace::LinRegAugmented,
        1 => HashSpace::LogRegSigned,
        t => return Err(Error::Store(format!("unknown hash-space tag {t}"))),
    };
    let center = r.f32s()?;
    let norms = r.f64s()?;
    let hashed = r.matrix()?;
    r.expect_end("data section")?;
    let n = x.rows();
    if norms.len() != n || hashed.rows() != n {
        return Err(Error::Store(format!(
            "data section inconsistent: {n} examples, {} norms, {} hashed rows",
            norms.len(),
            hashed.rows()
        )));
    }
    if hashed.cols() != space.dim(x.cols()) {
        return Err(Error::Store(format!(
            "hash-space width {} does not match features ({})",
            hashed.cols(),
            space.dim(x.cols())
        )));
    }
    let data = Dataset::new(name, x, y, task).map_err(|e| Error::Store(e.to_string()))?;
    Ok(Preprocessed { data, hashed, space, center, norms })
}

/// Serialize a borrowed table dump — bucket contents stream straight off
/// the live store, so a save never deep-clones id slabs (the
/// [`TableDumpView`] indirection exists exactly for this).
fn encode_table_dump(w: &mut Writer, dump: &TableDumpView<'_>) {
    match dump {
        TableDumpView::Vec { tables, len } => {
            w.u8(0);
            w.u64(*len as u64);
            w.u64(tables.len() as u64);
            for buckets in tables {
                w.u64(buckets.len() as u64);
                for (code, ids) in buckets {
                    w.u32(*code);
                    w.u32s(ids);
                }
            }
        }
        TableDumpView::Sealed { tables, len } => {
            w.u8(1);
            w.u64(*len as u64);
            w.u64(tables.len() as u64);
            for t in tables {
                w.u32s(t.codes);
                w.u32s(t.offsets);
                w.u32s(t.live);
                w.u32s(t.ids);
                w.u64(t.overlay.len() as u64);
                for (code, ids) in &t.overlay {
                    w.u32(*code);
                    w.u32s(ids);
                }
            }
        }
    }
}

fn decode_table_dump(r: &mut Reader<'_>) -> Result<TableDump> {
    let layout = r.u8()?;
    let len = r.u64()? as usize;
    let l = r.u64()? as usize;
    if l > 1 << 20 {
        return Err(Error::Store(format!("implausible table count {l}")));
    }
    match layout {
        0 => {
            let mut tables = Vec::with_capacity(l);
            for _ in 0..l {
                let nb = r.u64()? as usize;
                if nb > r.remaining() {
                    return Err(Error::Store("corrupt bucket count".into()));
                }
                let mut buckets = Vec::with_capacity(nb);
                for _ in 0..nb {
                    let code = r.u32()?;
                    buckets.push((code, r.u32s()?));
                }
                tables.push(buckets);
            }
            Ok(TableDump::Vec { tables, len })
        }
        1 => {
            let mut tables = Vec::with_capacity(l);
            for _ in 0..l {
                let codes = r.u32s()?;
                let offsets = r.u32s()?;
                let live = r.u32s()?;
                let ids = r.u32s()?;
                let no = r.u64()? as usize;
                if no > r.remaining() {
                    return Err(Error::Store("corrupt overlay count".into()));
                }
                let mut overlay = Vec::with_capacity(no);
                for _ in 0..no {
                    let code = r.u32()?;
                    overlay.push((code, r.u32s()?));
                }
                tables.push(crate::lsh::tables::SealedTableDump {
                    codes,
                    offsets,
                    live,
                    ids,
                    overlay,
                });
            }
            Ok(TableDump::Sealed { tables, len })
        }
        other => Err(Error::Store(format!("unknown table layout tag {other}"))),
    }
}

/// One shard's persisted state.
pub(crate) struct ShardDump {
    pub(crate) rows: Vec<u32>,
    pub(crate) stored: Matrix,
    pub(crate) norms: Vec<f64>,
    pub(crate) tables: TableDump,
}

fn encode_shards<H: SrpHasher>(set: &ShardSet<H>) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(set.shard_count() as u32);
    for s in 0..set.shard_count() {
        let st = set.shard(s);
        w.u32s(&st.rows);
        w.matrix(&st.stored);
        w.f64s(&st.norms);
        encode_table_dump(&mut w, &st.tables.dump_view());
    }
    w.into_bytes()
}

fn decode_shards(bytes: &[u8]) -> Result<Vec<ShardDump>> {
    let mut r = Reader::new(bytes);
    let count = r.u32()? as usize;
    if count == 0 || count > 4096 {
        return Err(Error::Store(format!("shard count {count} out of 1..=4096")));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let rows = r.u32s()?;
        let stored = r.matrix()?;
        let norms = r.f64s()?;
        let tables = decode_table_dump(&mut r)?;
        out.push(ShardDump { rows, stored, norms, tables });
    }
    r.expect_end("shards section")?;
    Ok(out)
}

fn encode_stats(w: &mut Writer, st: &EstimatorStats) {
    w.u64(st.draws);
    w.u64(st.fallbacks);
    w.u64(st.cost.codes as u64);
    w.f64(st.cost.mults);
    w.u64(st.cost.randoms as u64);
    w.u64(st.cost.probes as u64);
    w.u64(st.migrations);
    w.u64(st.rebalances);
    w.f64(st.rebalance_secs);
    w.u64(st.prefetch_hits);
    w.u64(st.queue_stalls);
}

fn decode_stats(r: &mut Reader<'_>) -> Result<EstimatorStats> {
    Ok(EstimatorStats {
        draws: r.u64()?,
        fallbacks: r.u64()?,
        cost: SampleCost {
            codes: r.u64()? as usize,
            mults: r.f64()?,
            randoms: r.u64()? as usize,
            probes: r.u64()? as usize,
        },
        migrations: r.u64()?,
        rebalances: r.u64()?,
        rebalance_secs: r.f64()?,
        prefetch_hits: r.u64()?,
        queue_stalls: r.u64()?,
    })
}

fn encode_options(w: &mut Writer, opts: &LgdOptions) {
    match opts.weight_clip {
        Some(c) => {
            w.u8(1);
            w.f64(c);
        }
        None => {
            w.u8(0);
            w.f64(0.0);
        }
    }
    w.u64(opts.max_probes as u64);
    w.u64(opts.query_refresh as u64);
    w.u8(opts.mirror as u8);
    w.u8(opts.sealed as u8);
}

fn decode_options(r: &mut Reader<'_>) -> Result<LgdOptions> {
    let has_clip = r.u8()? != 0;
    let clip = r.f64()?;
    Ok(LgdOptions {
        weight_clip: if has_clip { Some(clip) } else { None },
        max_probes: r.u64()? as usize,
        query_refresh: r.u64()? as usize,
        mirror: r.u8()? != 0,
        sealed: r.u8()? != 0,
    })
}

fn encode_estimator<H: SrpHasher>(est: &ShardedLgdEstimator<'_, H>) -> Vec<u8> {
    let set = est.shard_set();
    let mut w = Writer::new();
    // live shard-set state
    w.u64(est.preprocessed().data.len() as u64);
    w.u8(est.options().mirror as u8);
    w.f64(set.threshold());
    w.u64(set.generation());
    let ss = set.stats();
    w.u64(ss.migrations);
    w.u64(ss.rebalances);
    w.f64(ss.rebalance_secs);
    // estimator state
    let (state, inc) = est.rng_raw();
    w.u128(state);
    w.u128(inc);
    encode_stats(&mut w, &est.raw_stats());
    encode_options(&mut w, est.options());
    // query cache (mid-window single-draw state)
    let (query, codes, age, norm) = est.cache_view().snapshot_parts();
    w.f32s(query);
    w.u64(codes.len() as u64);
    for c in codes {
        match c {
            Some(v) => {
                w.u8(1);
                w.u32(*v);
            }
            None => {
                w.u8(0);
                w.u32(0);
            }
        }
    }
    w.u64(age as u64);
    w.f64(norm);
    w.into_bytes()
}

/// Everything the estimator needs beyond the dataset and the hash family —
/// the decoded (but not yet wired) engine. Turn it into a live estimator
/// with [`restore_estimator`] / [`restore_boxed`].
pub struct EngineDump {
    pub(crate) shards: Vec<ShardDump>,
    pub(crate) n: usize,
    pub(crate) mirror: bool,
    pub(crate) threshold: f64,
    pub(crate) generation: u64,
    pub(crate) set_stats: ShardSetStats,
    pub(crate) rng: (u128, u128),
    pub(crate) stats: EstimatorStats,
    pub(crate) opts: LgdOptions,
    pub(crate) cache_query: Vec<f32>,
    pub(crate) cache_codes: Vec<Option<u32>>,
    pub(crate) cache_age: usize,
    pub(crate) cache_norm: f64,
}

fn decode_estimator(bytes: &[u8], shards: Vec<ShardDump>) -> Result<EngineDump> {
    let mut r = Reader::new(bytes);
    let n = r.u64()? as usize;
    let mirror = r.u8()? != 0;
    let threshold = r.f64()?;
    let generation = r.u64()?;
    let set_stats = ShardSetStats {
        migrations: r.u64()?,
        rebalances: r.u64()?,
        rebalance_secs: r.f64()?,
    };
    let state = r.u128()?;
    let inc = r.u128()?;
    let stats = decode_stats(&mut r)?;
    let opts = decode_options(&mut r)?;
    let cache_query = r.f32s()?;
    let nc = r.u64()? as usize;
    if nc.checked_mul(5).map(|b| b > r.remaining()).unwrap_or(true) {
        return Err(Error::Store("corrupt query-cache code count".into()));
    }
    let mut cache_codes = Vec::with_capacity(nc);
    for _ in 0..nc {
        let present = r.u8()? != 0;
        let v = r.u32()?;
        cache_codes.push(if present { Some(v) } else { None });
    }
    let cache_age = r.u64()? as usize;
    let cache_norm = r.f64()?;
    r.expect_end("estimator section")?;
    Ok(EngineDump {
        shards,
        n,
        mirror,
        threshold,
        generation,
        set_stats,
        rng: (state, inc),
        stats,
        opts,
        cache_query,
        cache_codes,
        cache_age,
        cache_norm,
    })
}

/// Optional training state riding along an engine snapshot: the model
/// weights, the global iteration counter and the optimizer's moments —
/// everything `lgd train --resume` needs to continue mid-run.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    /// Model parameters at the save point.
    pub theta: Vec<f32>,
    /// Iterations completed.
    pub iter: u64,
    /// Whole epochs completed (saves happen at epoch boundaries — the only
    /// legal points under the generation-counter contract, since sessions
    /// hold the estimator borrow).
    pub epochs_done: u32,
    /// Update rule the moments belong to.
    pub optimizer: OptimizerKind,
    /// Exported optimizer state.
    pub optim: OptimState,
}

fn optimizer_tag(kind: OptimizerKind) -> u8 {
    match kind {
        OptimizerKind::Sgd => 0,
        OptimizerKind::AdaGrad => 1,
        OptimizerKind::Adam => 2,
    }
}

fn encode_train(ts: &TrainState) -> Vec<u8> {
    let mut w = Writer::new();
    w.f32s(&ts.theta);
    w.u64(ts.iter);
    w.u32(ts.epochs_done);
    w.u8(optimizer_tag(ts.optimizer));
    w.u64(ts.optim.t);
    w.u32(ts.optim.slots.len() as u32);
    for s in &ts.optim.slots {
        w.f64s(s);
    }
    w.into_bytes()
}

fn decode_train(bytes: &[u8]) -> Result<TrainState> {
    let mut r = Reader::new(bytes);
    let theta = r.f32s()?;
    let iter = r.u64()?;
    let epochs_done = r.u32()?;
    let optimizer = match r.u8()? {
        0 => OptimizerKind::Sgd,
        1 => OptimizerKind::AdaGrad,
        2 => OptimizerKind::Adam,
        t => return Err(Error::Store(format!("unknown optimizer tag {t}"))),
    };
    let t = r.u64()?;
    let nslots = r.u32()? as usize;
    if nslots > 8 {
        return Err(Error::Store(format!("implausible optimizer slot count {nslots}")));
    }
    let slots = (0..nslots).map(|_| r.f64s()).collect::<Result<Vec<_>>>()?;
    r.expect_end("train section")?;
    Ok(TrainState { theta, iter, epochs_done, optimizer, optim: OptimState { t, slots } })
}

/// The health supervisor's verdict on the training state at save time,
/// persisted as an optional trailing section (`SectionKind::Health`).
/// Only the health-enabled save paths emit it, so snapshots written with
/// the supervisor off are byte-identical to pre-health builds; recovery in
/// [`recover_healthy`] mode skips stamped-unhealthy snapshots and treats
/// unstamped ones as healthy.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthStamp {
    /// The supervisor's verdict: is this state safe to roll back to?
    pub healthy: bool,
    /// Sentinel trips observed so far in the run that saved this.
    pub sentinel_trips: u64,
    /// Examples quarantined so far.
    pub quarantined: u64,
    /// Rollbacks performed so far.
    pub rollbacks: u64,
    /// Train loss at the save point (NaN when no eval had run yet).
    pub loss: f64,
}

fn encode_health(hs: &HealthStamp) -> Vec<u8> {
    let mut w = Writer::new();
    w.u8(hs.healthy as u8);
    w.u64(hs.sentinel_trips);
    w.u64(hs.quarantined);
    w.u64(hs.rollbacks);
    w.f64(hs.loss);
    w.into_bytes()
}

fn decode_health(bytes: &[u8]) -> Result<HealthStamp> {
    let mut r = Reader::new(bytes);
    let healthy = match r.u8()? {
        0 => false,
        1 => true,
        t => return Err(Error::Store(format!("unknown health verdict tag {t}"))),
    };
    let sentinel_trips = r.u64()?;
    let quarantined = r.u64()?;
    let rollbacks = r.u64()?;
    let loss = r.f64()?;
    r.expect_end("health section")?;
    Ok(HealthStamp { healthy, sentinel_trips, quarantined, rollbacks, loss })
}

/// Encode the full engine (plus optional training state) into a snapshot
/// image — the bytes [`save`] writes atomically.
pub fn snapshot_bytes<H: SnapshotHasher>(
    est: &ShardedLgdEstimator<'_, H>,
    train: Option<&TrainState>,
) -> Vec<u8> {
    snapshot_bytes_stamped(est, train, None)
}

/// [`snapshot_bytes`] with an optional health stamp. `None` produces bytes
/// identical to the unstamped encoder — the wire-format gate the existing
/// corruption/inspect tests pin down.
pub fn snapshot_bytes_stamped<H: SnapshotHasher>(
    est: &ShardedLgdEstimator<'_, H>,
    train: Option<&TrainState>,
    health: Option<&HealthStamp>,
) -> Vec<u8> {
    let hasher = est.shard_set().shard(0).tables.hasher();
    let mut hw = Writer::new();
    hw.u8(hasher.hasher_tag());
    hasher.encode_state(&mut hw);
    let mut sections = vec![
        (SectionKind::Meta, encode_meta(est, train.is_some())),
        (SectionKind::Data, encode_data(est.preprocessed())),
        (SectionKind::Hasher, hw.into_bytes()),
        (SectionKind::Shards, encode_shards(est.shard_set())),
        (SectionKind::Estimator, encode_estimator(est)),
    ];
    if let Some(ts) = train {
        sections.push((SectionKind::Train, encode_train(ts)));
    }
    if let Some(hs) = health {
        sections.push((SectionKind::Health, encode_health(hs)));
    }
    format::assemble(&sections)
}

/// Save the engine to `path` crash-safely (`*.tmp` + fsync + rename).
/// Returns the bytes written.
pub fn save<H: SnapshotHasher>(
    path: &Path,
    est: &ShardedLgdEstimator<'_, H>,
    train: Option<&TrainState>,
) -> Result<u64> {
    save_stamped(path, est, train, None)
}

/// [`save`] with an optional health stamp.
pub fn save_stamped<H: SnapshotHasher>(
    path: &Path,
    est: &ShardedLgdEstimator<'_, H>,
    train: Option<&TrainState>,
    health: Option<&HealthStamp>,
) -> Result<u64> {
    let bytes = snapshot_bytes_stamped(est, train, health);
    format::write_atomic(path, &bytes)?;
    Ok(bytes.len() as u64)
}

/// Path of rotation slot `slot` for base path `base`: slot 0 is the base
/// itself, slot `k` appends `.{k}` to the full file name
/// (`snap.lgdsnap` → `snap.lgdsnap.1`).
pub fn rotated_path(base: &Path, slot: usize) -> std::path::PathBuf {
    if slot == 0 {
        return base.to_path_buf();
    }
    let mut name = std::ffi::OsString::from(base.as_os_str());
    name.push(format!(".{slot}"));
    std::path::PathBuf::from(name)
}

/// [`save`] with crash-recovery rotation: before writing, shift the
/// existing generations one slot down (`base` → `base.1` → … →
/// `base.{keep-1}`, dropping the oldest), then write the new snapshot to
/// `base` atomically. A crash at any point leaves the previous generation
/// reachable: mid-shift, the renames are themselves atomic; mid-write,
/// `base` is missing or truncated but `base.1` holds the previous
/// generation intact — exactly what [`recover`] scans for. `keep` is
/// floored at 1 (plain [`save`] semantics, no rotation).
pub fn save_rotated<H: SnapshotHasher>(
    base: &Path,
    keep: usize,
    est: &ShardedLgdEstimator<'_, H>,
    train: Option<&TrainState>,
) -> Result<u64> {
    save_rotated_stamped(base, keep, est, train, None)
}

/// [`save_rotated`] with an optional health stamp.
pub fn save_rotated_stamped<H: SnapshotHasher>(
    base: &Path,
    keep: usize,
    est: &ShardedLgdEstimator<'_, H>,
    train: Option<&TrainState>,
    health: Option<&HealthStamp>,
) -> Result<u64> {
    let keep = keep.max(1);
    let oldest = rotated_path(base, keep - 1);
    if keep > 1 && oldest.exists() {
        std::fs::remove_file(&oldest)
            .map_err(|e| Error::Store(format!("rotate remove {}: {e}", oldest.display())))?;
    }
    for k in (0..keep.saturating_sub(1)).rev() {
        let from = rotated_path(base, k);
        if from.exists() {
            let to = rotated_path(base, k + 1);
            std::fs::rename(&from, &to).map_err(|e| {
                Error::Store(format!("rotate {} -> {}: {e}", from.display(), to.display()))
            })?;
        }
    }
    save_stamped(base, est, train, health)
}

/// What [`recover`] found.
pub struct Recovered {
    /// The newest valid snapshot.
    pub snap: LoadedSnapshot,
    /// The file it was loaded from.
    pub path: std::path::PathBuf,
    /// Its rotation slot (0 = the base path; > 0 = an older generation
    /// recovered after the newer ones failed verification).
    pub slot: usize,
    /// Slots skipped as missing, truncated, or corrupt before this one.
    pub skipped: usize,
}

/// Newest-valid-wins recovery scan over the rotation slots of `base`:
/// try `base`, then `base.1`, … up to `base.{keep-1}`, returning the
/// first snapshot that fully verifies (every CRC and structural
/// invariant) and how many newer slots had to be skipped. Errs only when
/// no slot holds a valid snapshot.
pub fn recover(base: &Path, keep: usize) -> Result<Recovered> {
    recover_with(base, keep, false)
}

/// [`recover`] in newest-*healthy*-wins mode: slots whose snapshot carries
/// a health stamp with `healthy = false` are skipped like corrupt ones, so
/// the trainer's rollback lands on the newest state the supervisor vouched
/// for. Unstamped snapshots (every save made with the supervisor off)
/// count as healthy.
pub fn recover_healthy(base: &Path, keep: usize) -> Result<Recovered> {
    recover_with(base, keep, true)
}

fn recover_with(base: &Path, keep: usize, require_healthy: bool) -> Result<Recovered> {
    let keep = keep.max(1);
    let mut last_err: Option<Error> = None;
    let mut skipped = 0usize;
    for slot in 0..keep {
        let path = rotated_path(base, slot);
        if !path.exists() {
            skipped += 1;
            continue;
        }
        match load(&path) {
            Ok(snap) => {
                if require_healthy && snap.health.as_ref().is_some_and(|h| !h.healthy) {
                    skipped += 1;
                    last_err = Some(Error::Store(format!(
                        "{} is stamped unhealthy",
                        path.display()
                    )));
                    continue;
                }
                return Ok(Recovered { snap, path, slot, skipped });
            }
            Err(e) => {
                skipped += 1;
                last_err = Some(e);
            }
        }
    }
    let what = if require_healthy { "healthy " } else { "" };
    Err(match last_err {
        Some(Error::Store(msg)) => Error::Store(format!(
            "no valid {what}snapshot among {keep} rotation slot(s) of {} (last error: {msg})",
            base.display()
        )),
        Some(e) => e,
        None => Error::Store(format!(
            "no snapshot found in any of the {keep} rotation slot(s) of {}",
            base.display()
        )),
    })
}

/// A fully decoded and verified snapshot. `pre` owns the dataset the
/// restored estimator borrows; `engine` + `hasher` feed
/// [`restore_estimator`] / [`restore_boxed`].
pub struct LoadedSnapshot {
    /// Summary metadata.
    pub meta: SnapshotMeta,
    /// The persisted preprocessed dataset.
    pub pre: Preprocessed,
    /// The persisted hash family (bit-exact, fresh counters).
    pub hasher: AnyHasher,
    /// The decoded engine state.
    pub engine: EngineDump,
    /// Training state, when the snapshot carries one.
    pub train: Option<TrainState>,
    /// Health stamp, when the snapshot carries one (health-enabled saves).
    pub health: Option<HealthStamp>,
}

/// Decode and verify a snapshot image (every CRC checked before any
/// decode; every structural invariant re-validated).
pub fn decode(bytes: &[u8]) -> Result<LoadedSnapshot> {
    let entries = format::parse(bytes)?;
    let meta = decode_meta(format::require_section(bytes, &entries, SectionKind::Meta)?)?;
    let pre = decode_data(format::require_section(bytes, &entries, SectionKind::Data)?)?;
    let mut hr = Reader::new(format::require_section(bytes, &entries, SectionKind::Hasher)?);
    let hasher = decode_hasher(&mut hr)?;
    hr.expect_end("hasher section")?;
    let shards = decode_shards(format::require_section(bytes, &entries, SectionKind::Shards)?)?;
    let est_bytes = format::require_section(bytes, &entries, SectionKind::Estimator)?;
    let engine = decode_estimator(est_bytes, shards)?;
    let train = match format::section(bytes, &entries, SectionKind::Train) {
        Some(b) => Some(decode_train(b)?),
        None => None,
    };
    let health = match format::section(bytes, &entries, SectionKind::Health) {
        Some(b) => Some(decode_health(b)?),
        None => None,
    };
    if meta.has_train != train.is_some() {
        return Err(Error::Store("meta/train-section presence disagree".into()));
    }
    if engine.n != pre.data.len() {
        return Err(Error::Store(format!(
            "engine covers {} examples but dataset has {}",
            engine.n,
            pre.data.len()
        )));
    }
    // Cross-section consistency: the summary the resume gate trusts must
    // agree with the sections actually restored. Per-section CRCs cannot
    // catch a writer bug or a reassembled file whose sections are
    // individually valid but mutually inconsistent — this does.
    let kind_name = hasher.kind().name();
    if meta.hasher != kind_name || meta.k != hasher.k() || meta.l != hasher.l() {
        return Err(Error::Store(format!(
            "meta section claims hasher {} (K={}, L={}) but the hasher section holds \
             {kind_name} (K={}, L={})",
            meta.hasher,
            meta.k,
            meta.l,
            hasher.k(),
            hasher.l()
        )));
    }
    if meta.shards != engine.shards.len() {
        return Err(Error::Store(format!(
            "meta section claims {} shard(s) but the shards section holds {}",
            meta.shards,
            engine.shards.len()
        )));
    }
    if meta.n != pre.data.len() || meta.mirror != engine.mirror {
        return Err(Error::Store(
            "meta section disagrees with the data/estimator sections".into(),
        ));
    }
    Ok(LoadedSnapshot { meta, pre, hasher, engine, train, health })
}

/// Load and verify a snapshot file.
pub fn load(path: &Path) -> Result<LoadedSnapshot> {
    decode(&format::read_file(path)?)
}

/// One section row of [`SnapshotInfo`].
#[derive(Debug, Clone)]
pub struct SectionInfo {
    /// Section name.
    pub name: &'static str,
    /// Payload bytes.
    pub bytes: usize,
    /// Stored (and verified) CRC-32.
    pub crc: u32,
}

/// What `lgd snapshot inspect` prints: the verified container layout plus
/// the summary metadata.
#[derive(Debug, Clone)]
pub struct SnapshotInfo {
    /// Total file bytes.
    pub file_bytes: usize,
    /// Container format version.
    pub version: u32,
    /// Verified sections in file order.
    pub sections: Vec<SectionInfo>,
    /// Summary metadata.
    pub meta: SnapshotMeta,
}

/// Verify a snapshot file and report its layout without decoding the bulk
/// sections (the CRCs of *all* sections are still checked).
pub fn inspect(path: &Path) -> Result<SnapshotInfo> {
    let bytes = format::read_file(path)?;
    let entries = format::parse(&bytes)?;
    let meta = decode_meta(format::require_section(&bytes, &entries, SectionKind::Meta)?)?;
    Ok(SnapshotInfo {
        file_bytes: bytes.len(),
        version: format::VERSION,
        sections: entries
            .iter()
            .map(|e| SectionInfo { name: e.kind.name(), bytes: e.len, crc: e.crc })
            .collect(),
        meta,
    })
}

/// Wire a decoded engine back into a live [`ShardedLgdEstimator`] borrowing
/// `pre` (normally the snapshot's own `pre`). Performs **zero** table-build
/// work and **zero** hash invocations — tables are reassembled from their
/// dumps, membership indices are recomputed (pure integer work), and the
/// RNG/cache/counters continue exactly where the saved engine stopped.
pub fn restore_estimator<'a, H: SnapshotHasher + Clone>(
    pre: &'a Preprocessed,
    hasher: H,
    engine: EngineDump,
) -> Result<ShardedLgdEstimator<'a, H>> {
    let n = engine.n;
    if n != pre.data.len() {
        return Err(Error::Store(format!(
            "engine covers {n} examples but dataset has {}",
            pre.data.len()
        )));
    }
    let hd = pre.hashed.cols();
    if hasher.dim() != hd {
        return Err(Error::Store(format!(
            "hasher dim {} but hash space is {hd}-dimensional",
            hasher.dim()
        )));
    }
    let mut owned = vec![false; 2 * n];
    let mut base_rows = 0usize;
    let mut mirror_rows = 0usize;
    let mut shards: Vec<ShardTables<H>> = Vec::with_capacity(engine.shards.len());
    for (s, d) in engine.shards.into_iter().enumerate() {
        let rows_n = d.rows.len();
        if d.stored.rows() != rows_n || d.norms.len() != rows_n {
            return Err(Error::Store(format!(
                "shard {s}: {rows_n} row ids, {} stored rows, {} norms",
                d.stored.rows(),
                d.norms.len()
            )));
        }
        if rows_n > 0 && d.stored.cols() != hd {
            return Err(Error::Store(format!(
                "shard {s}: stored width {} but hash space is {hd}",
                d.stored.cols()
            )));
        }
        for &r in &d.rows {
            let r = r as usize;
            if r >= 2 * n {
                return Err(Error::Store(format!("shard {s}: virtual row id {r} out of range")));
            }
            if owned[r] {
                return Err(Error::Store(format!("virtual row id {r} owned by two shards")));
            }
            owned[r] = true;
            if r < n {
                base_rows += 1;
            } else {
                mirror_rows += 1;
            }
        }
        let tables = TableStore::from_dump(hasher.clone(), d.tables)?;
        if tables.len() != rows_n {
            return Err(Error::Store(format!(
                "shard {s}: tables index {} points but shard stores {rows_n}",
                tables.len()
            )));
        }
        shards.push(ShardTables {
            rows: d.rows,
            stored: d.stored,
            norms: d.norms,
            tables,
            build_secs: 0.0,
        });
    }
    if mirror_rows != if engine.mirror { base_rows } else { 0 } {
        return Err(Error::Store(format!(
            "mirror flag disagrees with the shard layout ({base_rows} base, \
             {mirror_rows} mirror rows)"
        )));
    }
    if !engine.cache_query.is_empty() {
        if engine.cache_codes.len() != hasher.l() {
            return Err(Error::Store(format!(
                "query cache holds {} codes but the family has {} tables",
                engine.cache_codes.len(),
                hasher.l()
            )));
        }
        // A wrong-width cached query would panic (or silently mis-hash)
        // inside the lazy code fill on the first draw — reject at load.
        if engine.cache_query.len() != hd {
            return Err(Error::Store(format!(
                "query cache holds a {}-dimensional query but the hash space is {hd}",
                engine.cache_query.len()
            )));
        }
    }
    let mut set = ShardSet::from_shards(shards, n, engine.mirror, engine.threshold);
    set.restore_counters(engine.generation, engine.set_stats);
    let rng = Pcg64::from_raw_state(engine.rng.0, engine.rng.1);
    let cache = QueryCache::from_parts(
        engine.cache_query,
        engine.cache_codes,
        engine.cache_age,
        engine.cache_norm,
    );
    Ok(ShardedLgdEstimator::from_restored(pre, set, rng, engine.stats, cache, engine.opts))
}

struct BoxedRestore<'a> {
    pre: &'a Preprocessed,
    engine: EngineDump,
}

impl<'a> HasherVisitor for BoxedRestore<'a> {
    type Out = Result<Box<dyn GradientEstimator + 'a>>;

    fn visit<H>(self, hasher: H) -> Self::Out
    where
        H: SnapshotHasher + Clone + 'static,
    {
        Ok(Box::new(restore_estimator(self.pre, hasher, self.engine)?))
    }
}

/// Restore into a boxed [`GradientEstimator`] — the serving-side entry
/// point (`lgd snapshot load`, `examples/warm_start.rs`) where the concrete
/// hash family does not matter.
pub fn restore_boxed<'a>(
    hasher: AnyHasher,
    pre: &'a Preprocessed,
    engine: EngineDump,
) -> Result<Box<dyn GradientEstimator + 'a>> {
    hasher.visit(BoxedRestore { pre, engine })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::draw_engine::{run_session, DrawEngineConfig};
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::estimator::WeightedDraw;

    fn setup(n: usize, d: usize, seed: u64) -> Preprocessed {
        let ds = SynthSpec::power_law("snap", n, d, seed).generate().unwrap();
        preprocess(ds, &PreprocessOptions::default()).unwrap()
    }

    fn mutate(est: &mut ShardedLgdEstimator<'_, DenseSrp>, pre: &Preprocessed) {
        for id in 0..20 {
            assert!(est.remove(id).unwrap());
        }
        for id in 0..8 {
            est.shard_set_mut().insert_into(0, id, &pre.hashed).unwrap();
        }
    }

    /// The headline contract: across layouts and shard counts, with live
    /// overlay mutations and a warm mid-window query cache, a restored
    /// engine replays the saved engine's exact stream — single draws and
    /// batches — with zero table-build hashing on load.
    #[test]
    fn snapshot_roundtrip_replays_draw_stream_exactly() {
        let pre = setup(120, 8, 11);
        let hd = pre.hashed.cols();
        let theta: Vec<f32> = (0..8).map(|j| 0.03 * (j as f32 - 3.0)).collect();
        for sealed in [true, false] {
            for shards in [1usize, 3] {
                let opts = LgdOptions { sealed, ..LgdOptions::default() };
                let mut a = ShardedLgdEstimator::new(
                    &pre,
                    DenseSrp::new(hd, 3, 8, 13),
                    15,
                    opts,
                    shards,
                )
                .unwrap();
                mutate(&mut a, &pre);
                // warm the cache mid-window so refresh timing is part of
                // the persisted state
                for _ in 0..7 {
                    a.draw(&theta);
                }
                let bytes = snapshot_bytes(&a, None);
                let snap = decode(&bytes).unwrap();
                assert_eq!(snap.meta.shards, shards);
                assert_eq!(snap.meta.sealed, sealed);
                assert!(!snap.meta.has_train);
                let handle = snap.hasher.clone();
                let mut b = restore_boxed(snap.hasher, &pre, snap.engine).unwrap();
                // zero-rebuild proof: restoring hashed nothing at all
                let s0 = handle.hash_stats();
                assert_eq!(s0.code_calls, 0, "restore must not hash rows (table build)");
                assert_eq!(s0.fused_calls, 0, "restore must not hash the query");
                for i in 0..300 {
                    assert_eq!(
                        a.draw(&theta),
                        b.draw(&theta),
                        "sealed={sealed} shards={shards}: draw {i} diverged after restore"
                    );
                }
                let (mut xa, mut xb) = (Vec::new(), Vec::new());
                for round in 0..4 {
                    a.draw_batch(&theta, 24, &mut xa);
                    b.draw_batch(&theta, 24, &mut xb);
                    assert_eq!(xa, xb, "batch round {round} diverged after restore");
                }
                // the draw path never needs per-row hashing
                assert_eq!(handle.hash_stats().code_calls, 0);
                assert_eq!(a.stats().fallbacks, b.stats().fallbacks);
            }
        }
    }

    /// The same identity through the async draw engine: a restored engine's
    /// sessions replay the saved engine's sessions, in both worker modes.
    #[test]
    fn snapshot_roundtrip_replays_async_sessions() {
        let pre = setup(150, 8, 31);
        let hd = pre.hashed.cols();
        let theta = vec![0.04f32; 8];
        for workers in [1usize, 2] {
            let mut a = ShardedLgdEstimator::new(
                &pre,
                DenseSrp::new(hd, 3, 10, 33),
                35,
                LgdOptions::default(),
                2,
            )
            .unwrap();
            mutate(&mut a, &pre);
            let bytes = snapshot_bytes(&a, None);
            let snap = decode(&bytes).unwrap();
            let AnyHasher::Dense(h) = snap.hasher else { panic!("dense family expected") };
            let mut b = restore_estimator(&pre, h, snap.engine).unwrap();
            assert_eq!(b.shard_set().generation(), a.shard_set().generation());
            let cfg = DrawEngineConfig { workers, queue_depth: 32, ..Default::default() };
            let (mut ga, mut gb): (Vec<WeightedDraw>, Vec<WeightedDraw>) =
                (Vec::new(), Vec::new());
            run_session(&mut a, &cfg, &theta, 16, 5, |_, d| {
                ga.extend(d.iter().copied());
                true
            })
            .unwrap();
            run_session(&mut b, &cfg, &theta, 16, 5, |_, d| {
                gb.extend(d.iter().copied());
                true
            })
            .unwrap();
            assert_eq!(ga, gb, "workers={workers}: async session diverged after restore");
        }
    }

    /// Sparse and quadratic families restore bit-exact (codes *and*
    /// calibrated probabilities), not just the dense reference family.
    #[test]
    fn snapshot_roundtrip_other_hash_families() {
        let pre = setup(80, 6, 51);
        let hd = pre.hashed.cols();
        let theta = vec![0.05f32; 6];
        // sparse
        let mut a = ShardedLgdEstimator::new(
            &pre,
            SparseSrp::new(hd, 3, 6, 0.3, 53),
            55,
            LgdOptions::default(),
            2,
        )
        .unwrap();
        let snap = decode(&snapshot_bytes(&a, None)).unwrap();
        assert_eq!(snap.meta.hasher, "sparse");
        let mut b = restore_boxed(snap.hasher, &pre, snap.engine).unwrap();
        for i in 0..200 {
            assert_eq!(a.draw(&theta), b.draw(&theta), "sparse draw {i} diverged");
        }
        // quadratic
        let mut a = ShardedLgdEstimator::new(
            &pre,
            QuadraticSrp::new(hd, 3, 6, 0.2, 57),
            59,
            LgdOptions::default(),
            2,
        )
        .unwrap();
        let snap = decode(&snapshot_bytes(&a, None)).unwrap();
        assert_eq!(snap.meta.hasher, "quadratic");
        let mut b = restore_boxed(snap.hasher, &pre, snap.engine).unwrap();
        for i in 0..200 {
            assert_eq!(a.draw(&theta), b.draw(&theta), "quadratic draw {i} diverged");
        }
    }

    /// Training state (θ, iteration, optimizer moments) rides along and
    /// round-trips exactly.
    #[test]
    fn snapshot_train_state_roundtrips() {
        let pre = setup(60, 6, 71);
        let hd = pre.hashed.cols();
        let est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 6, 73),
            75,
            LgdOptions::default(),
            1,
        )
        .unwrap();
        let ts = TrainState {
            theta: vec![0.25, -0.5, 1.5, 0.0, -2.0, 0.125],
            iter: 1234,
            epochs_done: 3,
            optimizer: OptimizerKind::Adam,
            optim: OptimState {
                t: 1234,
                slots: vec![vec![0.1, -0.2, 0.3], vec![0.01, 0.02, 0.03]],
            },
        };
        let bytes = snapshot_bytes(&est, Some(&ts));
        let snap = decode(&bytes).unwrap();
        assert!(snap.meta.has_train);
        assert_eq!(snap.train, Some(ts));
    }

    /// The health stamp rides along as its own trailing section: it
    /// round-trips exactly, a `None` stamp leaves the image byte-identical
    /// to the unstamped encoder (the wire-format invariance gate), and
    /// `recover_healthy` skips stamped-unhealthy generations while plain
    /// `recover` does not.
    #[test]
    fn snapshot_health_stamp_roundtrips_and_gates_recovery() {
        let pre = setup(40, 5, 121);
        let hd = pre.hashed.cols();
        let est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 4, 123),
            125,
            LgdOptions::default(),
            2,
        )
        .unwrap();
        let ts = |iter: u64| TrainState {
            theta: vec![0.5; 5],
            iter,
            epochs_done: 0,
            optimizer: OptimizerKind::Sgd,
            optim: OptimState { t: 0, slots: vec![] },
        };
        // None stamp == legacy bytes, bit for bit
        assert_eq!(
            snapshot_bytes_stamped(&est, Some(&ts(7)), None),
            snapshot_bytes(&est, Some(&ts(7))),
            "a None stamp must not change the wire format"
        );
        // roundtrip
        let hs = HealthStamp {
            healthy: true,
            sentinel_trips: 2,
            quarantined: 1,
            rollbacks: 1,
            loss: 0.125,
        };
        let snap = decode(&snapshot_bytes_stamped(&est, Some(&ts(7)), Some(&hs))).unwrap();
        assert_eq!(snap.health, Some(hs.clone()));
        assert_eq!(snap.train.unwrap().iter, 7);
        let snap = decode(&snapshot_bytes(&est, None)).unwrap();
        assert_eq!(snap.health, None, "unstamped snapshots decode with no stamp");
        // recovery: newest is stamped unhealthy, middle is stamped healthy,
        // oldest is unstamped (pre-health save)
        let dir = std::env::temp_dir().join("lgd-store-health");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("hs.lgdsnap");
        for slot in 0..3 {
            let p = rotated_path(&base, slot);
            if p.exists() {
                std::fs::remove_file(&p).unwrap();
            }
        }
        save_rotated_stamped(&base, 3, &est, Some(&ts(1)), None).unwrap();
        save_rotated_stamped(&base, 3, &est, Some(&ts(2)), Some(&hs)).unwrap();
        let bad = HealthStamp { healthy: false, ..hs.clone() };
        save_rotated_stamped(&base, 3, &est, Some(&ts(3)), Some(&bad)).unwrap();
        let rec = recover(&base, 3).unwrap();
        assert_eq!(rec.snap.train.unwrap().iter, 3, "plain recover ignores stamps");
        let rec = recover_healthy(&base, 3).unwrap();
        assert_eq!(rec.slot, 1);
        assert_eq!(rec.skipped, 1);
        assert_eq!(rec.snap.train.unwrap().iter, 2, "newest healthy generation wins");
        // unstamped counts as healthy too
        std::fs::remove_file(rotated_path(&base, 1)).unwrap();
        let rec = recover_healthy(&base, 3).unwrap();
        assert_eq!(rec.slot, 2);
        assert_eq!(rec.snap.train.unwrap().iter, 1);
        // every remaining slot unhealthy => clean Store error
        std::fs::remove_file(rotated_path(&base, 2)).unwrap();
        let err = recover_healthy(&base, 3).unwrap_err();
        assert!(
            matches!(&err, Error::Store(m) if m.contains("healthy")),
            "want a 'no healthy snapshot' error, got {err}"
        );
        std::fs::remove_file(&base).unwrap();
    }

    /// Corruption gate: every single-byte flip in the header/section table
    /// is rejected, and so is every sampled payload flip and truncation —
    /// always as `Error::Store`, never a panic.
    #[test]
    fn snapshot_corruption_rejected_at_every_position() {
        let pre = setup(24, 4, 91);
        let hd = pre.hashed.cols();
        let mut est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 2, 3, 93),
            95,
            LgdOptions::default(),
            2,
        )
        .unwrap();
        let theta = vec![0.1f32; 4];
        for _ in 0..5 {
            est.draw(&theta);
        }
        let ts = TrainState {
            theta: vec![0.0; 4],
            iter: 24,
            epochs_done: 1,
            optimizer: OptimizerKind::Sgd,
            optim: OptimState { t: 24, slots: Vec::new() },
        };
        let bytes = snapshot_bytes(&est, Some(&ts));
        decode(&bytes).unwrap();
        // exhaustive over the header + section table (the satellite's
        // specific requirement)...
        let header_end = 24 + 6 * 32 + 4;
        assert!(bytes.len() > header_end);
        for pos in 0..header_end {
            let mut c = bytes.clone();
            c[pos] ^= 0x20;
            match decode(&c) {
                Err(Error::Store(_)) => {}
                Err(e) => panic!("header flip at {pos}: wrong error kind {e}"),
                Ok(_) => panic!("header flip at byte {pos} was not detected"),
            }
        }
        // ...and sampled across every payload (section CRCs catch all
        // single-byte errors; sampling keeps the test fast)
        let mut pos = header_end;
        while pos < bytes.len() {
            let mut c = bytes.clone();
            c[pos] ^= 0xFF;
            assert!(
                matches!(decode(&c), Err(Error::Store(_))),
                "payload flip at byte {pos} was not detected"
            );
            pos += 13;
        }
        // truncations
        for cut in [0usize, 7, 23, header_end - 1, header_end, bytes.len() - 1] {
            assert!(
                matches!(decode(&bytes[..cut]), Err(Error::Store(_))),
                "truncation at {cut} accepted"
            );
        }
    }

    /// Inspect verifies the container and reports layout + metadata.
    #[test]
    fn snapshot_inspect_reports_sections() {
        let pre = setup(40, 5, 101);
        let hd = pre.hashed.cols();
        let est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 4, 103),
            105,
            LgdOptions::default(),
            2,
        )
        .unwrap();
        let dir = std::env::temp_dir().join("lgd-store-inspect");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("probe.lgdsnap");
        let written = save(&path, &est, None).unwrap();
        let info = inspect(&path).unwrap();
        assert_eq!(info.file_bytes as u64, written);
        assert_eq!(info.version, format::VERSION);
        let names: Vec<&str> = info.sections.iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["meta", "data", "hasher", "shards", "estimator"]);
        assert_eq!(info.meta.n, 40);
        assert_eq!(info.meta.shards, 2);
        assert!(info.meta.mirror);
        std::fs::remove_file(&path).unwrap();
    }

    /// Rotation + newest-valid-wins recovery: `save_rotated` keeps the
    /// last `keep` generations, `recover` loads the newest slot that
    /// verifies and skips corrupt ones. (The crash-injected mid-save
    /// variants live in `tests/chaos.rs`.)
    #[test]
    fn rotation_keeps_generations_and_recovery_skips_corruption() {
        let pre = setup(50, 5, 111);
        let hd = pre.hashed.cols();
        let est = ShardedLgdEstimator::new(
            &pre,
            DenseSrp::new(hd, 3, 4, 113),
            115,
            LgdOptions::default(),
            2,
        )
        .unwrap();
        let dir = std::env::temp_dir().join("lgd-store-rotate");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("rot.lgdsnap");
        for slot in 0..3 {
            let p = rotated_path(&base, slot);
            if p.exists() {
                std::fs::remove_file(&p).unwrap();
            }
        }
        let ts = |iter: u64| TrainState {
            theta: vec![0.5; 5],
            iter,
            epochs_done: 0,
            optimizer: OptimizerKind::Sgd,
            optim: OptimState { t: 0, slots: vec![] },
        };
        // three generations under keep = 3: newest at the base, oldest at .2
        for iter in [1u64, 2, 3] {
            save_rotated(&base, 3, &est, Some(&ts(iter))).unwrap();
        }
        for (slot, want) in [(0usize, 3u64), (1, 2), (2, 1)] {
            let snap = load(&rotated_path(&base, slot)).unwrap();
            assert_eq!(snap.train.unwrap().iter, want, "slot {slot}");
        }
        let rec = recover(&base, 3).unwrap();
        assert_eq!(rec.slot, 0);
        assert_eq!(rec.skipped, 0);
        assert_eq!(rec.snap.train.unwrap().iter, 3, "newest generation wins");
        // corrupt the newest (truncate): recovery falls back to slot 1
        let full = std::fs::read(&base).unwrap();
        std::fs::write(&base, &full[..full.len() / 2]).unwrap();
        let rec = recover(&base, 3).unwrap();
        assert_eq!(rec.slot, 1);
        assert_eq!(rec.skipped, 1);
        assert_eq!(rec.snap.train.unwrap().iter, 2);
        assert_eq!(rec.path, rotated_path(&base, 1));
        // keep = 1 scans only the (corrupt) base and fails cleanly
        assert!(recover(&base, 1).is_err());
        // nothing on disk at all: a clean Store error, not a panic
        for slot in 0..3 {
            let p = rotated_path(&base, slot);
            if p.exists() {
                std::fs::remove_file(&p).unwrap();
            }
        }
        assert!(matches!(recover(&base, 3), Err(Error::Store(_))));
    }
}
