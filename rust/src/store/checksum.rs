//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the per-section
//! integrity check of the snapshot format.
//!
//! Zero dependencies: the 256-entry table is built at compile time with a
//! `const fn`. CRC-32 detects every single-bit and single-byte error (and
//! all burst errors up to 32 bits), which is exactly the guarantee the
//! snapshot loader leans on: any one-byte corruption of a section payload
//! fails its CRC and surfaces as a clean [`crate::core::error::Error::Store`].

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (init 0xFFFF_FFFF, final XOR — the zlib/PNG variant).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The canonical check value of CRC-32/ISO-HDLC.
    #[test]
    fn known_vectors() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    /// Every single-byte corruption of a buffer changes the checksum — the
    /// property the snapshot loader's corruption guarantee rests on.
    #[test]
    fn single_byte_flips_always_detected() {
        let base: Vec<u8> = (0..257u16).map(|i| (i * 31 % 251) as u8).collect();
        let want = crc32(&base);
        for pos in 0..base.len() {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut c = base.clone();
                c[pos] ^= flip;
                assert_ne!(crc32(&c), want, "flip {flip:#x} at {pos} not detected");
            }
        }
    }
}
