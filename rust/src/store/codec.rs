//! Primitive binary codec for the snapshot format: a growable little-endian
//! [`Writer`] and a bounds-checked [`Reader`].
//!
//! Every `Reader` method returns `Result`: running off the end of a buffer —
//! a truncated file, a corrupted length prefix — is always a clean
//! [`Error::Store`], never a panic or an out-of-bounds read. Length prefixes
//! are validated against the bytes actually remaining before any allocation,
//! so a flipped length byte cannot trigger a multi-gigabyte `Vec` reserve.

use crate::core::error::{Error, Result};
use crate::core::matrix::Matrix;

/// Growable little-endian byte sink.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Fresh empty writer.
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    /// Consume into the written bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// One raw byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Little-endian u32.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Little-endian u64.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Little-endian u128 (PRNG state).
    pub fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// f32 as its IEEE-754 bit pattern (bit-exact round trip).
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// f64 as its IEEE-754 bit pattern (bit-exact round trip).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, v: &[u8]) {
        self.u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str_(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Length-prefixed u32 slice.
    pub fn u32s(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u32(x);
        }
    }

    /// Length-prefixed f32 slice (bit patterns).
    pub fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }

    /// Length-prefixed f64 slice (bit patterns).
    pub fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }

    /// Row-major matrix: rows, cols, then the flat f32 buffer at *logical*
    /// widths — the in-memory lane padding never reaches disk, so these
    /// bytes are identical to what pre-aligned-layout versions wrote.
    pub fn matrix(&mut self, m: &Matrix) {
        self.u64(m.rows() as u64);
        self.u64(m.cols() as u64);
        self.u64((m.rows() * m.cols()) as u64);
        for r in 0..m.rows() {
            for &x in m.row(r) {
                self.f32(x);
            }
        }
    }
}

/// Bounds-checked little-endian reader over a borrowed buffer.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Read from the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Store(format!(
                "truncated {what}: need {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// One raw byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1, "u8")?[0])
    }

    /// Little-endian u32.
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, "u32")?.try_into().unwrap()))
    }

    /// Little-endian u64.
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, "u64")?.try_into().unwrap()))
    }

    /// Little-endian u128.
    pub fn u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16, "u128")?.try_into().unwrap()))
    }

    /// f32 from its bit pattern.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// f64 from its bit pattern.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Length prefix for `elem_bytes`-wide elements, validated against the
    /// remaining buffer *before* any allocation.
    fn len_prefix(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let n = self.u64()? as usize;
        if n.checked_mul(elem_bytes).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(Error::Store(format!(
                "corrupt {what} length {n}: exceeds {} remaining bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.len_prefix(1, "byte buffer")?;
        self.take(n, "byte buffer")
    }

    /// Length-prefixed UTF-8 string.
    pub fn str_(&mut self) -> Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec())
            .map_err(|_| Error::Store("string payload is not valid UTF-8".into()))
    }

    /// Length-prefixed u32 slice.
    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.len_prefix(4, "u32 slice")?;
        (0..n).map(|_| self.u32()).collect()
    }

    /// Length-prefixed f32 slice.
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix(4, "f32 slice")?;
        (0..n).map(|_| self.f32()).collect()
    }

    /// Length-prefixed f64 slice.
    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.len_prefix(8, "f64 slice")?;
        (0..n).map(|_| self.f64()).collect()
    }

    /// Row-major matrix (validated shape).
    pub fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let data = self.f32s()?;
        if rows.checked_mul(cols) != Some(data.len()) {
            return Err(Error::Store(format!(
                "matrix shape {rows}x{cols} does not match buffer of {}",
                data.len()
            )));
        }
        Matrix::from_vec(rows, cols, data).map_err(|e| Error::Store(e.to_string()))
    }

    /// Assert the payload was consumed exactly — trailing garbage inside a
    /// CRC-valid section still indicates a format mismatch.
    pub fn expect_end(&self, what: &str) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::Store(format!(
                "{what}: {} unexpected trailing bytes",
                self.remaining()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip_bit_exact() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.u128(0x0123_4567_89AB_CDEF_0011_2233_4455_6677);
        w.f32(-0.0);
        w.f64(f64::NAN);
        w.str_("größe");
        w.u32s(&[1, 2, 3]);
        w.f32s(&[1.5, -2.25]);
        w.f64s(&[3.141592653589793]);
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        w.matrix(&m);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.u128().unwrap(), 0x0123_4567_89AB_CDEF_0011_2233_4455_6677);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.f64().unwrap().is_nan(), "NaN must survive bit-exact");
        assert_eq!(r.str_().unwrap(), "größe");
        assert_eq!(r.u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.f32s().unwrap(), vec![1.5, -2.25]);
        assert_eq!(r.f64s().unwrap(), vec![3.141592653589793]);
        assert_eq!(r.matrix().unwrap(), m);
        r.expect_end("test").unwrap();
    }

    #[test]
    fn matrix_wire_format_is_unchanged_by_aligned_storage() {
        // Hand-build the bytes a pre-aligned-layout writer emitted:
        // u64 rows, u64 cols, then a length-prefixed flat f32 buffer. A
        // ragged width (21 = LANES + 5) forces in-memory padding.
        let (rows, cols) = (3usize, 21usize);
        let flat: Vec<f32> = (0..rows * cols).map(|i| (i as f32 - 31.5) * 0.25).collect();
        let mut legacy = Vec::new();
        legacy.extend_from_slice(&(rows as u64).to_le_bytes());
        legacy.extend_from_slice(&(cols as u64).to_le_bytes());
        legacy.extend_from_slice(&((rows * cols) as u64).to_le_bytes());
        for &x in &flat {
            legacy.extend_from_slice(&x.to_bits().to_le_bytes());
        }

        // Today's writer must emit the identical bytes (logical widths only)…
        let m = Matrix::from_vec(rows, cols, flat).unwrap();
        let mut w = Writer::new();
        w.matrix(&m);
        assert_eq!(w.into_bytes(), legacy, "matrix wire format drifted");

        // …and a legacy (PR-5-era) payload must load into the aligned
        // layout with the zero-tail invariant intact.
        let mut r = Reader::new(&legacy);
        let loaded = r.matrix().unwrap();
        r.expect_end("legacy matrix").unwrap();
        assert_eq!(loaded, m);
        assert!(loaded.zero_tail_ok(), "snapshot load must re-establish zero tails");
    }

    #[test]
    fn truncation_and_bad_lengths_error_cleanly() {
        let mut w = Writer::new();
        w.u64(100); // claims a 100-element u32 slice that is not there
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(matches!(r.u32s(), Err(Error::Store(_))));
        // plain truncation
        let mut r = Reader::new(&bytes[..3]);
        assert!(matches!(r.u64(), Err(Error::Store(_))));
        // absurd length prefix must not allocate
        let mut w = Writer::new();
        w.u64(u64::MAX);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(matches!(r.f64s(), Err(Error::Store(_))));
        // mismatched matrix shape
        let mut w = Writer::new();
        w.u64(2);
        w.u64(2);
        w.f32s(&[1.0, 2.0, 3.0]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(matches!(r.matrix(), Err(Error::Store(_))));
        // trailing bytes detected
        let mut w = Writer::new();
        w.u8(1);
        w.u8(2);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        r.u8().unwrap();
        assert!(r.expect_end("tail").is_err());
    }
}
