//! Training-loop health supervisor: the NaN/divergence sentinels and the
//! bookkeeping behind quarantine and rollback-to-last-good recovery.
//!
//! The trainer ([`crate::coordinator::trainer`]) owns the recovery *acts* —
//! evicting poisoned examples through the shard set's generation-flip
//! machinery and restoring θ/optimizer/engine state from the newest
//! health-stamped snapshot. This module owns the *judgement*: when is a
//! batch gradient, a parameter vector or a loss evaluation evidence that
//! the run has gone off the rails?
//!
//! Determinism contract: the sentinels only **read** the quantities the
//! loop already computed — the accumulated batch gradient, θ after the
//! optimizer step, the train loss at an eval point. They never draw from
//! an RNG, never touch the estimator and never perturb a float, so a run
//! with the supervisor enabled but never tripped is bit-for-bit identical
//! to a run without it (gated by the integration suite).

use std::collections::VecDeque;

use crate::config::spec::HealthConfig;
use crate::core::numerics::all_finite;

/// Why a sentinel tripped — everything the trainer's rollback state
/// machine needs to recover.
#[derive(Debug, Clone, PartialEq)]
pub enum Trip {
    /// The accumulated batch gradient went non-finite. `poisoned` holds
    /// the example ids per-example attribution blamed (possibly empty if
    /// the corruption was not attributable to a single input — e.g. an
    /// overflow of the weighted sum itself).
    Grad {
        /// Example ids whose individual contribution is non-finite.
        poisoned: Vec<usize>,
    },
    /// θ went non-finite or its norm exploded past the windowed bound.
    Theta(String),
    /// The train loss went non-finite or spiked past the windowed bound
    /// for `patience` consecutive evals.
    Loss(String),
}

impl Trip {
    /// One-line description for errors and logs.
    pub fn describe(&self) -> String {
        match self {
            Trip::Grad { poisoned } => format!(
                "non-finite batch gradient (attributed to {} example(s): {:?})",
                poisoned.len(),
                poisoned
            ),
            Trip::Theta(m) => format!("parameter sentinel tripped: {m}"),
            Trip::Loss(m) => format!("loss sentinel tripped: {m}"),
        }
    }
}

/// Counters the supervisor accumulates over a run — surfaced on
/// [`crate::coordinator::trainer::TrainOutcome`] and gated at zero on the
/// clean benchmark path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealthReport {
    /// Gradient-sentinel trips (non-finite batch gradient).
    pub grad_trips: u64,
    /// θ-sentinel trips (non-finite or exploded parameters).
    pub theta_trips: u64,
    /// Loss-sentinel trips (non-finite or spiking train loss).
    pub loss_trips: u64,
    /// Examples evicted from the engine by poisoned-input quarantine.
    pub quarantined: u64,
    /// Rollbacks to a health-stamped snapshot performed.
    pub rollbacks: u64,
}

impl HealthReport {
    /// Total sentinel trips of any kind.
    pub fn sentinel_trips(&self) -> u64 {
        self.grad_trips + self.theta_trips + self.loss_trips
    }
}

/// The armed sentinels: windowed baselines for the divergence detectors
/// plus the run's counters. One per training run, owned by the loop
/// context when `health.enabled` is set.
pub struct HealthMonitor {
    cfg: HealthConfig,
    /// Run counters (the trainer also bumps `quarantined`/`rollbacks`).
    pub report: HealthReport,
    /// Trailing ‖θ‖ observations (healthy steps only).
    theta_norms: VecDeque<f64>,
    /// Trailing train-loss observations (healthy evals only).
    losses: VecDeque<f64>,
    /// Consecutive spiking evals so far.
    strikes: u32,
}

impl HealthMonitor {
    /// Arm the sentinels with the run's thresholds.
    pub fn new(cfg: &HealthConfig) -> Self {
        HealthMonitor {
            cfg: cfg.clone(),
            report: HealthReport::default(),
            theta_norms: VecDeque::new(),
            losses: VecDeque::new(),
            strikes: 0,
        }
    }

    /// Record a gradient trip (the trainer already holds the attribution).
    pub fn trip_grad(&mut self, poisoned: Vec<usize>) -> Trip {
        self.report.grad_trips += 1;
        Trip::Grad { poisoned }
    }

    /// Observe θ after an optimizer step. Trips on any non-finite
    /// parameter, or when ‖θ‖ exceeds `theta_factor ×` the smallest norm
    /// in the trailing window (floored at 1.0 so a near-zero start cannot
    /// trip the ratio). Healthy observations enter the window; a tripping
    /// one does not, so the baseline stays untainted for the resumed run.
    pub fn observe_theta(&mut self, theta: &[f32]) -> Option<Trip> {
        if !all_finite(theta) {
            self.report.theta_trips += 1;
            return Some(Trip::Theta("θ contains a non-finite parameter".into()));
        }
        let norm = theta.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
        if let Some(base) = self.theta_norms.iter().copied().fold(None, |m: Option<f64>, v| {
            Some(m.map_or(v, |m| m.min(v)))
        }) {
            let bound = self.cfg.theta_factor * base.max(1.0);
            if norm > bound {
                self.report.theta_trips += 1;
                return Some(Trip::Theta(format!(
                    "‖θ‖ = {norm:.3e} exceeds {:.1} × windowed baseline {base:.3e}",
                    self.cfg.theta_factor
                )));
            }
        }
        self.theta_norms.push_back(norm);
        while self.theta_norms.len() > self.cfg.window {
            self.theta_norms.pop_front();
        }
        None
    }

    /// Observe the train loss at an eval point. Trips immediately on
    /// NaN/Inf; trips on divergence when the loss exceeds `spike_factor ×`
    /// the windowed minimum for `patience` consecutive evals. Spiking
    /// evals never enter the window (they would drag the baseline up
    /// toward the divergence they are meant to catch).
    pub fn observe_loss(&mut self, loss: f64) -> Option<Trip> {
        if !loss.is_finite() {
            self.report.loss_trips += 1;
            return Some(Trip::Loss(format!("train loss is {loss}")));
        }
        let min = self.losses.iter().copied().fold(None, |m: Option<f64>, v| {
            Some(m.map_or(v, |m| m.min(v)))
        });
        if let Some(min) = min {
            if loss > self.cfg.spike_factor * min {
                self.strikes += 1;
                if self.strikes >= self.cfg.patience {
                    self.report.loss_trips += 1;
                    return Some(Trip::Loss(format!(
                        "train loss {loss:.3e} > {:.1} × windowed minimum {min:.3e} \
                         for {} consecutive eval(s)",
                        self.cfg.spike_factor, self.strikes
                    )));
                }
                return None;
            }
        }
        self.strikes = 0;
        self.losses.push_back(loss);
        while self.losses.len() > self.cfg.window {
            self.losses.pop_front();
        }
        None
    }

    /// Reset the windowed baselines after a rollback: the loop state
    /// jumped back to an earlier point, so observations from the doomed
    /// segment no longer describe the stream being supervised. Counters
    /// are kept — they describe the run, not the segment.
    pub fn rollback_reset(&mut self) {
        self.theta_norms.clear();
        self.losses.clear();
        self.strikes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            enabled: true,
            window: 4,
            spike_factor: 10.0,
            patience: 2,
            theta_factor: 100.0,
            rollback_lr_factor: 1.0,
            max_rollbacks: 3,
        }
    }

    #[test]
    fn healthy_streams_never_trip() {
        let mut m = HealthMonitor::new(&cfg());
        for i in 0..200 {
            let t = vec![0.1 + 0.001 * i as f32; 8];
            assert!(m.observe_theta(&t).is_none(), "step {i}");
            assert!(m.observe_loss(1.0 / (1.0 + i as f64)).is_none(), "eval {i}");
        }
        assert_eq!(m.report, HealthReport::default());
        assert_eq!(m.report.sentinel_trips(), 0);
    }

    #[test]
    fn non_finite_theta_trips_immediately() {
        let mut m = HealthMonitor::new(&cfg());
        assert!(m.observe_theta(&[0.5, 0.5]).is_none());
        let trip = m.observe_theta(&[0.5, f32::NAN]).unwrap();
        assert!(matches!(trip, Trip::Theta(_)));
        assert_eq!(m.report.theta_trips, 1);
    }

    #[test]
    fn theta_norm_explosion_trips_against_windowed_baseline() {
        let mut m = HealthMonitor::new(&cfg());
        // window fills with ~unit norms; baseline floor is 1.0
        for _ in 0..4 {
            assert!(m.observe_theta(&[1.0, 0.0, 0.0]).is_none());
        }
        // 50× is under theta_factor = 100 — healthy, enters the window
        assert!(m.observe_theta(&[50.0, 0.0, 0.0]).is_none());
        // 200× the min-of-window (still 1.0) trips
        let trip = m.observe_theta(&[200.0, 0.0, 0.0]).unwrap();
        assert!(matches!(trip, Trip::Theta(_)), "{trip:?}");
        assert_eq!(m.report.theta_trips, 1);
        // the tripping norm did not enter the window: the same vector
        // trips again (baseline unchanged)
        assert!(m.observe_theta(&[200.0, 0.0, 0.0]).is_some());
        // tiny norms never trip via the 1.0 floor
        let mut m = HealthMonitor::new(&cfg());
        assert!(m.observe_theta(&[1e-8, 0.0]).is_none());
        assert!(m.observe_theta(&[1e-3, 0.0]).is_none(), "1e5× a tiny norm is under the floor");
    }

    #[test]
    fn loss_nan_trips_immediately_and_spike_respects_patience() {
        let mut m = HealthMonitor::new(&cfg());
        assert!(m.observe_loss(f64::NAN).is_some());
        assert_eq!(m.report.loss_trips, 1);
        // patience = 2: one spike is a strike, the second consecutive trips
        let mut m = HealthMonitor::new(&cfg());
        for _ in 0..3 {
            assert!(m.observe_loss(1.0).is_none());
        }
        assert!(m.observe_loss(50.0).is_none(), "first spike is a strike, not a trip");
        assert!(m.observe_loss(60.0).is_some(), "second consecutive spike trips");
        assert_eq!(m.report.loss_trips, 1);
        // a healthy eval between spikes resets the strike counter
        let mut m = HealthMonitor::new(&cfg());
        for _ in 0..3 {
            assert!(m.observe_loss(1.0).is_none());
        }
        assert!(m.observe_loss(50.0).is_none());
        assert!(m.observe_loss(1.1).is_none(), "recovery resets strikes");
        assert!(m.observe_loss(55.0).is_none(), "strike count restarted");
        assert_eq!(m.report.loss_trips, 0);
    }

    #[test]
    fn windows_are_bounded_and_rollback_reset_clears_baselines() {
        let mut m = HealthMonitor::new(&cfg());
        // old tiny losses age out of the window = 4, so a slow upward
        // drift never trips
        for i in 0..50 {
            let v = 1.0 + i as f64;
            assert!(m.observe_loss(v).is_none(), "drift eval {i}");
        }
        // after a reset the next observations rebuild the baseline from
        // scratch: a value 10^4 times the pre-reset baseline is fine
        m.rollback_reset();
        assert!(m.observe_loss(5e5).is_none());
        let grad = m.trip_grad(vec![3, 17]);
        assert!(matches!(&grad, Trip::Grad { poisoned } if poisoned == &vec![3, 17]));
        assert_eq!(m.report.grad_trips, 1);
        assert!(grad.describe().contains("2 example(s)"));
    }
}
