//! Lightweight metrics registry: named counters and timers shared across
//! pipeline stages and the trainer. (No external metrics crates offline —
//! this is the substrate.)

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::core::stats::Welford;

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    timers: Mutex<BTreeMap<String, Welford>>,
}

impl Metrics {
    /// Fresh registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter by `v`.
    pub fn count(&self, name: &str, v: u64) {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(v, Ordering::Relaxed);
    }

    /// Read a counter (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a duration sample (seconds).
    pub fn observe(&self, name: &str, secs: f64) {
        let mut m = self.timers.lock().unwrap();
        m.entry(name.to_string()).or_default().push(secs);
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Timer summary: (count, mean_secs, total_secs).
    pub fn timer(&self, name: &str) -> Option<(u64, f64, f64)> {
        let m = self.timers.lock().unwrap();
        m.get(name).map(|w| (w.count(), w.mean(), w.mean() * w.count() as f64))
    }

    /// Render a human-readable report of everything recorded.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, w) in self.timers.lock().unwrap().iter() {
            out.push_str(&format!(
                "timer   {k}: n={} mean={:.6}s total={:.3}s\n",
                w.count(),
                w.mean(),
                w.mean() * w.count() as f64
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("a", 2);
        m.count("a", 3);
        assert_eq!(m.counter("a"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        m.observe("t", 0.5);
        m.observe("t", 1.5);
        let (n, mean, total) = m.timer("t").unwrap();
        assert_eq!(n, 2);
        assert!((mean - 1.0).abs() < 1e-12);
        assert!((total - 2.0).abs() < 1e-12);
        assert!(m.timer("none").is_none());
    }

    #[test]
    fn time_closure_returns_value() {
        let m = Metrics::new();
        let v = m.time("f", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.timer("f").unwrap().0, 1);
    }

    #[test]
    fn concurrent_counting() {
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.count("c", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("c"), 8000);
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.count("x", 1);
        m.observe("y", 0.1);
        let r = m.report();
        assert!(r.contains("counter x = 1"));
        assert!(r.contains("timer   y"));
    }
}
