//! Pipeline/trainer metrics facade over the unified telemetry registry
//! (`core::telemetry::registry`).
//!
//! The old implementation locked a whole `BTreeMap` per `count()` call,
//! defeating the inner `AtomicU64`. Now `Metrics` is a thin view over a
//! [`Registry`]: the name-keyed map is consulted only when a metric is
//! first registered (or enumerated), and hot paths can hold a
//! pre-registered [`CounterHandle`]/[`HistogramHandle`] via
//! [`Metrics::counter_handle`] / [`Metrics::timer_handle`] — every
//! increment through a handle is a single relaxed atomic op.
//!
//! `Metrics::new()` is backed by a private registry (isolated, as the
//! pipeline tests expect); [`Metrics::shared`] is backed by the
//! process-global registry so a build report also lands on the wire
//! surface (`METRICS` op, `lgd stats`). Names are kind-unique per
//! registry: using one name as both a counter and a timer panics.

use std::sync::Arc;
use std::time::Instant;

use crate::core::telemetry::registry::{
    CounterHandle, HistogramHandle, Registry, SampleValue,
};

/// Thread-safe metrics facade. Cloning shares the underlying registry.
#[derive(Clone)]
pub struct Metrics {
    /// `None` = the process-global registry.
    reg: Option<Arc<Registry>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    /// Fresh facade over a private registry.
    pub fn new() -> Self {
        Metrics { reg: Some(Arc::new(Registry::new())) }
    }

    /// Facade over the process-global registry (what the `METRICS` wire op
    /// and `lgd stats` read).
    pub fn shared() -> Self {
        Metrics { reg: None }
    }

    /// The backing registry.
    pub fn registry(&self) -> &Registry {
        self.reg.as_deref().unwrap_or_else(Registry::global)
    }

    /// Pre-register a counter and return its lock-free handle — the hot
    /// path API (one relaxed `fetch_add` per increment, no map lookup).
    pub fn counter_handle(&self, name: &str) -> CounterHandle {
        self.registry().counter(name)
    }

    /// Pre-register a duration histogram and return its lock-free handle.
    pub fn timer_handle(&self, name: &str) -> HistogramHandle {
        self.registry().histogram(name)
    }

    /// Increment a counter by `v`. Slow path (registers on first use);
    /// hold a [`Metrics::counter_handle`] in loops.
    pub fn count(&self, name: &str, v: u64) {
        self.registry().counter(name).add(v);
    }

    /// Read a counter (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.registry().counter_value(name)
    }

    /// Record a duration sample (seconds) into the named histogram.
    pub fn observe(&self, name: &str, secs: f64) {
        self.registry().histogram(name).observe_secs(secs);
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Timer summary: (count, mean_secs, total_secs). `None` when the
    /// timer is absent or empty.
    pub fn timer(&self, name: &str) -> Option<(u64, f64, f64)> {
        self.registry()
            .snapshot()
            .into_iter()
            .find(|s| s.labels.is_empty() && s.name == name)
            .and_then(|s| match s.value {
                SampleValue::Histogram { sum_secs, count, .. } if count > 0 => {
                    Some((count, sum_secs / count as f64, sum_secs))
                }
                _ => None,
            })
    }

    /// Render a human-readable report of everything recorded: counters
    /// first, then gauges, then timers — each section name-sorted.
    pub fn report(&self) -> String {
        let snap = self.registry().snapshot();
        let key = |s: &crate::core::telemetry::registry::MetricSample| {
            if s.labels.is_empty() {
                s.name.clone()
            } else {
                format!("{}{{{}}}", s.name, s.labels)
            }
        };
        let mut out = String::new();
        for s in &snap {
            if let SampleValue::Counter(v) = s.value {
                out.push_str(&format!("counter {} = {v}\n", key(s)));
            }
        }
        for s in &snap {
            if let SampleValue::Gauge(v) = s.value {
                out.push_str(&format!("gauge   {} = {v}\n", key(s)));
            }
        }
        for s in &snap {
            if let SampleValue::Histogram { sum_secs, count, .. } = &s.value {
                let mean = if *count > 0 { sum_secs / *count as f64 } else { 0.0 };
                out.push_str(&format!(
                    "timer   {}: n={count} mean={mean:.6}s total={sum_secs:.3}s\n",
                    key(s)
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("a", 2);
        m.count("a", 3);
        assert_eq!(m.counter("a"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        m.observe("t", 0.5);
        m.observe("t", 1.5);
        let (n, mean, total) = m.timer("t").unwrap();
        assert_eq!(n, 2);
        assert!((mean - 1.0).abs() < 1e-12);
        assert!((total - 2.0).abs() < 1e-12);
        assert!(m.timer("none").is_none());
    }

    #[test]
    fn time_closure_returns_value() {
        let m = Metrics::new();
        let v = m.time("f", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.timer("f").unwrap().0, 1);
    }

    #[test]
    fn concurrent_counting() {
        let m = Metrics::new();
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.count("c", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("c"), 8000);
    }

    #[test]
    fn handles_bypass_the_registration_lock() {
        let m = Metrics::new();
        let c = m.counter_handle("hot");
        let t = m.timer_handle("lat");
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = c.clone();
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    c.inc();
                    t.observe_ns(i * 100);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("hot"), 8000);
        assert_eq!(m.timer("lat").unwrap().0, 8000);
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.count("x", 1);
        m.observe("y", 0.1);
        m.registry().gauge("z").set(2.5);
        let r = m.report();
        assert!(r.contains("counter x = 1"));
        assert!(r.contains("timer   y"));
        assert!(r.contains("gauge   z = 2.5"));
    }

    #[test]
    fn shared_facades_see_the_global_registry() {
        let a = Metrics::shared();
        let b = Metrics::shared();
        // Unique name: global registry is shared across the test binary.
        a.count("metrics.test.shared_facade", 3);
        assert!(b.counter("metrics.test.shared_facade") >= 3);
    }

    #[test]
    fn clones_share_the_private_registry() {
        let m = Metrics::new();
        let m2 = m.clone();
        m.count("c", 1);
        m2.count("c", 1);
        assert_eq!(m.counter("c"), 2);
    }
}
