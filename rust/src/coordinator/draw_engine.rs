//! Asynchronous pipelined draw engine: per-shard sampler workers, bounded
//! draw queues, and overlap of sampling with gradient compute.
//!
//! The paper's wall-clock argument (§2.2) needs the sampler to cost no more
//! per iteration than uniform sampling. The synchronous
//! [`ShardedLgdEstimator`] already makes each draw cheap, but the trainer
//! still *stalls* on every `draw_batch` while shards probe on the caller's
//! thread. This module retires that stall: a session pins the shard set,
//! hashes the query **once** (fused `codes_all`), and keeps bounded queues
//! of pre-drawn candidates warm so the next batch is (usually) ready the
//! moment the previous gradient step finishes.
//!
//! Two worker modes, selected by [`DrawEngineConfig::workers`]:
//!
//! * `workers == 1` — **replay mode**: one sampler thread runs the *exact*
//!   synchronous batch algorithm ([`mixture_draw_batch`], the same function
//!   `draw_batch` delegates to) against the estimator's own RNG, pushing
//!   assembled batches into a bounded queue. The draw stream is identical
//!   to the synchronous path draw-for-draw by construction (tested), and
//!   the RNG is handed back so synchronous draws can continue the stream
//!   seamlessly after the session.
//! * `workers >= 2` — **per-shard mode**: every non-empty shard gets a
//!   dedicated sampler worker that continuously pre-draws Algorithm-1
//!   candidates through the sealed/coded fast path into its own bounded
//!   ring buffer (its RNG stream is derived per shard, so the assembled
//!   stream is deterministic under a fixed seed regardless of thread
//!   timing). A mixer thread assembles exact shard-mixture batches: each
//!   draw picks a shard `∝ R_s` (the multinomial allocation), pops that
//!   shard's next candidate, and attaches the exact mixture probability
//!   `p = (R_s/R)·p_shard` — Theorem-1 unbiasedness is preserved
//!   draw-for-draw, and the 50k-draw statistical gate runs against this
//!   path in CI (`mixture_probabilities_exact_async`).
//!
//! **Staleness contract.** Candidates are tagged with the shard set's
//! [`generation`](crate::coordinator::pipeline::ShardSet::generation) at
//! draw time; the mixer refuses to serve a candidate from an older
//! generation. Sessions borrow the estimator mutably, so mutations
//! (`insert`/`remove`/`rebalance_to`) can only happen *between* sessions —
//! each session boundary is a queue flush plus a fused re-hash of the
//! (possibly new) query, and the generation tag makes the "never serve
//! dead rows" invariant checkable end-to-end rather than merely implied by
//! the borrow checker.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::thread;

use crate::coordinator::pipeline::ShardSet;
use crate::core::error::{Error, Result};
use crate::core::rng::{Pcg64, Rng};
use crate::estimator::lgd::LgdOptions;
use crate::estimator::sharded::{
    mixture_draw_batch, mixture_weigh, shard_sampler, uniform_fallback_from,
    ShardedLgdEstimator,
};
use crate::estimator::{EstimatorStats, WeightedDraw};
use crate::lsh::sampler::{SampleCost, Sampled};
use crate::lsh::srp::SrpHasher;
use crate::lsh::tables::BucketRead;
use crate::testkit::faults;

/// Tuning knobs of the async draw engine (`lsh.async_workers`,
/// `lsh.queue_depth`).
#[derive(Debug, Clone)]
pub struct DrawEngineConfig {
    /// Sampler parallelism. 0 is *not* valid here — it selects the
    /// synchronous path upstream and [`run_session`] rejects it. 1 =
    /// replay mode (single sampler thread, stream identical to the
    /// synchronous path); >= 2 = one dedicated worker per non-empty shard.
    pub workers: usize,
    /// Bound on pre-drawn work, measured in draws: each per-shard
    /// candidate queue holds at most this many candidates, and at most
    /// `max(1, queue_depth / m)` assembled batches wait for the consumer.
    pub queue_depth: usize,
}

impl Default for DrawEngineConfig {
    fn default() -> Self {
        DrawEngineConfig { workers: 1, queue_depth: 1024 }
    }
}

/// What one [`run_session`] did.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SessionReport {
    /// Batches delivered to the consumer.
    pub batches: usize,
    /// Draws assembled by the sampling side (>= batches · m when the
    /// consumer bailed early; prefetch keeps running until shutdown).
    pub draws: u64,
    /// Batches that were ready the moment the consumer asked.
    pub prefetch_hits: u64,
    /// Batch requests that had to wait on an empty queue.
    pub queue_stalls: u64,
    /// Candidates discarded because their generation tag was stale
    /// (structurally 0 while sessions hold the estimator borrow; the
    /// counter exists so the invariant is *observed*, not assumed).
    pub stale_drops: u64,
    /// Effective sampler worker threads the session ran.
    pub workers: usize,
    /// Shard-set generation the session served.
    pub generation: u64,
}

/// Bounded MPSC ring buffer on `Mutex` + `Condvar` — the zero-dep draw
/// queue of the engine. Blocking `push`/`pop` with close semantics, plus
/// hit/stall counters on the pop side (did the consumer wait?).
///
/// **Poison recovery.** Every lock/wait site recovers from
/// [`PoisonError`] instead of unwrapping: the ring state is a plain
/// `VecDeque` plus counters — no operation leaves it mid-update across a
/// panic point — so a producer or consumer that dies while holding the
/// mutex must not convert an isolated thread failure into a panic cascade
/// through every other session thread. The dead thread's `CloseGuard`
/// closes the queue during unwind and [`run_session`] surfaces a clean
/// [`Error::Pipeline`] from the join instead.
pub struct DrawQueue<T> {
    inner: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// Lock `m`, treating a poisoned mutex as live: the protected queue state
/// is always structurally valid (see [`DrawQueue`] docs), so the poison
/// flag carries no information the close/join protocol doesn't already
/// deliver.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

struct QueueState<T> {
    buf: VecDeque<T>,
    cap: usize,
    closed: bool,
    hits: u64,
    stalls: u64,
}

impl<T> DrawQueue<T> {
    /// New queue holding at most `cap` items (floored at 1).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        DrawQueue {
            inner: Mutex::new(QueueState {
                buf: VecDeque::with_capacity(cap),
                cap,
                closed: false,
                hits: 0,
                stalls: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking push. Returns false (dropping `v`) if the queue is closed.
    pub fn push(&self, v: T) -> bool {
        if faults::should_fail(faults::QUEUE_PUSH) {
            // A producer dying mid-push: panic holding the mutex so the
            // poison-recovery path downstream is the real one.
            let _poisoner = self.inner.lock();
            panic!("failpoint: {}", faults::QUEUE_PUSH);
        }
        let mut g = plock(&self.inner);
        while g.buf.len() >= g.cap && !g.closed {
            g = self.not_full.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
        if g.closed {
            return false;
        }
        g.buf.push_back(v);
        drop(g);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop. Returns `None` once the queue is closed *and*
    /// drained. Counts a prefetch hit when an item was already waiting and
    /// a stall when this call had to block first.
    pub fn pop(&self) -> Option<T> {
        if faults::should_fail(faults::QUEUE_POP) {
            // The consumer observing a dead/closed queue: early `None`
            // (never a panic — pop runs on consumer/main threads).
            return None;
        }
        let mut g = plock(&self.inner);
        let mut waited = false;
        loop {
            if let Some(v) = g.buf.pop_front() {
                if waited {
                    g.stalls += 1;
                } else {
                    g.hits += 1;
                }
                drop(g);
                self.not_full.notify_one();
                return Some(v);
            }
            if g.closed {
                return None;
            }
            waited = true;
            g = self.not_empty.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Close the queue: producers' `push` returns false, consumers drain
    /// the buffer then get `None`. Idempotent.
    pub fn close(&self) {
        let mut g = plock(&self.inner);
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently buffered.
    pub fn len(&self) -> usize {
        plock(&self.inner).buf.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (prefetch hits, stalls) observed on the pop side so far.
    pub fn counters(&self) -> (u64, u64) {
        let g = plock(&self.inner);
        (g.hits, g.stalls)
    }
}

/// Closes a queue when dropped — shutdown stays correct on every exit
/// path, including panics in the consumer's callback or the mixer.
struct CloseGuard<'q, T>(&'q DrawQueue<T>);

impl<T> Drop for CloseGuard<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// One pre-drawn Algorithm-1 candidate from a shard worker.
struct Candidate {
    gen: u64,
    res: Sampled,
}

/// One assembled shard-mixture batch.
struct TaggedBatch {
    gen: u64,
    draws: Vec<WeightedDraw>,
}

/// Serve one mixture draw from shard `s`'s pre-drawn candidate stream:
/// pop the next live-generation candidate (stale tags are dropped and
/// counted, never served) and attach the exact mixture probability
/// `p = (R_s/R)·p_shard`; an exhausted probe — or a dead worker — becomes
/// the same membership-aware uniform fallback as the synchronous path.
#[allow(clippy::too_many_arguments)]
fn serve_candidate<H: SrpHasher>(
    set: &ShardSet<H>,
    opts: &LgdOptions,
    n: usize,
    s: usize,
    gen: u64,
    q: &DrawQueue<Candidate>,
    rng: &mut Pcg64,
    st: &mut EstimatorStats,
    stale: &mut u64,
) -> WeightedDraw {
    let res = loop {
        match q.pop() {
            Some(c) if c.gen == gen => break Some(c.res),
            Some(_) => *stale += 1,
            None => break None,
        }
    };
    match res {
        Some(Sampled::Hit(d)) => mixture_weigh(set, s, &d, opts, n),
        Some(Sampled::Exhausted { .. }) | None => {
            uniform_fallback_from(set, n, rng, &mut st.fallbacks)
        }
    }
}

/// Pop batches off `q` and hand them to the consumer callback until
/// `steps` batches were delivered, the callback asks to stop, or the
/// producing side died. Closes `q` on every exit path (unblocking
/// producers) and returns the number of batches consumed.
fn consume_batches<F>(
    q: &DrawQueue<TaggedBatch>,
    gen: u64,
    steps: usize,
    on_batch: &mut F,
) -> usize
where
    F: FnMut(usize, &[WeightedDraw]) -> bool,
{
    let guard = CloseGuard(q);
    let mut consumed = 0usize;
    for step in 0..steps {
        match q.pop() {
            Some(b) => {
                debug_assert_eq!(b.gen, gen, "stale batch crossed a session boundary");
                let go = on_batch(step, &b.draws);
                consumed += 1;
                if !go {
                    break;
                }
            }
            None => break,
        }
    }
    drop(guard);
    consumed
}

/// Run one pipelined serving session: `steps` batches of `m` draws against
/// the query built from `theta`, assembled ahead of the consumer by the
/// engine's sampler threads. `on_batch(step, draws)` runs on the calling
/// thread — while it computes (the gradient step, in the trainer), the
/// next batch is already being assembled. Return `false` from the callback
/// to stop early.
///
/// The query is frozen for the whole session (hashed once, fused); the
/// estimator's RNG and counters are taken over for the session and handed
/// back merged, so `est.stats()` stays exact — per-worker costs are
/// accumulated locally and merged on join, never racing. With
/// `cfg.workers == 1` the delivered stream is draw-for-draw identical to
/// calling the synchronous `draw_batch` the same number of times.
///
/// **Early-stop caveat:** the stream/RNG-continuation guarantees hold for
/// *fully consumed* sessions (the normal case — the trainer stops early
/// only when aborting on an error). After a callback-initiated stop, the
/// sampler side may have assembled up to a queue's worth of extra batches
/// before noticing the close; the handed-back RNG position and the draw
/// counters reflect all *assembled* work, which can depend on thread
/// timing. `SessionReport::draws` vs `batches · m` exposes the overshoot.
pub fn run_session<H, F>(
    est: &mut ShardedLgdEstimator<'_, H>,
    cfg: &DrawEngineConfig,
    theta: &[f32],
    m: usize,
    steps: usize,
    mut on_batch: F,
) -> Result<SessionReport>
where
    H: SrpHasher,
    F: FnMut(usize, &[WeightedDraw]) -> bool,
{
    if cfg.workers == 0 {
        return Err(Error::Config(
            "draw engine needs async workers >= 1 (0 selects the synchronous path)".into(),
        ));
    }
    if m == 0 || steps == 0 {
        return Ok(SessionReport::default());
    }
    let parts = est.engine_parts();
    let set = parts.set;
    let opts = &parts.opts;
    let n = parts.pre.data.len();
    let gen = set.generation();

    // Fused query hash, once per session — every worker probes through
    // these codes; no thread ever re-hashes. A drained set skips the hash
    // (the mixer serves membership-aware uniform fallbacks instead).
    let mut query = Vec::new();
    let mut codes = Vec::new();
    let mut session_cost = SampleCost::default();
    if set.total_rows() > 0 {
        parts.pre.query(theta, &mut query);
        let hasher = set.shard(0).tables.hasher();
        hasher.codes_all(&query, &mut codes);
        session_cost.codes += hasher.l();
        session_cost.mults += hasher.mults_all();
    }
    let query = &query;
    let codes = &codes;

    let batch_depth = (cfg.queue_depth / m).max(1);
    let batch_q: DrawQueue<TaggedBatch> = DrawQueue::new(batch_depth);

    let report = if cfg.workers == 1 {
        // --- Replay mode: one sampler thread, the exact sync stream. ---
        let prod_rng = parts.rng.clone();
        let (prod_res, consumed) = thread::scope(|scope| {
            let q = &batch_q;
            let producer = scope.spawn(move || {
                let _guard = CloseGuard(q);
                let mut rng = prod_rng;
                let mut st = EstimatorStats::default();
                let mut scratch = Vec::new();
                for _ in 0..steps {
                    let mut out = Vec::with_capacity(m);
                    mixture_draw_batch(
                        set,
                        n,
                        opts,
                        codes,
                        query,
                        m,
                        &mut rng,
                        &mut st,
                        &mut scratch,
                        &mut out,
                    );
                    if !q.push(TaggedBatch { gen, draws: out }) {
                        break;
                    }
                }
                (rng, st)
            });
            let consumed = consume_batches(&batch_q, gen, steps, &mut on_batch);
            (producer.join(), consumed)
        });
        let (rng_back, prod_stats) =
            prod_res.map_err(|_| Error::Pipeline("draw-engine sampler thread panicked".into()))?;
        *parts.rng = rng_back;
        let draws = prod_stats.draws;
        parts.stats.merge_draws(&prod_stats);
        SessionReport { batches: consumed, draws, stale_drops: 0, workers: 1, ..Default::default() }
    } else {
        // --- Per-shard mode: a dedicated sampler worker per non-empty
        // shard feeds its own bounded queue; the mixer multinomially
        // assembles exact mixture batches from the queues. ---
        let session_seed = parts.rng.next_u64();
        let mixer_rng = parts.rng.clone();
        let shard_count = set.shard_count();
        // Per-shard candidate capacity: the configured bound, but never
        // more than the whole session's demand — workers free-run until
        // their queue closes, so the capacity is also the bound on
        // over-drawn (wasted) candidates per shard at session end.
        let cand_cap = cfg.queue_depth.min(steps * m);
        let cand_qs: Vec<DrawQueue<Candidate>> =
            (0..shard_count).map(|_| DrawQueue::new(cand_cap)).collect();
        let cand_qs = &cand_qs;
        let (mixer_res, worker_res, consumed) = thread::scope(|scope| {
            let bq = &batch_q;
            let mut workers = Vec::new();
            for s in 0..shard_count {
                if set.shard(s).stored.rows() == 0 {
                    continue;
                }
                workers.push(scope.spawn(move || {
                    let _guard = CloseGuard(&cand_qs[s]);
                    if faults::should_fail_at(faults::WORKER_START, s as u64) {
                        // Die while holding the queue mutex so it is
                        // genuinely poisoned — the recovery path under
                        // test is the real one, not a simulation.
                        let _poisoner = cand_qs[s].inner.lock();
                        panic!("failpoint: {} shard {s}", faults::WORKER_START);
                    }
                    let sampler = shard_sampler(set.shard(s), opts);
                    // Per-shard RNG stream derived from (session, shard):
                    // candidate streams — and therefore the assembled
                    // mixture — are deterministic under a fixed seed no
                    // matter how threads interleave or how many workers
                    // the knob requested.
                    let mut rng = Pcg64::new(session_seed, 0x5748_5244 ^ s as u64);
                    let mut cost = SampleCost::default();
                    loop {
                        let res = sampler.sample_coded(codes, query, &mut rng, &mut cost);
                        if !cand_qs[s].push(Candidate { gen, res }) {
                            break;
                        }
                    }
                    cost
                }));
            }
            let mixer = scope.spawn(move || {
                let _bguard = CloseGuard(bq);
                let cguards: Vec<CloseGuard<'_, Candidate>> =
                    cand_qs.iter().map(CloseGuard).collect();
                let mut rng = mixer_rng;
                let mut st = EstimatorStats::default();
                let mut stale = 0u64;
                for _ in 0..steps {
                    let mut out = Vec::with_capacity(m);
                    for _ in 0..m {
                        if set.total_rows() == 0 {
                            out.push(uniform_fallback_from(set, n, &mut rng, &mut st.fallbacks));
                            continue;
                        }
                        // Multinomial shard pick ∝ stored rows — the same
                        // allocation rule as the synchronous mixture.
                        let s = if shard_count > 1 {
                            let r = rng.index(set.total_rows());
                            st.cost.randoms += 1;
                            set.shard_of_row(r)
                        } else {
                            0
                        };
                        let d = serve_candidate(
                            set, opts, n, s, gen, &cand_qs[s], &mut rng, &mut st, &mut stale,
                        );
                        out.push(d);
                    }
                    st.draws += m as u64;
                    if !bq.push(TaggedBatch { gen, draws: out }) {
                        break;
                    }
                }
                drop(cguards);
                (rng, st, stale)
            });
            let consumed = consume_batches(&batch_q, gen, steps, &mut on_batch);
            let mixer_res = mixer.join();
            let worker_res: Vec<thread::Result<SampleCost>> =
                workers.into_iter().map(|w| w.join()).collect();
            (mixer_res, worker_res, consumed)
        });
        let (rng_back, mixer_stats, stale) =
            mixer_res.map_err(|_| Error::Pipeline("draw-engine mixer thread panicked".into()))?;
        *parts.rng = rng_back;
        let mut spawned = 0usize;
        let mut prefetch_cost = SampleCost::default();
        for r in worker_res {
            let c = r.map_err(|_| Error::Pipeline("draw-engine shard worker panicked".into()))?;
            prefetch_cost.absorb(&c);
            spawned += 1;
        }
        let draws = mixer_stats.draws;
        parts.stats.merge_draws(&mixer_stats);
        // Prefetch work (including over-drawn candidates the session never
        // consumed) is real sampling cost — merged per worker, no racing.
        parts.stats.cost.absorb(&prefetch_cost);
        SessionReport {
            batches: consumed,
            draws,
            stale_drops: stale,
            workers: spawned,
            ..Default::default()
        }
    };

    parts.stats.cost.absorb(&session_cost);
    let (hits, stalls) = batch_q.counters();
    parts.stats.prefetch_hits += hits;
    parts.stats.queue_stalls += stalls;
    Ok(SessionReport { prefetch_hits: hits, queue_stalls: stalls, generation: gen, ..report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::preprocess::{preprocess, Preprocessed, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::estimator::lgd::LgdOptions;
    use crate::estimator::GradientEstimator;
    use crate::lsh::srp::DenseSrp;

    fn setup(n: usize, d: usize, seed: u64) -> Preprocessed {
        let ds = SynthSpec::power_law("ae", n, d, seed).generate().unwrap();
        preprocess(ds, &PreprocessOptions::default()).unwrap()
    }

    fn mk(pre: &Preprocessed, shards: usize) -> ShardedLgdEstimator<'_, DenseSrp> {
        let hd = pre.hashed.cols();
        let h = DenseSrp::new(hd, 3, 12, 101);
        ShardedLgdEstimator::new(pre, h, 103, LgdOptions::default(), shards).unwrap()
    }

    #[test]
    fn queue_is_fifo_bounded_and_closable() {
        let q: DrawQueue<u32> = DrawQueue::new(4);
        assert!(q.is_empty());
        for i in 0..4 {
            assert!(q.push(i));
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i), "FIFO order");
        }
        assert!(q.push(9));
        q.close();
        assert!(!q.push(10), "push after close must fail");
        assert_eq!(q.pop(), Some(9), "close drains buffered items first");
        assert_eq!(q.pop(), None);
        let (hits, stalls) = q.counters();
        assert_eq!(hits + stalls, 5, "every successful pop is a hit or a stall");
    }

    #[test]
    fn queue_capacity_blocks_producer_until_popped() {
        let q: DrawQueue<u32> = DrawQueue::new(1);
        thread::scope(|scope| {
            let h = scope.spawn(|| {
                // second push blocks until the main thread pops
                assert!(q.push(1));
                assert!(q.push(2));
            });
            assert_eq!(q.pop(), Some(1));
            assert_eq!(q.pop(), Some(2));
            h.join().unwrap();
        });
        assert!(q.is_empty());
    }

    #[test]
    fn zero_workers_is_rejected() {
        let pre = setup(60, 6, 7);
        let mut est = mk(&pre, 2);
        let cfg = DrawEngineConfig { workers: 0, queue_depth: 8, ..Default::default() };
        assert!(run_session(&mut est, &cfg, &[0.1; 6], 8, 2, |_, _| true).is_err());
    }

    /// The determinism gate: with a fixed seed and `workers = 1`, the
    /// async engine's draw stream is identical to the synchronous
    /// `draw_batch` stream — and the RNG hand-back means synchronous draws
    /// continue the very same stream after the session.
    #[test]
    fn async_single_worker_matches_sync_draw_stream() {
        let pre = setup(240, 8, 31);
        let mut sync = mk(&pre, 3);
        let mut async_ = mk(&pre, 3);
        let theta: Vec<f32> = (0..8).map(|j| 0.03 * (j as f32 - 3.0)).collect();
        let (m, steps) = (32usize, 6usize);
        let mut want = Vec::new();
        let mut got = Vec::new();
        let mut out = Vec::new();
        for _ in 0..steps {
            sync.draw_batch(&theta, m, &mut out);
            want.extend(out.iter().copied());
        }
        let cfg = DrawEngineConfig { workers: 1, queue_depth: 64, ..Default::default() };
        let rep = run_session(&mut async_, &cfg, &theta, m, steps, |_, draws| {
            got.extend(draws.iter().copied());
            true
        })
        .unwrap();
        assert_eq!(rep.batches, steps);
        assert_eq!(rep.draws, (m * steps) as u64);
        assert_eq!(rep.workers, 1);
        assert_eq!(want, got, "async workers=1 must replay the sync stream");
        // cost parity (the multi-thread counter satellite): randoms,
        // probes, fallbacks and draws all match the sequential path; only
        // hashing differs (once per session vs once per batch — the win).
        let (ss, aa) = (sync.stats(), async_.stats());
        assert_eq!(ss.draws, aa.draws);
        assert_eq!(ss.fallbacks, aa.fallbacks);
        assert_eq!(ss.cost.randoms, aa.cost.randoms);
        assert_eq!(ss.cost.probes, aa.cost.probes);
        // L = 12: sync hashes once per batch, async once per session
        assert_eq!(aa.cost.codes + 12 * (steps - 1), ss.cost.codes);
        assert_eq!(aa.prefetch_hits + aa.queue_stalls, steps as u64);
        // the RNG was handed back: sync and async continue identically
        sync.draw_batch(&theta, m, &mut out);
        let mut out2 = Vec::new();
        async_.draw_batch(&theta, m, &mut out2);
        assert_eq!(out, out2, "post-session sync draws diverged");
    }

    /// Per-shard mode (`workers >= 2`): the assembled stream is valid,
    /// deterministic under a fixed seed (thread timing cannot change it),
    /// and independent of the requested worker count beyond the shard
    /// count (one dedicated worker per shard).
    #[test]
    fn async_per_shard_stream_deterministic_and_valid() {
        let pre = setup(180, 8, 47);
        let theta = vec![0.05f32; 8];
        let (m, steps) = (25usize, 8usize);
        let run = |workers: usize| {
            let mut est = mk(&pre, 3);
            let mut got = Vec::new();
            let cfg = DrawEngineConfig { workers, queue_depth: 64, ..Default::default() };
            let rep = run_session(&mut est, &cfg, &theta, m, steps, |_, draws| {
                got.extend(draws.iter().copied());
                true
            })
            .unwrap();
            assert_eq!(rep.batches, steps);
            assert_eq!(rep.stale_drops, 0);
            assert_eq!(rep.workers, 3, "one dedicated worker per shard");
            assert_eq!(est.stats().draws, (m * steps) as u64);
            (got, est.stats())
        };
        let (a, sa) = run(3);
        let (b, _) = run(3);
        assert_eq!(a, b, "fixed seed must pin the per-shard stream exactly");
        let (c, _) = run(8);
        assert_eq!(a, c, "worker counts beyond the shard count are clamped");
        assert_eq!(sa.fallbacks, 0, "dense K=3 buckets must not exhaust");
        for d in &a {
            assert!(d.index < 180);
            assert!(d.prob > 0.0 && d.prob <= 1.0);
            assert!(d.weight > 0.0);
        }
        assert!(sa.cost.probes as usize >= m * steps, "prefetch work must be accounted");
    }

    /// Session boundaries are the mutation points: after removals the next
    /// session must never serve dead rows (generation bumped, queues
    /// flushed by construction), in both worker modes.
    #[test]
    fn sessions_across_mutation_never_serve_dead_rows() {
        for workers in [1usize, 4] {
            let pre = setup(150, 8, 59);
            let mut est = mk(&pre, 3);
            let theta = vec![0.04f32; 8];
            let cfg = DrawEngineConfig { workers, queue_depth: 32, ..Default::default() };
            let g0 = est.shard_set().generation();
            run_session(&mut est, &cfg, &theta, 16, 4, |_, draws| {
                assert!(draws.iter().all(|d| d.index < 150));
                true
            })
            .unwrap();
            for id in 0..50 {
                assert!(est.remove(id).unwrap());
            }
            assert!(est.shard_set().generation() > g0, "mutations must bump the generation");
            let rep = run_session(&mut est, &cfg, &theta, 16, 6, |_, draws| {
                for d in draws {
                    assert!(
                        d.index >= 50 && d.index < 150,
                        "workers={workers}: served dead row {}",
                        d.index
                    );
                }
                true
            })
            .unwrap();
            assert_eq!(rep.batches, 6);
        }
    }

    /// A fully drained set degenerates to counted uniform fallbacks
    /// (weight 1) instead of hanging or panicking — per-shard mode spawns
    /// no workers and the mixer serves the fallbacks.
    #[test]
    fn drained_set_serves_uniform_fallbacks() {
        let pre = setup(40, 6, 71);
        let mut est = mk(&pre, 2);
        for id in 0..40 {
            assert!(est.remove(id).unwrap());
        }
        for workers in [1usize, 2] {
            let cfg = DrawEngineConfig { workers, queue_depth: 16, ..Default::default() };
            let before = est.stats().fallbacks;
            let rep = run_session(&mut est, &cfg, &[0.1; 6], 8, 3, |_, draws| {
                assert_eq!(draws.len(), 8);
                assert!(draws.iter().all(|d| d.index < 40 && d.weight == 1.0));
                true
            })
            .unwrap();
            assert_eq!(rep.batches, 3);
            assert_eq!(est.stats().fallbacks - before, 24);
        }
    }

    /// Early consumer stop shuts the pipeline down cleanly in both modes
    /// (no deadlock, no panic), and the engine reports what was consumed.
    #[test]
    fn early_stop_shuts_down_cleanly() {
        let pre = setup(120, 6, 83);
        for workers in [1usize, 3] {
            let mut est = mk(&pre, 3);
            let cfg = DrawEngineConfig { workers, queue_depth: 16, ..Default::default() };
            let rep = run_session(&mut est, &cfg, &[0.05; 6], 8, 100, |step, _| step < 2).unwrap();
            assert_eq!(rep.batches, 3, "steps 0,1 continue, step 2 stops");
        }
    }

    /// A thread dying while it holds the queue mutex poisons it; every
    /// queue operation must recover (the ring state is plain data, always
    /// valid) instead of cascading the panic into other threads.
    #[test]
    fn poisoned_queue_recovers_on_every_operation() {
        let q: DrawQueue<u32> = DrawQueue::new(4);
        assert!(q.push(1));
        let died = thread::scope(|scope| {
            scope
                .spawn(|| {
                    let _held = q.inner.lock().unwrap();
                    panic!("die holding the queue mutex");
                })
                .join()
        });
        assert!(died.is_err(), "the poisoning thread must have panicked");
        assert!(q.inner.is_poisoned(), "setup failed: mutex not poisoned");
        // all operations still work against the poisoned mutex
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        let (hits, stalls) = q.counters();
        assert_eq!(hits + stalls, 2);
        q.close();
        assert!(!q.push(3));
        assert_eq!(q.pop(), None);
    }

    // The killed-worker end-to-end test (a shard worker dying while it
    // holds its queue mutex surfaces as a clean `Error::Pipeline`, and
    // synchronous draws survive) lives in `tests/chaos.rs`: it arms the
    // real `WORKER_START` failpoint, and real sites must never be armed
    // from the lib's parallel unit-test threads.
}
