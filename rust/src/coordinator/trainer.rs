//! The training driver: runs Algorithm 2 (or its SGD baseline) over a
//! preprocessed dataset, instrumenting exactly what the paper plots —
//! loss-vs-epoch and loss-vs-wall-clock, with sampling/gradient/update time
//! split out. Evaluation time is *excluded* from the training clock so the
//! LGD-vs-SGD wall-clock comparison measures only the algorithms.

use std::time::Instant;

use crate::config::spec::{EstimatorKind, HasherKind, OptimizerKind, RunConfig};
use crate::coordinator::draw_engine::{run_session, DrawEngineConfig};
use crate::core::error::{Error, Result};
use crate::core::matrix::axpy;
use crate::data::dataset::{Dataset, Task};
use crate::data::preprocess::Preprocessed;
use crate::estimator::lgd::{LgdEstimator, LgdOptions};
use crate::estimator::sharded::ShardedLgdEstimator;
use crate::estimator::{EstimatorStats, GradientEstimator, UniformEstimator, WeightedDraw};
use crate::lsh::srp::{DenseSrp, SparseSrp, SrpHasher};
use crate::lsh::QuadraticSrp;
use crate::model::{LinReg, LogReg, Model};
use crate::optim::{AdaGrad, Adam, Optimizer, Sgd};
use crate::runtime::{PjrtLinear, Runtime};

/// One point of the convergence curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    /// Iterations completed.
    pub iter: u64,
    /// Fractional epochs completed.
    pub epoch: f64,
    /// Training wall-clock seconds so far (eval excluded; LGD table build
    /// included as the t=0 offset).
    pub wall: f64,
    /// Mean loss on the training split.
    pub train_loss: f64,
    /// Mean loss on the test split.
    pub test_loss: f64,
}

/// Everything a training run produces.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Convergence curve (one point at t=0, then per eval cadence).
    pub curve: Vec<CurvePoint>,
    /// Final parameters.
    pub theta: Vec<f32>,
    /// Total training wall-clock (excl. eval).
    pub wall_secs: f64,
    /// One-time preprocessing (LSH table build; 0 for SGD).
    pub preprocess_secs: f64,
    /// Iterations executed.
    pub iterations: u64,
    /// Estimator counters (draws, fallbacks, hash cost).
    pub est_stats: EstimatorStats,
    /// Estimator name ("sgd"/"lgd"/"lgd-sharded").
    pub estimator: String,
    /// Per-shard table-build seconds (empty unless `lsh.shards > 1`).
    pub shard_build_secs: Vec<f64>,
}

/// Gradient execution source.
pub enum GradSource<'rt> {
    /// Pure-Rust model math.
    Native,
    /// AOT artifacts through the PJRT runtime.
    Pjrt(&'rt mut Runtime),
}

/// Build the configured estimator over a preprocessed dataset.
pub fn build_estimator<'a>(
    cfg: &RunConfig,
    pre: &'a Preprocessed,
) -> Result<Box<dyn GradientEstimator + 'a>> {
    Ok(build_estimator_reported(cfg, pre)?.0)
}

/// Pick the single-structure `LgdEstimator` or, for `lsh.shards > 1`, the
/// sharded engine; returns the per-shard build seconds alongside (empty for
/// the unsharded estimators).
fn lgd_boxed<'a, H>(
    cfg: &RunConfig,
    pre: &'a Preprocessed,
    h: H,
    opts: LgdOptions,
) -> Result<(Box<dyn GradientEstimator + 'a>, Vec<f64>)>
where
    H: SrpHasher + Clone + 'a,
{
    if cfg.lsh.shards > 1 {
        let mut est = ShardedLgdEstimator::new(pre, h, cfg.train.seed, opts, cfg.lsh.shards)?;
        if cfg.lsh.rebalance_threshold > 0.0 {
            est.set_rebalance_threshold(cfg.lsh.rebalance_threshold);
        }
        let secs = est.build_report().per_shard_secs.clone();
        Ok((Box::new(est), secs))
    } else {
        Ok((Box::new(LgdEstimator::new(pre, h, cfg.train.seed, opts)?), Vec::new()))
    }
}

/// [`build_estimator`] plus the per-shard build timings the sharded engine
/// reports (fed into [`TrainOutcome::shard_build_secs`]).
pub fn build_estimator_reported<'a>(
    cfg: &RunConfig,
    pre: &'a Preprocessed,
) -> Result<(Box<dyn GradientEstimator + 'a>, Vec<f64>)> {
    match cfg.train.estimator {
        EstimatorKind::Sgd => {
            Ok((Box::new(UniformEstimator::new(pre.data.len(), cfg.train.seed)), Vec::new()))
        }
        EstimatorKind::Lgd => {
            let hd = pre.hashed.cols();
            let opts = lgd_options(cfg);
            match cfg.lsh.hasher {
                HasherKind::Dense => {
                    let h = DenseSrp::new(hd, cfg.lsh.k, cfg.lsh.l, cfg.lsh.seed);
                    lgd_boxed(cfg, pre, h, opts)
                }
                HasherKind::Sparse => {
                    let h = SparseSrp::new(hd, cfg.lsh.k, cfg.lsh.l, cfg.lsh.density, cfg.lsh.seed);
                    lgd_boxed(cfg, pre, h, opts)
                }
                HasherKind::Quadratic => {
                    let h =
                        QuadraticSrp::new(hd, cfg.lsh.k, cfg.lsh.l, cfg.lsh.density, cfg.lsh.seed);
                    lgd_boxed(cfg, pre, h, opts)
                }
            }
        }
    }
}

/// The estimator options a run config implies — one definition shared by
/// the synchronous `build_estimator` path and the async trainer, so the
/// two paths can never diverge on sampler tuning.
fn lgd_options(cfg: &RunConfig) -> LgdOptions {
    LgdOptions {
        weight_clip: cfg.lsh.weight_clip,
        max_probes: 0,
        query_refresh: 0,
        mirror: cfg.lsh.mirror,
        sealed: cfg.lsh.sealed,
    }
}

fn build_optimizer(cfg: &RunConfig) -> Box<dyn Optimizer> {
    match cfg.train.optimizer {
        OptimizerKind::Sgd => Box::new(Sgd::new(cfg.train.schedule)),
        OptimizerKind::AdaGrad => Box::new(AdaGrad::new(cfg.train.schedule.base())),
        OptimizerKind::Adam => Box::new(Adam::new(cfg.train.schedule.base())),
    }
}

fn native_model(task: Task) -> Box<dyn Model> {
    match task {
        Task::Regression => Box::new(LinReg),
        Task::Classification => Box::new(LogReg),
    }
}

/// Mean train/test loss through the run's gradient backend — loss evals go
/// through the same backend as training for coherence, but the callers
/// exclude them from the training clock. One definition shared by the
/// synchronous and async trainers.
fn eval_losses(
    pre: &Preprocessed,
    test: &Dataset,
    model: &dyn Model,
    pjrt: &mut Option<(&mut Runtime, PjrtLinear)>,
    theta: &[f32],
) -> Result<(f64, f64)> {
    if let Some((rt, lin)) = pjrt.as_mut() {
        let tr = lin.mean_loss(rt, &pre.data, theta)?;
        let te = if test.is_empty() { 0.0 } else { lin.mean_loss(rt, test, theta)? };
        Ok((tr, te))
    } else {
        let tr = model.mean_loss(&pre.data, theta);
        let te = if test.is_empty() { 0.0 } else { model.mean_loss(test, theta) };
        Ok((tr, te))
    }
}

/// One step's weighted-minibatch gradient estimate into `acc`, native or
/// PJRT — the other half of the step body both trainers share.
#[allow(clippy::too_many_arguments)]
fn accumulate_grad(
    pre: &Preprocessed,
    model: &dyn Model,
    pjrt: &mut Option<(&mut Runtime, PjrtLinear)>,
    draws: &[WeightedDraw],
    batch: usize,
    theta: &[f32],
    grad: &mut [f32],
    idxs: &mut [usize],
    weights: &mut [f64],
    acc: &mut [f32],
) -> Result<()> {
    match pjrt.as_mut() {
        None => {
            acc.iter_mut().for_each(|v| *v = 0.0);
            let inv_b = 1.0 / batch as f32;
            for dr in draws {
                let (x, y) = pre.data.example(dr.index);
                model.grad(x, y, theta, grad);
                axpy(dr.weight as f32 * inv_b, grad, acc);
            }
        }
        Some((rt, lin)) => {
            for (i, dr) in draws.iter().enumerate() {
                idxs[i] = dr.index;
                weights[i] = dr.weight;
            }
            lin.grad(rt, &pre.data, idxs, weights, theta, acc)?;
        }
    }
    Ok(())
}

/// Run one training configuration. `test` may be empty (test loss = 0).
/// With `lsh.async_workers > 0` (and the LGD estimator) the step loop is
/// fully pipelined: sampling overlaps gradient compute via the async draw
/// engine. `async_workers = 0` is the synchronous path, byte-identical to
/// the pre-engine behavior.
pub fn train(
    cfg: &RunConfig,
    pre: &Preprocessed,
    test: &Dataset,
    src: GradSource<'_>,
) -> Result<TrainOutcome> {
    if cfg.lsh.async_workers > 0 && cfg.train.estimator == EstimatorKind::Lgd {
        return train_async_dispatch(cfg, pre, test, src);
    }
    train_sync(cfg, pre, test, src)
}

fn train_sync(
    cfg: &RunConfig,
    pre: &Preprocessed,
    test: &Dataset,
    src: GradSource<'_>,
) -> Result<TrainOutcome> {
    let n = pre.data.len();
    let d = pre.data.dim();
    if n == 0 {
        return Err(Error::Data("empty training set".into()));
    }
    let batch = cfg.train.batch;
    let iters_per_epoch = (n / batch).max(1) as u64;
    let total_iters = iters_per_epoch * cfg.train.epochs as u64;
    let eval_every = if cfg.train.eval_every > 0 {
        cfg.train.eval_every as u64
    } else {
        iters_per_epoch
    };

    // One-time preprocessing: estimator construction builds the LSH tables
    // (concurrently per shard when `lsh.shards > 1`).
    let t0 = Instant::now();
    let (mut est, shard_build_secs) = build_estimator_reported(cfg, pre)?;
    let preprocess_secs = t0.elapsed().as_secs_f64();

    let mut opt = build_optimizer(cfg);
    let model = native_model(pre.data.task);
    let mut pjrt = match src {
        GradSource::Native => None,
        GradSource::Pjrt(rt) => {
            let lin = PjrtLinear::new(rt, pre.data.task, batch, d)?;
            Some((rt, lin))
        }
    };

    let mut theta = vec![0.0f32; d];
    let mut grad = vec![0.0f32; d];
    let mut acc = vec![0.0f32; d];
    let mut draws: Vec<WeightedDraw> = Vec::with_capacity(batch);
    let mut idxs = vec![0usize; batch];
    let mut weights = vec![0.0f64; batch];

    let mut curve = Vec::new();
    // LGD's table build counts as wall-clock spent before the first step.
    let mut train_wall = preprocess_secs;

    // Loss evals are excluded from the training clock.
    let (tr0, te0) = eval_losses(pre, test, model.as_ref(), &mut pjrt, &theta)?;
    curve.push(CurvePoint {
        iter: 0,
        epoch: 0.0,
        wall: train_wall,
        train_loss: tr0,
        test_loss: te0,
    });

    for it in 1..=total_iters {
        let step_t = Instant::now();
        // --- sample ---
        if batch == 1 {
            draws.clear();
            draws.push(est.draw(&theta));
        } else {
            est.draw_batch(&theta, batch, &mut draws);
        }
        // --- gradient estimate ---
        accumulate_grad(
            pre,
            model.as_ref(),
            &mut pjrt,
            &draws,
            batch,
            &theta,
            &mut grad,
            &mut idxs,
            &mut weights,
            &mut acc,
        )?;
        // --- update ---
        opt.step(&mut theta, &acc);
        train_wall += step_t.elapsed().as_secs_f64();

        if it % eval_every == 0 || it == total_iters {
            let (tr, te) = eval_losses(pre, test, model.as_ref(), &mut pjrt, &theta)?;
            curve.push(CurvePoint {
                iter: it,
                epoch: it as f64 / iters_per_epoch as f64,
                wall: train_wall,
                train_loss: tr,
                test_loss: te,
            });
        }
    }

    Ok(TrainOutcome {
        curve,
        theta,
        wall_secs: train_wall,
        preprocess_secs,
        iterations: total_iters,
        est_stats: est.stats(),
        estimator: est.name().to_string(),
        shard_build_secs,
    })
}

/// `lsh.async_workers > 0`: monomorphize the pipelined trainer over the
/// configured hash family (the draw engine is generic over the hasher).
fn train_async_dispatch(
    cfg: &RunConfig,
    pre: &Preprocessed,
    test: &Dataset,
    src: GradSource<'_>,
) -> Result<TrainOutcome> {
    let hd = pre.hashed.cols();
    let opts = lgd_options(cfg);
    match cfg.lsh.hasher {
        HasherKind::Dense => {
            let h = DenseSrp::new(hd, cfg.lsh.k, cfg.lsh.l, cfg.lsh.seed);
            train_async(cfg, pre, test, src, h, opts)
        }
        HasherKind::Sparse => {
            let h = SparseSrp::new(hd, cfg.lsh.k, cfg.lsh.l, cfg.lsh.density, cfg.lsh.seed);
            train_async(cfg, pre, test, src, h, opts)
        }
        HasherKind::Quadratic => {
            let h = QuadraticSrp::new(hd, cfg.lsh.k, cfg.lsh.l, cfg.lsh.density, cfg.lsh.seed);
            train_async(cfg, pre, test, src, h, opts)
        }
    }
}

/// The pipelined step loop: one draw-engine session per epoch. The
/// sampling query is frozen at the epoch's entry θ (a stale proposal with
/// *exact* probabilities — importance weighting keeps the estimator
/// unbiased for any fixed proposal, exactly the `QueryCache` amortisation
/// argument), so while batch `t`'s gradient is computed and applied here,
/// batch `t+1` is already being assembled on the sampler threads. Each
/// epoch boundary is a queue flush plus one fused re-hash of the new θ.
/// Eval time is excluded from the training clock; queue-stall time is
/// *included* (it is real wall-clock the pipeline failed to hide).
fn train_async<H>(
    cfg: &RunConfig,
    pre: &Preprocessed,
    test: &Dataset,
    src: GradSource<'_>,
    hasher: H,
    opts: LgdOptions,
) -> Result<TrainOutcome>
where
    H: SrpHasher + Clone,
{
    let n = pre.data.len();
    let d = pre.data.dim();
    if n == 0 {
        return Err(Error::Data("empty training set".into()));
    }
    let batch = cfg.train.batch;
    let iters_per_epoch = (n / batch).max(1) as u64;
    let total_iters = iters_per_epoch * cfg.train.epochs as u64;
    let eval_every = if cfg.train.eval_every > 0 {
        cfg.train.eval_every as u64
    } else {
        iters_per_epoch
    };

    // One-time preprocessing: the sharded table build (shards = 1 is the
    // single-table engine, still served asynchronously).
    let t0 = Instant::now();
    let mut est = ShardedLgdEstimator::new(pre, hasher, cfg.train.seed, opts, cfg.lsh.shards)?;
    if cfg.lsh.rebalance_threshold > 0.0 {
        est.set_rebalance_threshold(cfg.lsh.rebalance_threshold);
    }
    let shard_build_secs = est.build_report().per_shard_secs.clone();
    let preprocess_secs = t0.elapsed().as_secs_f64();

    let mut opt = build_optimizer(cfg);
    let model = native_model(pre.data.task);
    let mut pjrt = match src {
        GradSource::Native => None,
        GradSource::Pjrt(rt) => {
            let lin = PjrtLinear::new(rt, pre.data.task, batch, d)?;
            Some((rt, lin))
        }
    };

    let mut theta = vec![0.0f32; d];
    let mut grad = vec![0.0f32; d];
    let mut acc = vec![0.0f32; d];
    let mut idxs = vec![0usize; batch];
    let mut weights = vec![0.0f64; batch];

    let mut curve = Vec::new();
    let mut train_wall = preprocess_secs;

    let (tr0, te0) = eval_losses(pre, test, model.as_ref(), &mut pjrt, &theta)?;
    curve.push(CurvePoint {
        iter: 0,
        epoch: 0.0,
        wall: train_wall,
        train_loss: tr0,
        test_loss: te0,
    });

    let engine =
        DrawEngineConfig { workers: cfg.lsh.async_workers, queue_depth: cfg.lsh.queue_depth };
    let mut it = 0u64;
    let mut abort: Option<Error> = None;
    for _epoch in 0..cfg.train.epochs {
        let frozen = theta.clone();
        let epoch_t = Instant::now();
        let mut eval_secs = 0.0f64;
        let wall_base = train_wall;
        run_session(&mut est, &engine, &frozen, batch, iters_per_epoch as usize, |_, draws| {
            it += 1;
            // --- gradient estimate (overlaps the next batch's sampling) ---
            if let Err(e) = accumulate_grad(
                pre,
                model.as_ref(),
                &mut pjrt,
                draws,
                batch,
                &theta,
                &mut grad,
                &mut idxs,
                &mut weights,
                &mut acc,
            ) {
                abort = Some(e);
                return false;
            }
            // --- update ---
            opt.step(&mut theta, &acc);
            if it % eval_every == 0 || it == total_iters {
                let ev = Instant::now();
                match eval_losses(pre, test, model.as_ref(), &mut pjrt, &theta) {
                    Ok((tr, te)) => {
                        eval_secs += ev.elapsed().as_secs_f64();
                        curve.push(CurvePoint {
                            iter: it,
                            epoch: it as f64 / iters_per_epoch as f64,
                            wall: wall_base + epoch_t.elapsed().as_secs_f64() - eval_secs,
                            train_loss: tr,
                            test_loss: te,
                        });
                    }
                    Err(e) => {
                        abort = Some(e);
                        return false;
                    }
                }
            }
            true
        })?;
        if let Some(e) = abort.take() {
            return Err(e);
        }
        train_wall = wall_base + epoch_t.elapsed().as_secs_f64() - eval_secs;
    }

    Ok(TrainOutcome {
        curve,
        theta,
        wall_secs: train_wall,
        preprocess_secs,
        iterations: total_iters,
        est_stats: est.stats(),
        estimator: "lgd-async".to_string(),
        shard_build_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::RunConfig;
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::optim::Schedule;

    fn small_cfg(est: EstimatorKind) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.train.estimator = est;
        cfg.train.epochs = 4;
        cfg.train.schedule = Schedule::Const(0.05);
        cfg.lsh.k = 4;
        cfg.lsh.l = 16;
        cfg.lsh.hasher = HasherKind::Dense;
        cfg
    }

    fn setup(n: usize, d: usize, seed: u64) -> (Preprocessed, Dataset) {
        let ds = SynthSpec::power_law("t", n, d, seed).generate().unwrap();
        let (tr, te) = ds.split(0.8, 1).unwrap();
        (preprocess(tr, &PreprocessOptions::default()).unwrap(), te)
    }

    #[test]
    fn sgd_training_reduces_loss() {
        let (pre, te) = setup(500, 10, 3);
        let cfg = small_cfg(EstimatorKind::Sgd);
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "sgd");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first * 0.8, "loss {first} -> {last}");
        assert_eq!(out.iterations, 4 * 400);
        assert!(out.preprocess_secs < 0.01, "SGD has no preprocessing");
    }

    #[test]
    fn lgd_training_reduces_loss() {
        let (pre, te) = setup(500, 10, 5);
        let cfg = small_cfg(EstimatorKind::Lgd);
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "lgd");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first * 0.8, "loss {first} -> {last}");
        assert!(out.est_stats.cost.codes > 0, "LGD must compute hashes");
    }

    #[test]
    fn sharded_lgd_training_reduces_loss() {
        let (pre, te) = setup(500, 10, 5);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.shards = 4;
        // exercise the config plumbing: a static training set starts (and
        // stays) balanced, so the knob must be a no-op here
        cfg.lsh.rebalance_threshold = 1.25;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "lgd-sharded");
        assert_eq!(out.shard_build_secs.len(), 4, "one build timing per shard");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first * 0.8, "loss {first} -> {last}");
        assert!(out.est_stats.cost.codes > 0, "sharded LGD must compute hashes");
        assert_eq!(out.est_stats.migrations, 0, "static training must not migrate");
        assert_eq!(out.est_stats.rebalances, 0);
    }

    /// The `lsh.sealed` knob is a pure layout swap: training with the CSR
    /// arena and with Vec buckets produces identical loss curves under the
    /// same seed (draw-for-draw identity end-to-end through the trainer).
    #[test]
    fn sealed_knob_is_layout_only() {
        let (pre, te) = setup(400, 8, 13);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.shards = 2;
        assert!(cfg.lsh.sealed, "default on");
        let sealed = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        cfg.lsh.sealed = false;
        let vecs = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(sealed.curve.len(), vecs.curve.len());
        for (a, b) in sealed.curve.iter().zip(&vecs.curve) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.train_loss, b.train_loss, "iter {}: layouts diverged", a.iter);
            assert_eq!(a.test_loss, b.test_loss);
        }
        assert_eq!(sealed.est_stats.fallbacks, vecs.est_stats.fallbacks);
    }

    /// Pipelined trainer: `lsh.async_workers > 0` runs the step loop
    /// through the draw engine (per-shard workers here); the run still
    /// converges and the outcome carries the queue counters.
    #[test]
    fn async_trainer_reduces_loss() {
        let (pre, te) = setup(500, 10, 5);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.shards = 2;
        cfg.lsh.async_workers = 2;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "lgd-async");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first * 0.8, "async loss {first} -> {last}");
        let st = out.est_stats;
        assert_eq!(st.draws, out.iterations, "batch = 1: one draw per iteration");
        assert_eq!(
            st.prefetch_hits + st.queue_stalls,
            out.iterations,
            "every step pops exactly one batch off the engine queue"
        );
        assert_eq!(st.migrations, 0, "static training must not migrate");
    }

    /// The smallest async config — one worker, one shard (replay mode) —
    /// trains with a well-formed monotone curve.
    #[test]
    fn async_single_worker_single_shard_trains() {
        let (pre, te) = setup(300, 8, 7);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.async_workers = 1;
        cfg.train.batch = 8;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "lgd-async");
        for w in out.curve.windows(2) {
            assert!(w[1].iter > w[0].iter);
            assert!(w[1].wall >= w[0].wall);
        }
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first, "single-worker async did not descend: {first} -> {last}");
    }

    /// The async knob belongs to the LGD sampler; SGD runs stay on the
    /// synchronous path untouched.
    #[test]
    fn async_knob_ignored_for_sgd() {
        let (pre, te) = setup(200, 8, 9);
        let mut cfg = small_cfg(EstimatorKind::Sgd);
        cfg.lsh.async_workers = 4;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "sgd");
        assert_eq!(out.est_stats.prefetch_hits, 0);
    }

    #[test]
    fn curve_is_monotone_in_time_and_iters() {
        let (pre, te) = setup(300, 8, 7);
        let out = train(&small_cfg(EstimatorKind::Lgd), &pre, &te, GradSource::Native).unwrap();
        for w in out.curve.windows(2) {
            assert!(w[1].iter > w[0].iter);
            assert!(w[1].wall >= w[0].wall);
        }
        // epochs land on integers at the per-epoch cadence
        assert!((out.curve.last().unwrap().epoch - 4.0).abs() < 1e-9);
    }

    #[test]
    fn minibatch_runs() {
        let (pre, te) = setup(400, 8, 9);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.train.batch = 16;
        cfg.train.optimizer = OptimizerKind::AdaGrad;
        cfg.train.schedule = Schedule::Const(0.1);
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first, "minibatch adagrad did not descend: {first} -> {last}");
    }

    #[test]
    fn classification_task_trains() {
        let spec = SynthSpec {
            task: Task::Classification,
            ..SynthSpec::power_law("c", 400, 8, 11)
        };
        let ds = spec.generate().unwrap();
        let (tr, te) = ds.split(0.8, 2).unwrap();
        let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.train.schedule = Schedule::Const(0.5);
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first, "logreg did not descend: {first} -> {last}");
    }
}
