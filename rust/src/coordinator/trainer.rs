//! The training driver: runs Algorithm 2 (or its SGD baseline) over a
//! preprocessed dataset, instrumenting exactly what the paper plots —
//! loss-vs-epoch and loss-vs-wall-clock, with sampling/gradient/update time
//! split out. Evaluation time is *excluded* from the training clock so the
//! LGD-vs-SGD wall-clock comparison measures only the algorithms.
//!
//! Structure (post `store::snapshot`):
//! * [`LoopCtx`] is the single definition of the step-loop scaffolding —
//!   shape math, optimizer/model/backend construction, gradient
//!   accumulation, curve bookkeeping — shared by the SGD baseline, the
//!   synchronous LGD loop and the pipelined async loop (previously three
//!   near-copies, flagged by the PR-4 review).
//! * [`crate::lsh::AnyHasher`] is the single `HasherKind` → constructor
//!   dispatch; the boxed estimator builder, the monomorphized LGD path and
//!   the snapshot-restore path all go through `visit`.
//! * LGD runs are always driven through [`ShardedLgdEstimator`] (with
//!   `shards = 1` it is `LgdEstimator` draw-for-draw — tested), which is
//!   what makes warm starts and epoch-boundary autosaves
//!   (`[store]`/`lgd train --resume`) uniform across sync and async modes.
//!   Saves happen only at epoch boundaries: sessions hold the estimator
//!   borrow, so the shard-set generation counter cannot move mid-save —
//!   the same invariant that makes mutation a session-boundary event for
//!   the async engine.

use std::time::Instant;

use crate::config::spec::{EstimatorKind, OptimizerKind, RunConfig};
use crate::coordinator::draw_engine::{run_session, DrawEngineConfig};
use crate::coordinator::health::{HealthMonitor, HealthReport, Trip};
use crate::core::error::{Error, Result};
use crate::core::matrix::axpy;
use crate::core::telemetry::registry::Registry;
use crate::core::telemetry::{probes, prom};
use crate::core::numerics::all_finite;
use crate::data::dataset::{Dataset, Task};
use crate::data::preprocess::Preprocessed;
use crate::estimator::lgd::{LgdEstimator, LgdOptions};
use crate::estimator::sharded::ShardedLgdEstimator;
use crate::estimator::{EstimatorStats, GradientEstimator, UniformEstimator, WeightedDraw};
use crate::lsh::srp::SrpHasher;
use crate::lsh::{AnyHasher, HasherVisitor};
use crate::model::{LinReg, LogReg, Model};
use crate::optim::{AdaGrad, Adam, Optimizer, Sgd};
use crate::runtime::{PjrtLinear, Runtime};
use crate::store::snapshot::{
    self, EngineDump, HealthStamp, LoadedSnapshot, SnapshotHasher, TrainState,
};
use crate::testkit::faults;

/// One point of the convergence curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    /// Iterations completed.
    pub iter: u64,
    /// Fractional epochs completed.
    pub epoch: f64,
    /// Training wall-clock seconds so far (eval excluded; LGD table build
    /// included as the t=0 offset).
    pub wall: f64,
    /// Mean loss on the training split.
    pub train_loss: f64,
    /// Mean loss on the test split.
    pub test_loss: f64,
}

/// One epoch's flattened view of the global metrics registry, captured at
/// the epoch boundary (after the autosave, so the snapshot-write timings
/// are included). Histograms flatten to `<name>.count` / `<name>.sum_secs`.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochMetricsSnapshot {
    /// 1-based epoch the capture closed.
    pub epoch: u32,
    /// `(metric key, value)` pairs, sorted by key.
    pub samples: Vec<(String, f64)>,
}

/// Everything a training run produces.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Convergence curve (one point at the entry iteration, then per eval
    /// cadence).
    pub curve: Vec<CurvePoint>,
    /// Final parameters.
    pub theta: Vec<f32>,
    /// Total training wall-clock (excl. eval).
    pub wall_secs: f64,
    /// One-time preprocessing: LSH table build for a cold start, snapshot
    /// restore for a warm start, 0 for SGD.
    pub preprocess_secs: f64,
    /// Global iterations completed (a resumed run includes the iterations
    /// done before the save).
    pub iterations: u64,
    /// Estimator counters (draws, fallbacks, hash cost).
    pub est_stats: EstimatorStats,
    /// Estimator name ("sgd"/"lgd"/"lgd-sharded"/"lgd-async").
    pub estimator: String,
    /// Per-shard table-build seconds (all-zero after a warm start — the
    /// observable "zero table-build work" guarantee).
    pub shard_build_secs: Vec<f64>,
    /// True when the engine was warm-started from a snapshot.
    pub resumed: bool,
    /// Snapshots written during the run (autosaves + the final save).
    pub autosaves: u32,
    /// Health-supervisor counters (all zero when `health.enabled` is off
    /// or nothing tripped — the clean-path gate).
    pub health: HealthReport,
    /// Per-epoch registry captures (`telemetry.enabled`, LGD epoch loop
    /// only — empty for SGD runs and with telemetry off).
    pub epoch_metrics: Vec<EpochMetricsSnapshot>,
}

/// Gradient execution source.
pub enum GradSource<'rt> {
    /// Pure-Rust model math.
    Native,
    /// AOT artifacts through the PJRT runtime.
    Pjrt(&'rt mut Runtime),
}

/// Build the configured estimator over a preprocessed dataset.
pub fn build_estimator<'a>(
    cfg: &RunConfig,
    pre: &'a Preprocessed,
) -> Result<Box<dyn GradientEstimator + 'a>> {
    Ok(build_estimator_reported(cfg, pre)?.0)
}

/// Pick the single-structure `LgdEstimator` or, for `lsh.shards > 1`, the
/// sharded engine; returns the per-shard build seconds alongside (empty for
/// the unsharded estimators).
fn lgd_boxed<'a, H>(
    cfg: &RunConfig,
    pre: &'a Preprocessed,
    h: H,
    opts: LgdOptions,
) -> Result<(Box<dyn GradientEstimator + 'a>, Vec<f64>)>
where
    H: SrpHasher + Clone + 'a,
{
    if cfg.lsh.shards > 1 {
        let mut est = ShardedLgdEstimator::new(pre, h, cfg.train.seed, opts, cfg.lsh.shards)?;
        if cfg.lsh.rebalance_threshold > 0.0 {
            est.set_rebalance_threshold(cfg.lsh.rebalance_threshold);
        }
        let secs = est.build_report().per_shard_secs.clone();
        Ok((Box::new(est), secs))
    } else {
        Ok((Box::new(LgdEstimator::new(pre, h, cfg.train.seed, opts)?), Vec::new()))
    }
}

struct BoxedBuild<'c, 'a> {
    cfg: &'c RunConfig,
    pre: &'a Preprocessed,
}

impl<'c, 'a> HasherVisitor for BoxedBuild<'c, 'a> {
    type Out = Result<(Box<dyn GradientEstimator + 'a>, Vec<f64>)>;

    fn visit<H>(self, hasher: H) -> Self::Out
    where
        H: SnapshotHasher + Clone + 'static,
    {
        lgd_boxed(self.cfg, self.pre, hasher, lgd_options(self.cfg))
    }
}

/// [`build_estimator`] plus the per-shard build timings the sharded engine
/// reports (fed into [`TrainOutcome::shard_build_secs`]).
pub fn build_estimator_reported<'a>(
    cfg: &RunConfig,
    pre: &'a Preprocessed,
) -> Result<(Box<dyn GradientEstimator + 'a>, Vec<f64>)> {
    match cfg.train.estimator {
        EstimatorKind::Sgd => {
            Ok((Box::new(UniformEstimator::new(pre.data.len(), cfg.train.seed)), Vec::new()))
        }
        EstimatorKind::Lgd => {
            let hd = pre.hashed.cols();
            AnyHasher::from_lsh_config(&cfg.lsh, hd).visit(BoxedBuild { cfg, pre })
        }
    }
}

/// The estimator options a run config implies — one definition shared by
/// the boxed builder, the monomorphized trainer paths and the snapshot
/// save CLI, so no path can diverge on sampler tuning.
pub fn lgd_options(cfg: &RunConfig) -> LgdOptions {
    LgdOptions {
        weight_clip: cfg.lsh.weight_clip,
        max_probes: 0,
        query_refresh: 0,
        mirror: cfg.lsh.mirror,
        sealed: cfg.lsh.sealed,
    }
}

/// Cold-build the sharded LGD engine a config describes (any shard count —
/// `shards = 1` is `LgdEstimator` draw-for-draw). Shared by the trainer's
/// cold path and `lgd snapshot save`.
pub fn build_sharded_estimator<'a, H>(
    cfg: &RunConfig,
    pre: &'a Preprocessed,
    hasher: H,
) -> Result<ShardedLgdEstimator<'a, H>>
where
    H: SrpHasher + Clone,
{
    let mut est =
        ShardedLgdEstimator::new(pre, hasher, cfg.train.seed, lgd_options(cfg), cfg.lsh.shards)?;
    if cfg.lsh.rebalance_threshold > 0.0 {
        est.set_rebalance_threshold(cfg.lsh.rebalance_threshold);
    }
    Ok(est)
}

fn build_optimizer(cfg: &RunConfig) -> Box<dyn Optimizer> {
    match cfg.train.optimizer {
        OptimizerKind::Sgd => Box::new(Sgd::new(cfg.train.schedule)),
        OptimizerKind::AdaGrad => Box::new(AdaGrad::new(cfg.train.schedule.base())),
        OptimizerKind::Adam => Box::new(Adam::new(cfg.train.schedule.base())),
    }
}

fn native_model(task: Task) -> Box<dyn Model> {
    match task {
        Task::Regression => Box::new(LinReg),
        Task::Classification => Box::new(LogReg),
    }
}

/// Mean train/test loss through the run's gradient backend — loss evals go
/// through the same backend as training for coherence, but the callers
/// exclude them from the training clock.
fn eval_losses(
    pre: &Preprocessed,
    test: &Dataset,
    model: &dyn Model,
    pjrt: &mut Option<(&mut Runtime, PjrtLinear)>,
    theta: &[f32],
) -> Result<(f64, f64)> {
    if let Some((rt, lin)) = pjrt.as_mut() {
        let tr = lin.mean_loss(rt, &pre.data, theta)?;
        let te = if test.is_empty() { 0.0 } else { lin.mean_loss(rt, test, theta)? };
        Ok((tr, te))
    } else {
        let tr = model.mean_loss(&pre.data, theta);
        let te = if test.is_empty() { 0.0 } else { model.mean_loss(test, theta) };
        Ok((tr, te))
    }
}

/// One step's weighted-minibatch gradient estimate into `acc`, native or
/// PJRT.
#[allow(clippy::too_many_arguments)]
fn accumulate_grad(
    pre: &Preprocessed,
    model: &dyn Model,
    pjrt: &mut Option<(&mut Runtime, PjrtLinear)>,
    draws: &[WeightedDraw],
    batch: usize,
    theta: &[f32],
    grad: &mut [f32],
    idxs: &mut [usize],
    weights: &mut [f64],
    acc: &mut [f32],
) -> Result<()> {
    match pjrt.as_mut() {
        None => {
            acc.iter_mut().for_each(|v| *v = 0.0);
            let inv_b = 1.0 / batch as f32;
            for dr in draws {
                let (x, y) = pre.data.example(dr.index);
                model.grad(x, y, theta, grad);
                if faults::should_fail_at(faults::GRAD_NAN, dr.index as u64) {
                    grad[0] = f32::NAN;
                }
                axpy(dr.weight as f32 * inv_b, grad, acc);
            }
        }
        Some((rt, lin)) => {
            for (i, dr) in draws.iter().enumerate() {
                idxs[i] = dr.index;
                weights[i] = dr.weight;
            }
            lin.grad(rt, &pre.data, idxs, weights, theta, acc)?;
        }
    }
    Ok(())
}

/// Per-example attribution after a non-finite batch gradient: re-derive
/// each drawn example's contribution in isolation and blame the ones that
/// are themselves non-finite (input row, target, importance weight or
/// per-example gradient). Runs only on the already-tripped slow path, so
/// its cost is irrelevant; it re-checks the [`faults::GRAD_NAN`] site with
/// the same per-example filter so an injected persistent poison is
/// attributed exactly like a real one. Uses the native model even under
/// the PJRT backend (attribution needs per-example isolation, not batch
/// throughput).
fn attribute_poison(
    pre: &Preprocessed,
    model: &dyn Model,
    draws: &[WeightedDraw],
    theta: &[f32],
    grad: &mut [f32],
) -> Vec<usize> {
    let mut poisoned = Vec::new();
    for dr in draws {
        let (x, y) = pre.data.example(dr.index);
        let mut bad = !all_finite(x) || !y.is_finite() || !dr.weight.is_finite();
        if !bad {
            model.grad(x, y, theta, grad);
            if faults::should_fail_at(faults::GRAD_NAN, dr.index as u64) {
                grad[0] = f32::NAN;
            }
            bad = !all_finite(grad);
        }
        if bad && !poisoned.contains(&dr.index) {
            poisoned.push(dr.index);
        }
    }
    poisoned
}

/// The single definition of the training-loop scaffolding: iteration
/// shapes, optimizer/model/backend state, parameter and scratch buffers,
/// curve bookkeeping and the per-step gradient/update body. The SGD
/// baseline loop, the synchronous LGD loop and the async pipelined loop
/// all drive this (previously each carried its own copy).
struct LoopCtx<'rt> {
    batch: usize,
    iters_per_epoch: u64,
    total_iters: u64,
    eval_every: u64,
    opt: Box<dyn Optimizer>,
    model: Box<dyn Model>,
    pjrt: Option<(&'rt mut Runtime, PjrtLinear)>,
    theta: Vec<f32>,
    grad: Vec<f32>,
    acc: Vec<f32>,
    idxs: Vec<usize>,
    weights: Vec<f64>,
    curve: Vec<CurvePoint>,
    /// Global iteration counter (resumes continue the saved value so
    /// schedules and eval cadence stay aligned across restarts).
    it: u64,
    autosaves: u32,
    /// Armed sentinels when `health.enabled`; `None` keeps the loop body
    /// on the exact pre-health path.
    monitor: Option<HealthMonitor>,
    /// Epoch-boundary registry captures (filled by the LGD epoch loop when
    /// `telemetry.enabled`).
    epoch_metrics: Vec<EpochMetricsSnapshot>,
}

impl<'rt> LoopCtx<'rt> {
    /// Build the loop state; `warm` restores θ, the iteration counter and
    /// the optimizer moments from a snapshot's training state.
    fn new(
        cfg: &RunConfig,
        pre: &Preprocessed,
        src: GradSource<'rt>,
        warm: Option<&TrainState>,
    ) -> Result<Self> {
        let n = pre.data.len();
        let d = pre.data.dim();
        if n == 0 {
            return Err(Error::Data("empty training set".into()));
        }
        let batch = cfg.train.batch;
        let iters_per_epoch = (n / batch).max(1) as u64;
        let total_iters = iters_per_epoch * cfg.train.epochs as u64;
        let eval_every = if cfg.train.eval_every > 0 {
            cfg.train.eval_every as u64
        } else {
            iters_per_epoch
        };
        let mut opt = build_optimizer(cfg);
        let mut theta = vec![0.0f32; d];
        let mut it = 0u64;
        if let Some(ts) = warm {
            if ts.theta.len() != d {
                return Err(Error::Store(format!(
                    "snapshot θ has {} parameters but the dataset needs {d}",
                    ts.theta.len()
                )));
            }
            if ts.optimizer != cfg.train.optimizer {
                return Err(Error::Store(format!(
                    "snapshot optimizer state is {:?} but the config trains with {:?}",
                    ts.optimizer, cfg.train.optimizer
                )));
            }
            // Saves happen at epoch boundaries, so the saved counter must
            // sit on one under the *current* shape — a mismatch means the
            // dataset size or train.batch changed since the save, which
            // would silently shift the eval/autosave cadence.
            if ts.iter != ts.epochs_done as u64 * iters_per_epoch {
                return Err(Error::Store(format!(
                    "snapshot iteration counter {} does not sit on an epoch boundary of \
                     {iters_per_epoch} iterations/epoch — train.batch or the dataset \
                     changed since the save",
                    ts.iter
                )));
            }
            opt.import_state(&ts.optim)?;
            theta.copy_from_slice(&ts.theta);
            it = ts.iter;
        }
        let model = native_model(pre.data.task);
        let pjrt = match src {
            GradSource::Native => None,
            GradSource::Pjrt(rt) => {
                let lin = PjrtLinear::new(rt, pre.data.task, batch, d)?;
                Some((rt, lin))
            }
        };
        Ok(LoopCtx {
            batch,
            iters_per_epoch,
            total_iters,
            eval_every,
            opt,
            model,
            pjrt,
            theta,
            grad: vec![0.0f32; d],
            acc: vec![0.0f32; d],
            idxs: vec![0usize; batch],
            weights: vec![0.0f64; batch],
            curve: Vec::new(),
            it,
            autosaves: 0,
            monitor: cfg.health.enabled.then(|| HealthMonitor::new(&cfg.health)),
            epoch_metrics: Vec::new(),
        })
    }

    /// Mean train/test loss through the run's backend (the caller keeps
    /// eval time off the training clock).
    fn eval_now(&mut self, pre: &Preprocessed, test: &Dataset) -> Result<(f64, f64)> {
        eval_losses(pre, test, self.model.as_ref(), &mut self.pjrt, &self.theta)
    }

    /// Append a curve point at the current iteration.
    fn push_point(&mut self, wall: f64, train_loss: f64, test_loss: f64) {
        self.curve.push(CurvePoint {
            iter: self.it,
            epoch: self.it as f64 / self.iters_per_epoch as f64,
            wall,
            train_loss,
            test_loss,
        });
    }

    /// Eval + record in one step (loop entry points).
    fn eval_point(&mut self, pre: &Preprocessed, test: &Dataset, wall: f64) -> Result<()> {
        let (tr, te) = self.eval_now(pre, test)?;
        self.push_point(wall, tr, te);
        Ok(())
    }

    /// One gradient estimate + optimizer update from a drawn batch. With
    /// the health supervisor armed, the batch gradient is checked for
    /// finiteness *before* the optimizer step (a trip leaves θ and the
    /// moments untouched) and θ is checked after it; `Some(trip)` hands
    /// the verdict to the caller's recovery path. Untripped, the float
    /// stream is identical to the unsupervised body — the sentinels only
    /// read.
    fn grad_update(&mut self, pre: &Preprocessed, draws: &[WeightedDraw]) -> Result<Option<Trip>> {
        accumulate_grad(
            pre,
            self.model.as_ref(),
            &mut self.pjrt,
            draws,
            self.batch,
            &self.theta,
            &mut self.grad,
            &mut self.idxs,
            &mut self.weights,
            &mut self.acc,
        )?;
        if self.monitor.is_some() && !all_finite(&self.acc) {
            let poisoned =
                attribute_poison(pre, self.model.as_ref(), draws, &self.theta, &mut self.grad);
            let mon = self.monitor.as_mut().expect("checked above");
            return Ok(Some(mon.trip_grad(poisoned)));
        }
        self.opt.step(&mut self.theta, &self.acc);
        if faults::should_fail(faults::THETA_POISON) {
            self.theta[0] = f32::NAN;
        }
        if let Some(mon) = self.monitor.as_mut() {
            if let Some(trip) = mon.observe_theta(&self.theta) {
                return Ok(Some(trip));
            }
        }
        Ok(None)
    }

    /// Run the loss sentinel (and the `LOSS_CORRUPT` failpoint) over a
    /// fresh train-loss evaluation. Shared by the sync cadence eval and
    /// the async callback (which computes its wall-clock differently).
    fn check_loss(&mut self, tr: &mut f64) -> Option<Trip> {
        if faults::should_fail(faults::LOSS_CORRUPT) {
            *tr = f64::NAN;
        }
        self.monitor.as_mut().and_then(|mon| mon.observe_loss(*tr))
    }

    /// Eval + record with the loss sentinel in the path: a tripping eval
    /// is not pushed onto the curve (the doomed point would survive the
    /// rollback's truncation only to mislead the plots).
    fn eval_checked(
        &mut self,
        pre: &Preprocessed,
        test: &Dataset,
        wall: f64,
    ) -> Result<Option<Trip>> {
        let (mut tr, te) = self.eval_now(pre, test)?;
        if let Some(trip) = self.check_loss(&mut tr) {
            return Ok(Some(trip));
        }
        self.push_point(wall, tr, te);
        Ok(None)
    }

    /// Is a curve eval due at the current iteration?
    fn due_eval(&self) -> bool {
        self.it % self.eval_every == 0 || self.it == self.total_iters
    }

    /// Assemble the run outcome.
    fn outcome(
        self,
        wall_secs: f64,
        preprocess_secs: f64,
        est_stats: EstimatorStats,
        estimator: String,
        shard_build_secs: Vec<f64>,
        resumed: bool,
    ) -> TrainOutcome {
        TrainOutcome {
            curve: self.curve,
            theta: self.theta,
            wall_secs,
            preprocess_secs,
            iterations: self.it,
            est_stats,
            estimator,
            shard_build_secs,
            resumed,
            autosaves: self.autosaves,
            health: self.monitor.map(|m| m.report).unwrap_or_default(),
            epoch_metrics: self.epoch_metrics,
        }
    }
}

/// Run `steps` synchronous draw → gradient → update steps, timing each step
/// into the training clock and evaluating at the cadence (eval excluded
/// from the clock). Shared by the SGD baseline and the synchronous LGD
/// epoch loop. A sentinel trip stops the loop early and hands the verdict
/// back with the clock so far; the caller owns recovery.
fn run_sync_steps(
    ctx: &mut LoopCtx<'_>,
    est: &mut dyn GradientEstimator,
    pre: &Preprocessed,
    test: &Dataset,
    steps: u64,
    mut train_wall: f64,
    draws: &mut Vec<WeightedDraw>,
) -> Result<(f64, Option<Trip>)> {
    // Register-once handle: the hot loop below touches only the atomics.
    let draw_hist = Registry::global().histogram("train.draw_secs");
    for _ in 0..steps {
        let step_t = Instant::now();
        // --- sample ---
        if ctx.batch == 1 {
            draws.clear();
            draws.push(est.draw(&ctx.theta));
        } else {
            est.draw_batch(&ctx.theta, ctx.batch, draws);
        }
        draw_hist.observe_secs(step_t.elapsed().as_secs_f64());
        ctx.it += 1;
        // --- gradient estimate + update ---
        if let Some(trip) = ctx.grad_update(pre, draws)? {
            train_wall += step_t.elapsed().as_secs_f64();
            return Ok((train_wall, Some(trip)));
        }
        train_wall += step_t.elapsed().as_secs_f64();
        if ctx.due_eval() {
            if let Some(trip) = ctx.eval_checked(pre, test, train_wall)? {
                return Ok((train_wall, Some(trip)));
            }
        }
    }
    Ok((train_wall, None))
}

/// Save the engine + training state at an epoch boundary when the config
/// asks for it (every `store.autosave_epochs` epochs, and always at the
/// final epoch when a path is configured).
fn maybe_autosave<H: SnapshotHasher>(
    cfg: &RunConfig,
    est: &ShardedLgdEstimator<'_, H>,
    ctx: &mut LoopCtx<'_>,
    epochs_done: u32,
) -> Result<()> {
    let Some(path) = &cfg.store.path else { return Ok(()) };
    let cadence = cfg.store.autosave_epochs as u32;
    let last = epochs_done as usize == cfg.train.epochs;
    if !(last || (cadence > 0 && epochs_done % cadence == 0)) {
        return Ok(());
    }
    let ts = TrainState {
        theta: ctx.theta.clone(),
        iter: ctx.it,
        epochs_done,
        optimizer: cfg.train.optimizer,
        optim: ctx.opt.export_state(),
    };
    // With the supervisor armed, every autosave carries a health stamp —
    // the loop only reaches an epoch boundary through healthy steps, so
    // the verdict is `healthy: true` with the run's counters alongside.
    // Unsupervised saves stay byte-identical to the pre-health format.
    let stamp = ctx.monitor.as_ref().map(|m| HealthStamp {
        healthy: true,
        sentinel_trips: m.report.sentinel_trips(),
        quarantined: m.report.quarantined,
        rollbacks: m.report.rollbacks,
        loss: ctx.curve.last().map(|p| p.train_loss).unwrap_or(f64::NAN),
    });
    {
        let _sp = crate::span!("store.snapshot_write", epoch = epochs_done);
        snapshot::save_rotated_stamped(path, cfg.store.keep, est, Some(&ts), stamp.as_ref())?;
    }
    ctx.autosaves += 1;
    // Metrics ride along with every autosave: a best-effort Prometheus
    // sidecar next to the snapshot base path. Never fails the save.
    if cfg.telemetry.enabled {
        if probes::armed() {
            probes::publish(Registry::global());
        }
        let _ = std::fs::write(
            path.with_extension("metrics.prom"),
            prom::render(Registry::global()),
        );
    }
    Ok(())
}

/// The rollback-to-last-good state machine, entered when a sentinel
/// trips. Charges the rollback budget (a clean [`Error::Health`] once
/// `health.max_rollbacks` is spent), scans the rotation slots for the
/// newest health-stamped-good snapshot, rebuilds the estimator from it,
/// re-applies every quarantine verdict so far (the restored engine
/// predates them) plus whatever this trip attributed, and rewinds
/// θ/iteration/optimizer/curve state to the save point. The caller
/// replaces its estimator with the returned one and re-enters the epoch
/// loop at the rewound `epoch`.
#[allow(clippy::too_many_arguments)]
fn rollback<'p, H: SnapshotHasher + Clone>(
    cfg: &RunConfig,
    pre: &'p Preprocessed,
    hasher: H,
    ctx: &mut LoopCtx<'_>,
    trip: &Trip,
    quarantined: &mut Vec<usize>,
    epoch: &mut usize,
) -> Result<ShardedLgdEstimator<'p, H>> {
    {
        let mon = ctx.monitor.as_mut().expect("a trip implies an armed supervisor");
        mon.report.rollbacks += 1;
        Registry::global().counter("health.rollbacks").inc();
        if mon.report.rollbacks > cfg.health.max_rollbacks as u64 {
            return Err(Error::Health(format!(
                "{}; rollback budget exhausted (health.max_rollbacks = {})",
                trip.describe(),
                cfg.health.max_rollbacks
            )));
        }
    }
    let Some(base) = &cfg.store.path else {
        return Err(Error::Health(format!(
            "{}; no store.path configured to roll back to",
            trip.describe()
        )));
    };
    let rec = snapshot::recover_healthy(base, cfg.store.keep)
        .map_err(|e| Error::Health(format!("{}; rollback failed: {e}", trip.describe())))?;
    let Some(ts) = rec.snap.train else {
        return Err(Error::Health(format!(
            "{}; snapshot {} carries no training state to roll back to",
            trip.describe(),
            rec.path.display()
        )));
    };
    if ts.theta.len() != ctx.theta.len() {
        return Err(Error::Store(format!(
            "rollback snapshot θ has {} parameters but the run trains {}",
            ts.theta.len(),
            ctx.theta.len()
        )));
    }
    if ts.optimizer != cfg.train.optimizer {
        return Err(Error::Store(format!(
            "rollback snapshot optimizer state is {:?} but the config trains with {:?}",
            ts.optimizer, cfg.train.optimizer
        )));
    }
    let mut est = snapshot::restore_estimator(pre, hasher, rec.snap.engine)?;
    if cfg.lsh.rebalance_threshold > 0.0 {
        est.set_rebalance_threshold(cfg.lsh.rebalance_threshold);
    }
    // Quarantine: this trip's attributions join the run's cumulative
    // eviction list, and the whole list is applied to the restored engine
    // (supervisor verdicts survive rollbacks; only fresh evictions count).
    let mut fresh_ids: Vec<usize> = Vec::new();
    if let Trip::Grad { poisoned } = trip {
        for &id in poisoned {
            if !quarantined.contains(&id) {
                quarantined.push(id);
                fresh_ids.push(id);
            }
        }
    }
    let mut fresh = 0u64;
    for &id in quarantined.iter() {
        if est.remove(id)? && fresh_ids.contains(&id) {
            fresh += 1;
        }
    }
    ctx.theta.copy_from_slice(&ts.theta);
    ctx.it = ts.iter;
    ctx.opt.import_state(&ts.optim)?;
    ctx.opt.scale_lr(cfg.health.rollback_lr_factor);
    ctx.curve.retain(|p| p.iter <= ts.iter);
    {
        let mon = ctx.monitor.as_mut().expect("a trip implies an armed supervisor");
        mon.report.quarantined += fresh;
        mon.rollback_reset();
    }
    *epoch = ts.epochs_done as usize;
    Ok(est)
}

/// Run one training configuration. `test` may be empty (test loss = 0).
/// LGD runs always go through the monomorphized sharded path (shards = 1
/// is `LgdEstimator` draw-for-draw); with `lsh.async_workers > 0` the step
/// loop is fully pipelined through the async draw engine. When
/// `store.path` is set the engine (plus θ/optimizer state) is persisted at
/// epoch boundaries — see [`train_resumed`] for the warm-start side.
pub fn train(
    cfg: &RunConfig,
    pre: &Preprocessed,
    test: &Dataset,
    src: GradSource<'_>,
) -> Result<TrainOutcome> {
    if cfg.store.resume {
        // A resume config reaching the cold entry point would train from
        // scratch and then overwrite the checkpoint at the final autosave —
        // the exact failure the CLI guards against; guard the library API
        // the same way.
        return Err(Error::Config(
            "store.resume is set — load the snapshot and call train_resumed \
             (the CLI's --resume does this)"
                .into(),
        ));
    }
    // Bitwise-invisible perf A/B (docs/numerics.md); set before any kernel
    // touches data so the whole run uses one dispatch path.
    crate::core::numerics::set_kernel_mode(cfg.lsh.kernel);
    match cfg.train.estimator {
        EstimatorKind::Sgd => train_sgd(cfg, pre, test, src),
        EstimatorKind::Lgd => {
            let hd = pre.hashed.cols();
            AnyHasher::from_lsh_config(&cfg.lsh, hd)
                .visit(LgdRun { cfg, pre, test, src, warm: None })
        }
    }
}

/// Warm-start training from a loaded snapshot: the engine is restored
/// (zero table-build work, zero hash invocations), θ/iteration/optimizer
/// state continue where the save left them, and the run proceeds until
/// `cfg.train.epochs` *total* epochs are done. The snapshot owns the
/// training dataset; `test` comes from the caller (it is not persisted).
pub fn train_resumed(
    cfg: &RunConfig,
    test: &Dataset,
    src: GradSource<'_>,
    snap: LoadedSnapshot,
) -> Result<TrainOutcome> {
    if cfg.train.estimator != EstimatorKind::Lgd {
        return Err(Error::Config("--resume requires train.estimator = \"lgd\"".into()));
    }
    crate::core::numerics::set_kernel_mode(cfg.lsh.kernel);
    // The engine state rides the snapshot, so a config that disagrees on
    // the identity-critical knobs would produce a run that is not what the
    // config declares — reject it instead of silently serving the
    // snapshot's parameters under the config's name. (decode() guarantees
    // the meta summary agrees with the decoded hasher, so comparing kinds
    // directly is exact.)
    let m = &snap.meta;
    if snap.hasher.kind() != cfg.lsh.hasher || m.k != cfg.lsh.k || m.l != cfg.lsh.l {
        return Err(Error::Config(format!(
            "snapshot was built with hasher {} (K={}, L={}) but the config says {} \
             (K={}, L={}) — resume with a matching config or re-index",
            m.hasher,
            m.k,
            m.l,
            cfg.lsh.hasher.name(),
            cfg.lsh.k,
            cfg.lsh.l
        )));
    }
    if m.shards != cfg.lsh.shards {
        return Err(Error::Config(format!(
            "snapshot holds {} shard(s) but the config says {} — resume with --shards {} \
             or re-index",
            m.shards, cfg.lsh.shards, m.shards
        )));
    }
    if m.mirror != cfg.lsh.mirror {
        return Err(Error::Config(format!(
            "snapshot was built with lsh.mirror = {} but the config says {} — mirroring \
             changes the sampling distribution, resume with a matching config or re-index",
            m.mirror, cfg.lsh.mirror
        )));
    }
    let LoadedSnapshot { pre, hasher, engine, train: tstate, .. } = snap;
    hasher.visit(LgdRun { cfg, pre: &pre, test, src, warm: Some((engine, tstate)) })
}

/// The monomorphized LGD run: cold build or snapshot restore, then the
/// sync or async epoch loop.
struct LgdRun<'c, 'p, 't, 'rt> {
    cfg: &'c RunConfig,
    pre: &'p Preprocessed,
    test: &'t Dataset,
    src: GradSource<'rt>,
    warm: Option<(EngineDump, Option<TrainState>)>,
}

impl<'c, 'p, 't, 'rt> HasherVisitor for LgdRun<'c, 'p, 't, 'rt> {
    type Out = Result<TrainOutcome>;

    fn visit<H>(self, hasher: H) -> Self::Out
    where
        H: SnapshotHasher + Clone + 'static,
    {
        let LgdRun { cfg, pre, test, src, warm } = self;
        let t0 = Instant::now();
        let (est, tstate, resumed) = match warm {
            Some((engine, ts)) => {
                let mut est = snapshot::restore_estimator(pre, hasher.clone(), engine)?;
                // Live-engine tuning follows the config on a warm start
                // too: an explicit rebalance threshold overrides the
                // persisted one (the cold path applies it the same way).
                if cfg.lsh.rebalance_threshold > 0.0 {
                    est.set_rebalance_threshold(cfg.lsh.rebalance_threshold);
                }
                (est, ts, true)
            }
            None => (build_sharded_estimator(cfg, pre, hasher.clone())?, None, false),
        };
        let preprocess_secs = t0.elapsed().as_secs_f64();
        run_lgd(cfg, pre, test, src, hasher, est, tstate, resumed, preprocess_secs)
    }
}

#[allow(clippy::too_many_arguments)]
fn run_lgd<'p, H: SnapshotHasher + Clone>(
    cfg: &RunConfig,
    pre: &'p Preprocessed,
    test: &Dataset,
    src: GradSource<'_>,
    hasher: H,
    mut est: ShardedLgdEstimator<'p, H>,
    tstate: Option<TrainState>,
    resumed: bool,
    preprocess_secs: f64,
) -> Result<TrainOutcome> {
    let mut ctx = LoopCtx::new(cfg, pre, src, tstate.as_ref())?;
    let shard_build_secs = est.build_report().per_shard_secs.clone();
    let asynchronous = cfg.lsh.async_workers > 0;
    let engine = DrawEngineConfig {
        workers: cfg.lsh.async_workers,
        queue_depth: cfg.lsh.queue_depth,
        ..Default::default()
    };
    let start_epoch = tstate.as_ref().map(|t| t.epochs_done as usize).unwrap_or(0);

    // Operator-directed quarantine: evict the configured example ids from
    // the engine before the first draw, on the cold and warm paths alike.
    // These evictions are config, not supervisor verdicts, so they do not
    // count in the health report.
    for &id in &cfg.data.quarantine {
        if id >= pre.data.len() {
            return Err(Error::Config(format!(
                "data.quarantine: example id {id} is out of range for a dataset of {} examples",
                pre.data.len()
            )));
        }
        est.remove(id)?;
    }

    // The table build (or snapshot restore) counts as wall-clock spent
    // before the first step; loss evals never enter the clock.
    let mut train_wall = preprocess_secs;
    ctx.eval_point(pre, test, train_wall)?;

    let mut draws: Vec<WeightedDraw> = Vec::with_capacity(ctx.batch);
    // Supervisor-evicted example ids, cumulative across rollbacks (a
    // restored engine predates the evictions, so they must be re-applied).
    let mut auto_quarantine: Vec<usize> = Vec::new();
    let mut epoch = start_epoch;
    while epoch < cfg.train.epochs {
        let _ep_span = crate::span!("train.epoch", epoch = epoch as u64);
        let tripped: Option<Trip>;
        if asynchronous {
            // One draw-engine session per epoch: the sampling query is
            // frozen at the epoch's entry θ (stale proposal, *exact*
            // probabilities ⇒ unbiased), so batch t+1 assembles on the
            // sampler threads while batch t's gradient runs here. Queue
            // stalls are real un-hidden wall-clock and stay on the clock.
            let steps = ctx.iters_per_epoch as usize;
            let m = ctx.batch;
            let frozen = ctx.theta.clone();
            let epoch_t = Instant::now();
            let wall_base = train_wall;
            let mut eval_secs = 0.0f64;
            let mut abort: Option<Error> = None;
            let mut trip: Option<Trip> = None;
            {
                let ctx = &mut ctx;
                let abort = &mut abort;
                let eval_secs = &mut eval_secs;
                let trip_slot = &mut trip;
                run_session(&mut est, &engine, &frozen, m, steps, |_, dr| {
                    ctx.it += 1;
                    match ctx.grad_update(pre, dr) {
                        Err(e) => {
                            *abort = Some(e);
                            return false;
                        }
                        Ok(Some(t)) => {
                            *trip_slot = Some(t);
                            return false;
                        }
                        Ok(None) => {}
                    }
                    if ctx.due_eval() {
                        let ev = Instant::now();
                        match ctx.eval_now(pre, test) {
                            Ok((mut tr, te)) => {
                                *eval_secs += ev.elapsed().as_secs_f64();
                                let wall =
                                    wall_base + epoch_t.elapsed().as_secs_f64() - *eval_secs;
                                if let Some(t) = ctx.check_loss(&mut tr) {
                                    *trip_slot = Some(t);
                                    return false;
                                }
                                ctx.push_point(wall, tr, te);
                            }
                            Err(e) => {
                                *abort = Some(e);
                                return false;
                            }
                        }
                    }
                    true
                })?;
            }
            if let Some(e) = abort {
                return Err(e);
            }
            train_wall = wall_base + epoch_t.elapsed().as_secs_f64() - eval_secs;
            tripped = trip;
        } else {
            let steps = ctx.iters_per_epoch;
            let (wall, trip) =
                run_sync_steps(&mut ctx, &mut est, pre, test, steps, train_wall, &mut draws)?;
            train_wall = wall;
            tripped = trip;
        }
        match tripped {
            None => {
                // Epoch boundary: the only legal save point (the session
                // borrow has been released; the generation counter is
                // quiescent).
                maybe_autosave(cfg, &est, &mut ctx, (epoch + 1) as u32)?;
                epoch += 1;
                if cfg.telemetry.enabled {
                    if probes::armed() {
                        probes::publish(Registry::global());
                    }
                    ctx.epoch_metrics.push(EpochMetricsSnapshot {
                        epoch: epoch as u32,
                        samples: Registry::global().flat(),
                    });
                }
            }
            Some(trip) => {
                est = rollback(
                    cfg,
                    pre,
                    hasher.clone(),
                    &mut ctx,
                    &trip,
                    &mut auto_quarantine,
                    &mut epoch,
                )?;
            }
        }
    }

    let name = if asynchronous {
        "lgd-async"
    } else if est.shards() > 1 {
        "lgd-sharded"
    } else {
        "lgd"
    };
    let stats = est.stats();
    Ok(ctx.outcome(train_wall, preprocess_secs, stats, name.into(), shard_build_secs, resumed))
}

/// The uniform-sampling SGD baseline (boxed estimator, shared loop body).
fn train_sgd(
    cfg: &RunConfig,
    pre: &Preprocessed,
    test: &Dataset,
    src: GradSource<'_>,
) -> Result<TrainOutcome> {
    let t0 = Instant::now();
    let (mut est, shard_build_secs) = build_estimator_reported(cfg, pre)?;
    let preprocess_secs = t0.elapsed().as_secs_f64();
    let mut ctx = LoopCtx::new(cfg, pre, src, None)?;
    let mut train_wall = preprocess_secs;
    ctx.eval_point(pre, test, train_wall)?;
    let mut draws: Vec<WeightedDraw> = Vec::with_capacity(ctx.batch);
    let steps = ctx.total_iters;
    let (wall, tripped) =
        run_sync_steps(&mut ctx, est.as_mut(), pre, test, steps, train_wall, &mut draws)?;
    train_wall = wall;
    if let Some(trip) = tripped {
        // The uniform baseline has no engine to quarantine from and no
        // health-stamped snapshot chain — fail fast with the verdict.
        return Err(Error::Health(format!(
            "{} (the sgd estimator has no rollback path)",
            trip.describe()
        )));
    }
    let stats = est.stats();
    let name = est.name().to_string();
    Ok(ctx.outcome(train_wall, preprocess_secs, stats, name, shard_build_secs, false))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{HasherKind, RunConfig};
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::optim::Schedule;

    fn small_cfg(est: EstimatorKind) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.train.estimator = est;
        cfg.train.epochs = 4;
        cfg.train.schedule = Schedule::Const(0.05);
        cfg.lsh.k = 4;
        cfg.lsh.l = 16;
        cfg.lsh.hasher = HasherKind::Dense;
        cfg
    }

    fn setup(n: usize, d: usize, seed: u64) -> (Preprocessed, Dataset) {
        let ds = SynthSpec::power_law("t", n, d, seed).generate().unwrap();
        let (tr, te) = ds.split(0.8, 1).unwrap();
        (preprocess(tr, &PreprocessOptions::default()).unwrap(), te)
    }

    #[test]
    fn sgd_training_reduces_loss() {
        let (pre, te) = setup(500, 10, 3);
        let cfg = small_cfg(EstimatorKind::Sgd);
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "sgd");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first * 0.8, "loss {first} -> {last}");
        assert_eq!(out.iterations, 4 * 400);
        assert!(out.preprocess_secs < 0.01, "SGD has no preprocessing");
        assert!(!out.resumed);
        assert_eq!(out.autosaves, 0);
    }

    #[test]
    fn lgd_training_reduces_loss() {
        let (pre, te) = setup(500, 10, 5);
        let cfg = small_cfg(EstimatorKind::Lgd);
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "lgd");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first * 0.8, "loss {first} -> {last}");
        assert!(out.est_stats.cost.codes > 0, "LGD must compute hashes");
    }

    #[test]
    fn sharded_lgd_training_reduces_loss() {
        let (pre, te) = setup(500, 10, 5);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.shards = 4;
        // exercise the config plumbing: a static training set starts (and
        // stays) balanced, so the knob must be a no-op here
        cfg.lsh.rebalance_threshold = 1.25;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "lgd-sharded");
        assert_eq!(out.shard_build_secs.len(), 4, "one build timing per shard");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first * 0.8, "loss {first} -> {last}");
        assert!(out.est_stats.cost.codes > 0, "sharded LGD must compute hashes");
        assert_eq!(out.est_stats.migrations, 0, "static training must not migrate");
        assert_eq!(out.est_stats.rebalances, 0);
    }

    /// The `lsh.sealed` knob is a pure layout swap: training with the CSR
    /// arena and with Vec buckets produces identical loss curves under the
    /// same seed (draw-for-draw identity end-to-end through the trainer).
    #[test]
    fn sealed_knob_is_layout_only() {
        let (pre, te) = setup(400, 8, 13);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.shards = 2;
        assert!(cfg.lsh.sealed, "default on");
        let sealed = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        cfg.lsh.sealed = false;
        let vecs = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(sealed.curve.len(), vecs.curve.len());
        for (a, b) in sealed.curve.iter().zip(&vecs.curve) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.train_loss, b.train_loss, "iter {}: layouts diverged", a.iter);
            assert_eq!(a.test_loss, b.test_loss);
        }
        assert_eq!(sealed.est_stats.fallbacks, vecs.est_stats.fallbacks);
    }

    /// Pipelined trainer: `lsh.async_workers > 0` runs the step loop
    /// through the draw engine (per-shard workers here); the run still
    /// converges and the outcome carries the queue counters.
    #[test]
    fn async_trainer_reduces_loss() {
        let (pre, te) = setup(500, 10, 5);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.shards = 2;
        cfg.lsh.async_workers = 2;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "lgd-async");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first * 0.8, "async loss {first} -> {last}");
        let st = out.est_stats;
        assert_eq!(st.draws, out.iterations, "batch = 1: one draw per iteration");
        assert_eq!(
            st.prefetch_hits + st.queue_stalls,
            out.iterations,
            "every step pops exactly one batch off the engine queue"
        );
        assert_eq!(st.migrations, 0, "static training must not migrate");
    }

    /// The smallest async config — one worker, one shard (replay mode) —
    /// trains with a well-formed monotone curve.
    #[test]
    fn async_single_worker_single_shard_trains() {
        let (pre, te) = setup(300, 8, 7);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.async_workers = 1;
        cfg.train.batch = 8;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "lgd-async");
        for w in out.curve.windows(2) {
            assert!(w[1].iter > w[0].iter);
            assert!(w[1].wall >= w[0].wall);
        }
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first, "single-worker async did not descend: {first} -> {last}");
    }

    /// The async knob belongs to the LGD sampler; SGD runs stay on the
    /// synchronous path untouched.
    #[test]
    fn async_knob_ignored_for_sgd() {
        let (pre, te) = setup(200, 8, 9);
        let mut cfg = small_cfg(EstimatorKind::Sgd);
        cfg.lsh.async_workers = 4;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.estimator, "sgd");
        assert_eq!(out.est_stats.prefetch_hits, 0);
    }

    #[test]
    fn curve_is_monotone_in_time_and_iters() {
        let (pre, te) = setup(300, 8, 7);
        let out = train(&small_cfg(EstimatorKind::Lgd), &pre, &te, GradSource::Native).unwrap();
        for w in out.curve.windows(2) {
            assert!(w[1].iter > w[0].iter);
            assert!(w[1].wall >= w[0].wall);
        }
        // epochs land on integers at the per-epoch cadence
        assert!((out.curve.last().unwrap().epoch - 4.0).abs() < 1e-9);
    }

    #[test]
    fn minibatch_runs() {
        let (pre, te) = setup(400, 8, 9);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.train.batch = 16;
        cfg.train.optimizer = OptimizerKind::AdaGrad;
        cfg.train.schedule = Schedule::Const(0.1);
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first, "minibatch adagrad did not descend: {first} -> {last}");
    }

    #[test]
    fn classification_task_trains() {
        let spec = SynthSpec {
            task: Task::Classification,
            ..SynthSpec::power_law("c", 400, 8, 11)
        };
        let ds = spec.generate().unwrap();
        let (tr, te) = ds.split(0.8, 2).unwrap();
        let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.train.schedule = Schedule::Const(0.5);
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first, "logreg did not descend: {first} -> {last}");
    }

    /// Store wiring: a run with `store.path` saves at the autosave cadence
    /// plus the final epoch, and `train_resumed` warm-starts from the file
    /// with zero table-build work (all-zero shard build timings).
    #[test]
    fn autosave_and_resume_wire_through() {
        let (pre, te) = setup(300, 8, 21);
        let dir = std::env::temp_dir().join("lgd-trainer-store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wire.lgdsnap");
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.lsh.shards = 2;
        cfg.train.epochs = 2;
        cfg.store.path = Some(path.clone());
        cfg.store.autosave_epochs = 1;
        let cold = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(cold.autosaves, 2, "one per epoch (the final save coincides)");
        assert!(!cold.resumed);
        // resume for two more epochs
        cfg.train.epochs = 4;
        cfg.store.autosave_epochs = 0;
        cfg.store.resume = true;
        let snap = crate::store::snapshot::load(&path).unwrap();
        assert_eq!(snap.train.as_ref().unwrap().epochs_done, 2);
        let warm = train_resumed(&cfg, &te, GradSource::Native, snap).unwrap();
        assert!(warm.resumed);
        assert_eq!(warm.iterations, cold.iterations * 2, "global counter continues");
        assert!(
            warm.shard_build_secs.iter().all(|&s| s == 0.0),
            "a warm start performs zero table-build work"
        );
        assert_eq!(warm.autosaves, 1, "final save still fires when a path is set");
        assert_eq!(warm.curve.first().unwrap().iter, cold.iterations);
        std::fs::remove_file(&path).unwrap();
    }

    /// The determinism contract: a run with the supervisor armed but never
    /// tripped is bit-for-bit the run without it — θ, the curve, and the
    /// estimator counters. Covered for the sync and async LGD paths.
    #[test]
    fn untripped_supervisor_is_bitwise_invisible() {
        let (pre, te) = setup(400, 8, 17);
        for async_workers in [0usize, 2] {
            let mut cfg = small_cfg(EstimatorKind::Lgd);
            cfg.lsh.shards = 2;
            cfg.lsh.async_workers = async_workers;
            let plain = train(&cfg, &pre, &te, GradSource::Native).unwrap();
            cfg.health.enabled = true;
            let watched = train(&cfg, &pre, &te, GradSource::Native).unwrap();
            assert_eq!(plain.theta, watched.theta, "async_workers = {async_workers}");
            assert_eq!(plain.curve.len(), watched.curve.len());
            for (a, b) in plain.curve.iter().zip(&watched.curve) {
                // wall-clock is timing, not math — compare everything else
                assert_eq!(
                    (a.iter, a.train_loss, a.test_loss),
                    (b.iter, b.train_loss, b.test_loss),
                    "async_workers = {async_workers}"
                );
            }
            assert_eq!(plain.est_stats.draws, watched.est_stats.draws);
            assert_eq!(plain.health, HealthReport::default());
            assert_eq!(watched.health, HealthReport::default(), "nothing may trip");
        }
    }

    /// The telemetry determinism gate: arming the sampling probes (and the
    /// span layer, which is always passively timing) leaves a seeded run
    /// bit-for-bit identical — θ, the curve losses, the estimator
    /// counters. Probes observe the draw stream; they never touch the RNG.
    #[test]
    fn armed_telemetry_is_bitwise_invisible_to_training() {
        let (pre, te) = setup(400, 8, 23);
        for async_workers in [0usize, 2] {
            let mut cfg = small_cfg(EstimatorKind::Lgd);
            cfg.lsh.shards = 2;
            cfg.lsh.async_workers = async_workers;
            probes::disarm();
            let plain = train(&cfg, &pre, &te, GradSource::Native).unwrap();
            probes::arm(512, pre.data.len());
            let observed = train(&cfg, &pre, &te, GradSource::Native).unwrap();
            probes::disarm();
            assert_eq!(plain.theta, observed.theta, "async_workers = {async_workers}");
            assert_eq!(plain.curve.len(), observed.curve.len());
            for (a, b) in plain.curve.iter().zip(&observed.curve) {
                assert_eq!(
                    (a.iter, a.train_loss, a.test_loss),
                    (b.iter, b.train_loss, b.test_loss),
                    "async_workers = {async_workers}"
                );
            }
            assert_eq!(plain.est_stats.draws, observed.est_stats.draws);
            assert_eq!(plain.est_stats.fallbacks, observed.est_stats.fallbacks);
        }
    }

    /// `telemetry.enabled` (the default) captures one registry snapshot
    /// per completed epoch; disabling it empties the capture without
    /// touching the math.
    #[test]
    fn epoch_metrics_capture_follows_the_telemetry_knob() {
        let (pre, te) = setup(300, 8, 27);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        let on = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(on.epoch_metrics.len(), cfg.train.epochs);
        let last = on.epoch_metrics.last().unwrap();
        assert_eq!(last.epoch as usize, cfg.train.epochs);
        assert!(
            last.samples.iter().any(|(k, v)| k == "train.draw_secs.count" && *v >= 1.0),
            "the draw histogram must appear in the epoch capture"
        );
        cfg.telemetry.enabled = false;
        let off = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert!(off.epoch_metrics.is_empty());
        assert_eq!(on.theta, off.theta, "the capture knob must not touch the math");
    }

    /// `data.quarantine` evicts the listed examples before the first draw
    /// (duplicates are harmless); the evictions are operator config, not
    /// supervisor verdicts, so the health counters stay zero. An
    /// out-of-range id is a config error.
    #[test]
    fn operator_quarantine_applies_and_validates() {
        let (pre, te) = setup(300, 8, 19);
        let mut cfg = small_cfg(EstimatorKind::Lgd);
        cfg.data.quarantine = vec![0, 7, 7];
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert_eq!(out.health.quarantined, 0, "operator evictions are not supervisor verdicts");
        let first = out.curve.first().unwrap().train_loss;
        let last = out.curve.last().unwrap().train_loss;
        assert!(last < first, "quarantined run still trains: {first} -> {last}");
        cfg.data.quarantine = vec![pre.data.len()];
        let err = train(&cfg, &pre, &te, GradSource::Native).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err:?}");
    }
}
