//! L3 coordination: the training driver, the streaming ingestion pipeline,
//! the asynchronous pipelined draw engine and the metrics registry.

pub mod draw_engine;
pub mod health;
pub mod metrics;
pub mod pipeline;
pub mod trainer;

pub use draw_engine::{run_session, DrawEngineConfig, DrawQueue, SessionReport};
pub use health::{HealthMonitor, HealthReport, Trip};
pub use metrics::Metrics;
pub use pipeline::{
    build_shard_tables, streaming_build, streaming_build_sharded, PipelineConfig,
    PipelineReport, ShardSet, ShardSetStats, ShardTables,
};
pub use trainer::{build_estimator, train, CurvePoint, GradSource, TrainOutcome};
