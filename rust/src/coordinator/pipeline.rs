//! Streaming ingestion pipeline: Source → Preprocess → Hash → Tables.
//!
//! LGD's one-time preprocessing (normalise, embed into hash space, compute
//! K·L codes, insert into tables) is the natural streaming stage of the
//! system: records flow through bounded channels (backpressure), hash
//! workers parallelise the code computation across the L tables, and a
//! single owner thread applies coded inserts so the table structure never
//! needs locks. The result is bit-identical to the batch
//! [`crate::data::preprocess::preprocess`] + [`LshTables::build`] path
//! (tested below), so the trainer can consume either.
//!
//! The sharded engine has a streaming twin: [`streaming_build_sharded`]
//! routes each incoming record's coded inserts to the per-shard tables of
//! its [`ShardPlan`] owner (one worker thread per shard), producing shard
//! tables byte-identical to the batch [`build_shard_tables`] layout — so
//! the shard-mixture estimator draws identically over either build. After
//! the build, [`ShardSet`] keeps the shards *live*: post-build
//! `insert`/`remove` plus automatic [`ShardPlan::rebalance`]-driven
//! migration when skewed growth pushes the shard imbalance past a
//! configurable threshold (`lsh.rebalance_threshold`), with the exact
//! mixture weights `R_s/R` recomputed after every mutation so Theorem-1
//! unbiasedness holds at every point in the stream.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::core::error::{Error, Result};
use crate::core::matrix::{normalize, Matrix};
use crate::coordinator::metrics::Metrics;
use crate::data::dataset::Dataset;
use crate::data::preprocess::{HashSpace, Preprocessed};
use crate::data::shard::ShardPlan;
use crate::lsh::srp::SrpHasher;
use crate::lsh::tables::{BucketRead, LshTables, TableStore};

/// Pipeline tuning knobs.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Bounded-channel capacity between stages (records).
    pub channel_cap: usize,
    /// Parallel hash workers (tables are striped across them).
    pub hash_workers: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { channel_cap: 256, hash_workers: 4 }
    }
}

/// Timing/throughput report of a pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Records processed.
    pub records: usize,
    /// End-to-end wall seconds.
    pub wall_secs: f64,
    /// Records/second.
    pub throughput: f64,
}

struct RawRecord {
    id: u32,
    x: Vec<f32>,
    y: f32,
}

struct HashJob {
    id: u32,
    v: Arc<Vec<f32>>,
}

struct CodedInsert {
    id: u32,
    table: u32,
    code: u32,
}

/// Hash-space embedding of one (already normalised) record — the single
/// definition every streaming builder shares. Drift between the builders
/// here would silently break their byte-identity with the batch
/// [`crate::data::preprocess::preprocess`] path.
fn embed_record(space: HashSpace, hd: usize, x: &[f32], y: f32) -> Vec<f32> {
    let mut hv = Vec::with_capacity(hd);
    match space {
        HashSpace::LinRegAugmented => {
            hv.extend_from_slice(x);
            hv.push(y);
        }
        HashSpace::LogRegSigned => {
            hv.extend(x.iter().map(|v| y * v));
        }
    }
    hv
}

/// Run the streaming build: consumes `ds`, returns the preprocessed data,
/// the fully-built tables, and a throughput report.
pub fn streaming_build<H>(
    ds: Dataset,
    hasher: H,
    cfg: &PipelineConfig,
    metrics: &Metrics,
) -> Result<(Preprocessed, LshTables<H>, PipelineReport)>
where
    H: SrpHasher + Clone + 'static,
{
    let _n = ds.len();
    let d = ds.dim();
    let task = ds.task;
    let space = HashSpace::for_task(task);
    let hd = space.dim(d);
    if hasher.dim() != hd {
        return Err(Error::Pipeline(format!(
            "hasher dim {} but hash space needs {hd}",
            hasher.dim()
        )));
    }
    let workers = cfg.hash_workers.max(1);
    let l = hasher.l();
    let t0 = Instant::now();

    // Stage channels.
    let (src_tx, src_rx) = sync_channel::<RawRecord>(cfg.channel_cap);
    let mut hash_txs: Vec<SyncSender<HashJob>> = Vec::with_capacity(workers);
    let mut hash_rxs: Vec<Receiver<HashJob>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = sync_channel::<HashJob>(cfg.channel_cap);
        hash_txs.push(tx);
        hash_rxs.push(rx);
    }
    let (ins_tx, ins_rx) = sync_channel::<CodedInsert>(cfg.channel_cap * workers.max(1));

    // --- Source: stream the dataset out of this thread. ---
    let name = ds.name.clone();
    let src = thread::spawn(move || {
        let mut rows = 0usize;
        for i in 0..ds.len() {
            let (x, y) = ds.example(i);
            if src_tx
                .send(RawRecord { id: i as u32, x: x.to_vec(), y })
                .is_err()
            {
                break; // downstream died; it will report the error
            }
            rows += 1;
        }
        rows
    });

    // --- Preprocess: normalise + hash-space embed; fan out to workers. ---
    let pre_handle = thread::spawn(move || -> Result<(Matrix, Vec<f32>, Matrix, Vec<f64>)> {
        let mut xmat = Matrix::zeros(0, 0);
        let mut ys = Vec::new();
        let mut hashed = Matrix::zeros(0, 0);
        let mut norms = Vec::new();
        for mut rec in src_rx.iter() {
            let norm = normalize(&mut rec.x);
            norms.push(norm);
            let hv = Arc::new(embed_record(space, hd, &rec.x, rec.y));
            for tx in &hash_txs {
                tx.send(HashJob { id: rec.id, v: hv.clone() })
                    .map_err(|_| Error::Pipeline("hash worker hung up".into()))?;
            }
            xmat.push_row(&rec.x).map_err(|e| Error::Pipeline(e.to_string()))?;
            ys.push(rec.y);
            hashed
                .push_row(&hv)
                .map_err(|e| Error::Pipeline(e.to_string()))?;
        }
        drop(hash_txs);
        Ok((xmat, ys, hashed, norms))
    });

    // --- Hash workers: tables striped worker w -> tables {w, w+W, ...} ---
    let mut worker_handles = Vec::new();
    for (w, rx) in hash_rxs.into_iter().enumerate() {
        let h = hasher.clone();
        let tx = ins_tx.clone();
        worker_handles.push(thread::spawn(move || -> Result<u64> {
            let mut codes = 0u64;
            for job in rx.iter() {
                let mut t = w;
                while t < l {
                    let code = h.code(t, &job.v);
                    codes += 1;
                    tx.send(CodedInsert { id: job.id, table: t as u32, code })
                        .map_err(|_| Error::Pipeline("table owner hung up".into()))?;
                    t += workers;
                }
            }
            Ok(codes)
        }));
    }
    drop(ins_tx);

    // --- Table owner (this thread): apply coded inserts. ---
    let mut tables = LshTables::new(hasher);
    let mut inserts = 0u64;
    for ins in ins_rx.iter() {
        tables.insert_coded(ins.table as usize, ins.code, ins.id);
        inserts += 1;
    }

    // Join + propagate errors.
    let rows = src.join().map_err(|_| Error::Pipeline("source panicked".into()))?;
    let (xmat, ys, hashed, norms) =
        pre_handle.join().map_err(|_| Error::Pipeline("preprocess panicked".into()))??;
    let mut total_codes = 0u64;
    for h in worker_handles {
        total_codes += h.join().map_err(|_| Error::Pipeline("hash worker panicked".into()))??;
    }
    if inserts != total_codes || inserts != (rows as u64) * l as u64 {
        return Err(Error::Pipeline(format!(
            "insert count {inserts} != codes {total_codes} != rows*L {}",
            rows as u64 * l as u64
        )));
    }
    tables.finish_coded_inserts(rows);

    let wall = t0.elapsed().as_secs_f64();
    metrics.count("pipeline.records", rows as u64);
    metrics.count("pipeline.codes", total_codes);
    metrics.observe("pipeline.wall", wall);

    let data = Dataset::new(name, xmat, ys, task).map_err(|e| Error::Pipeline(e.to_string()))?;
    let pre = Preprocessed { data, hashed, space, center: Vec::new(), norms };
    let report = PipelineReport {
        records: rows,
        wall_secs: wall,
        throughput: rows as f64 / wall.max(1e-12),
    };
    Ok((pre, tables, report))
}

/// One shard of the sharded sampling engine: the slice of stored rows it
/// owns, its copy of those vectors, their norms, and the LSH tables built
/// over them. Row ids index the *virtual* stored matrix `[base; −base]`:
/// id `i < n` is `base.row(i)`, id `i + n` is its negation (mirrored
/// storage) — matching `LgdEstimator`'s stored-row layout.
#[derive(Clone)]
pub struct ShardTables<H: SrpHasher> {
    /// Virtual stored-row id of each local row (local row j ↔ rows\[j\]).
    pub rows: Vec<u32>,
    /// Local copy of the owned vectors (row j = the vector of rows\[j\]).
    pub stored: Matrix,
    /// Precomputed ‖row‖ for the sampling hot path.
    pub norms: Vec<f64>,
    /// Tables over the local rows (bucket ids are local row indices).
    /// Builders produce the Vec layout; the estimator seals it into the
    /// CSR arena when `lsh.sealed` is on.
    pub tables: TableStore<H>,
    /// Wall-clock seconds this shard's build took on its worker thread.
    pub build_secs: f64,
}

impl<H: SrpHasher> ShardTables<H> {
    /// Seal this shard's tables into the CSR bucket arena (no-op when
    /// already sealed). Bucket order is preserved, so draws are unchanged.
    pub fn seal(self) -> Self {
        let ShardTables { rows, stored, norms, tables, build_secs } = self;
        ShardTables { rows, stored, norms, tables: tables.seal(), build_secs }
    }
}

/// Build per-shard LSH tables concurrently, one worker thread per shard
/// (`std::thread::scope`). `base` holds one hash-space row per example
/// (e.g. `Preprocessed::hashed`); `plan` partitions the examples, and each
/// shard copies the rows of its member examples — plus their negations when
/// `mirror`, materialized on the fly so the full mirrored matrix never
/// exists (the peak-memory win of sharded builds). Every shard clones the
/// same hasher, so query codes agree across shards and a single
/// [`crate::lsh::sampler::QueryCache`] can serve all of them. Per-shard
/// build time is recorded under the `pipeline.shard_build` timer and row
/// counts under the `pipeline.shard_rows` counter.
pub fn build_shard_tables<H>(
    base: &Matrix,
    plan: &ShardPlan,
    mirror: bool,
    hasher: &H,
    metrics: &Metrics,
) -> Result<Vec<ShardTables<H>>>
where
    H: SrpHasher + Clone,
{
    let n: usize = plan.counts().iter().sum();
    if base.rows() != n {
        return Err(Error::Pipeline(format!(
            "shard plan covers {n} examples but base matrix has {} rows",
            base.rows()
        )));
    }
    let results: Vec<std::thread::Result<Result<ShardTables<H>>>> = thread::scope(|scope| {
        let mut handles = Vec::with_capacity(plan.shards());
        for s in 0..plan.shards() {
            let members = plan.members(s);
            let h = hasher.clone();
            handles.push(scope.spawn(move || -> Result<ShardTables<H>> {
                let _sp = crate::span!("pipeline.shard_build", shard = s);
                let t0 = Instant::now();
                let mut rows: Vec<u32> = members.to_vec();
                let mut local = Matrix::zeros(0, 0);
                for &i in members {
                    local
                        .push_row(base.row(i as usize))
                        .map_err(|e| Error::Pipeline(e.to_string()))?;
                }
                if mirror {
                    rows.extend(members.iter().map(|&i| i + n as u32));
                    for &i in members {
                        let neg: Vec<f32> = base.row(i as usize).iter().map(|v| -v).collect();
                        local.push_row(&neg).map_err(|e| Error::Pipeline(e.to_string()))?;
                    }
                }
                let norms: Vec<f64> = local.row_norms();
                let tables = LshTables::build(h, (0..local.rows()).map(|i| local.row(i)))?;
                Ok(ShardTables {
                    rows,
                    stored: local,
                    norms,
                    tables: TableStore::Vec(tables),
                    build_secs: t0.elapsed().as_secs_f64(),
                })
            }));
        }
        handles.into_iter().map(|h| h.join()).collect()
    });
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        let st = r.map_err(|_| Error::Pipeline("shard build worker panicked".into()))??;
        metrics.observe("pipeline.shard_build", st.build_secs);
        metrics.count("pipeline.shard_rows", st.rows.len() as u64);
        out.push(st);
    }
    Ok(out)
}

/// Streaming *sharded* build: Source → Preprocess → per-shard table
/// workers. Each incoming record is normalised and embedded once, then its
/// coded inserts are routed to the [`ShardPlan`] owner's worker thread
/// (round-robin plan, matching [`crate::estimator::ShardedLgdEstimator`]'s
/// batch construction), which applies them through the
/// `insert_coded`/`finish_coded_inserts` path of its private `LshTables` —
/// no locks, one owner per table set. Mirror rows are appended after the
/// stream drains so every shard's layout is `[base rows asc; mirrors asc]`,
/// byte-identical to [`build_shard_tables`]: the shard-mixture estimator
/// draws the same sequence over either build (tested below and in the
/// integration suite). Parallelism is one worker per shard
/// (`cfg.hash_workers` is not used here); `cfg.channel_cap` bounds every
/// stage channel.
pub fn streaming_build_sharded<H>(
    ds: Dataset,
    hasher: H,
    shards: usize,
    mirror: bool,
    cfg: &PipelineConfig,
    metrics: &Metrics,
) -> Result<(Preprocessed, Vec<ShardTables<H>>, PipelineReport)>
where
    H: SrpHasher + Clone,
{
    let n = ds.len();
    let d = ds.dim();
    let task = ds.task;
    let space = HashSpace::for_task(task);
    let hd = space.dim(d);
    if hasher.dim() != hd {
        return Err(Error::Pipeline(format!(
            "hasher dim {} but hash space needs {hd}",
            hasher.dim()
        )));
    }
    let plan = ShardPlan::round_robin(n, shards)?;
    let name = ds.name.clone();
    let t0 = Instant::now();

    let (src_tx, src_rx) = sync_channel::<RawRecord>(cfg.channel_cap);
    let mut shard_txs: Vec<SyncSender<HashJob>> = Vec::with_capacity(shards);
    let mut shard_rxs: Vec<Receiver<HashJob>> = Vec::with_capacity(shards);
    for _ in 0..shards {
        let (tx, rx) = sync_channel::<HashJob>(cfg.channel_cap);
        shard_txs.push(tx);
        shard_rxs.push(rx);
    }

    type PreOut = Result<(Matrix, Vec<f32>, Matrix, Vec<f64>)>;
    let plan_ref = &plan;
    let hasher_ref = &hasher;
    let (src_res, pre_res, worker_res) = thread::scope(|scope| {
        // --- Source: stream the dataset out of this thread. ---
        let src = scope.spawn(move || {
            let mut rows = 0usize;
            for i in 0..ds.len() {
                let (x, y) = ds.example(i);
                if src_tx.send(RawRecord { id: i as u32, x: x.to_vec(), y }).is_err() {
                    break; // downstream died; it will report the error
                }
                rows += 1;
            }
            rows
        });

        // --- Preprocess: normalise + embed; route to the owning shard. ---
        let pre = scope.spawn(move || -> PreOut {
            let mut xmat = Matrix::zeros(0, 0);
            let mut ys = Vec::new();
            let mut hashed = Matrix::zeros(0, 0);
            let mut norms = Vec::new();
            for mut rec in src_rx.iter() {
                let norm = normalize(&mut rec.x);
                norms.push(norm);
                let hv = Arc::new(embed_record(space, hd, &rec.x, rec.y));
                let s = plan_ref.shard_of(rec.id as usize);
                shard_txs[s]
                    .send(HashJob { id: rec.id, v: hv.clone() })
                    .map_err(|_| Error::Pipeline("shard worker hung up".into()))?;
                xmat.push_row(&rec.x).map_err(|e| Error::Pipeline(e.to_string()))?;
                ys.push(rec.y);
                hashed.push_row(&hv).map_err(|e| Error::Pipeline(e.to_string()))?;
            }
            drop(shard_txs);
            Ok((xmat, ys, hashed, norms))
        });

        // --- Shard workers: own their tables; coded inserts, no locks. ---
        let mut handles = Vec::with_capacity(shards);
        for (s, rx) in shard_rxs.into_iter().enumerate() {
            let h = hasher_ref.clone();
            handles.push(scope.spawn(move || -> Result<ShardTables<H>> {
                let _sp = crate::span!("pipeline.shard_build", shard = s);
                let tw = Instant::now();
                let l = h.l();
                let mut rows: Vec<u32> = Vec::new();
                let mut local = Matrix::zeros(0, 0);
                let mut norms: Vec<f64> = Vec::new();
                let mut tables = LshTables::new(h.clone());
                for job in rx.iter() {
                    let j = rows.len();
                    for t in 0..l {
                        tables.insert_coded(t, h.code(t, &job.v), j as u32);
                    }
                    local.push_row(&job.v).map_err(|e| Error::Pipeline(e.to_string()))?;
                    norms.push(crate::core::matrix::norm2(&job.v));
                    rows.push(job.id);
                }
                // Mirrors go in *after* the stream drains, so bucket order
                // matches the batch layout [base asc; mirrors asc] — the
                // draw-for-draw guarantee against build_shard_tables.
                if mirror {
                    let c = rows.len();
                    for j in 0..c {
                        let neg: Vec<f32> = local.row(j).iter().map(|v| -v).collect();
                        for t in 0..l {
                            tables.insert_coded(t, h.code(t, &neg), (c + j) as u32);
                        }
                        local.push_row(&neg).map_err(|e| Error::Pipeline(e.to_string()))?;
                        norms.push(crate::core::matrix::norm2(&neg));
                        let base_id = rows[j];
                        rows.push(base_id + n as u32);
                    }
                }
                tables.finish_coded_inserts(local.rows());
                Ok(ShardTables {
                    rows,
                    stored: local,
                    norms,
                    tables: TableStore::Vec(tables),
                    build_secs: tw.elapsed().as_secs_f64(),
                })
            }));
        }

        (
            src.join(),
            pre.join(),
            handles.into_iter().map(|w| w.join()).collect::<Vec<_>>(),
        )
    });

    let rows = src_res.map_err(|_| Error::Pipeline("source panicked".into()))?;
    let (xmat, ys, hashed, norms) =
        pre_res.map_err(|_| Error::Pipeline("preprocess panicked".into()))??;
    let mut built = Vec::with_capacity(shards);
    for r in worker_res {
        let st = r.map_err(|_| Error::Pipeline("shard worker panicked".into()))??;
        metrics.observe("pipeline.shard_build", st.build_secs);
        metrics.count("pipeline.shard_rows", st.rows.len() as u64);
        built.push(st);
    }
    let mult = if mirror { 2 } else { 1 };
    let total: usize = built.iter().map(|s| s.stored.rows()).sum();
    if rows != n || total != rows * mult {
        return Err(Error::Pipeline(format!(
            "streamed {rows}/{n} records but shards store {total} rows (expected {})",
            rows * mult
        )));
    }

    let wall = t0.elapsed().as_secs_f64();
    metrics.count("pipeline.records", rows as u64);
    metrics.observe("pipeline.wall", wall);

    let data = Dataset::new(name, xmat, ys, task).map_err(|e| Error::Pipeline(e.to_string()))?;
    let pre = Preprocessed { data, hashed, space, center: Vec::new(), norms };
    let report = PipelineReport {
        records: rows,
        wall_secs: wall,
        throughput: rows as f64 / wall.max(1e-12),
    };
    Ok((pre, built, report))
}

/// Migration/rebalance counters of a live [`ShardSet`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardSetStats {
    /// Examples moved between shards by rebalancing.
    pub migrations: u64,
    /// Rebalance passes that performed at least one migration.
    pub rebalances: u64,
    /// Wall seconds spent inside rebalance passes (including no-op checks).
    pub rebalance_secs: f64,
}

/// A *live* partition of (a subset of) the `n` examples of a fixed backing
/// hash-space matrix across shard tables.
///
/// Built shards ([`build_shard_tables`] or [`streaming_build_sharded`])
/// stay mutable after construction: `insert` routes a new example's rows
/// (base + mirror) into the least-loaded shard, `remove` evicts them, and
/// whenever the base-row imbalance (max/mean) exceeds the configured
/// threshold the set invokes [`ShardPlan::rebalance`] on its current
/// membership and migrates the reported examples between shard tables via
/// [`LshTables::remove`] + re-`insert`. Per-shard stored-row prefix sums
/// (`R_s`, `R = Σ R_s`) are recomputed after every mutation, so the
/// shard-mixture proposal `p = (R_s/R)·p_shard` stays exact and Theorem-1
/// unbiasedness holds at every point of the stream.
///
/// `Clone` (requiring `H: Clone`) deep-copies the whole set — tables,
/// stored rows, membership indexes and the generation counter — which is
/// what [`crate::runtime::serving`] builds generation `g+1` from while
/// readers keep serving the published `g`.
#[derive(Clone)]
pub struct ShardSet<H: SrpHasher> {
    shards: Vec<ShardTables<H>>,
    /// Base-row count of the backing matrix; example ids live in `[0, n)`.
    n: usize,
    mirror: bool,
    /// Rebalance when `imbalance() > threshold`; 0 / non-finite = never.
    threshold: f64,
    /// Example id → owning shard (-1 = not present).
    loc: Vec<i32>,
    /// Virtual stored-row id (`id`, or `id + n` for mirrors) → local row
    /// index inside its owning shard (u32::MAX = absent). The per-shard
    /// member index that makes migration O(1) per id instead of an O(R_s)
    /// `position` scan (ROADMAP rebalance-cost item).
    row_pos: Vec<u32>,
    /// Inclusive prefix sums of per-shard stored-row counts.
    cum_rows: Vec<usize>,
    total_rows: usize,
    /// Mutation epoch: bumped by every membership change (insert, remove,
    /// rebalance migration). The async draw engine tags pre-drawn
    /// candidates with the generation they were sampled under and refuses
    /// to serve a candidate from an older generation — the invalidation
    /// contract that makes "mutations never serve dead rows" checkable.
    generation: u64,
    stats: ShardSetStats,
}

impl<H: SrpHasher> ShardSet<H> {
    /// Build the per-shard tables for `plan` over `base` (concurrently, via
    /// [`build_shard_tables`]) and wrap them as a live set.
    pub fn build(
        base: &Matrix,
        plan: &ShardPlan,
        mirror: bool,
        hasher: &H,
        threshold: f64,
        metrics: &Metrics,
    ) -> Result<Self>
    where
        H: Clone,
    {
        let shards = build_shard_tables(base, plan, mirror, hasher, metrics)?;
        Ok(Self::from_shards(shards, base.rows(), mirror, threshold))
    }

    /// Wrap pre-built shards (batch or streaming). `n` is the base-row
    /// count of the backing matrix; shard `rows` entries must be `id` (or
    /// `id + n` for mirror rows), each present id owned by exactly one
    /// shard, and `mirror` must describe how the shards were actually
    /// built — a mismatch corrupts `counts()`/`present_len()` and any
    /// later insert (debug-asserted below).
    pub fn from_shards(
        shards: Vec<ShardTables<H>>,
        n: usize,
        mirror: bool,
        threshold: f64,
    ) -> Self {
        let mut loc = vec![-1i32; n];
        let mut row_pos = vec![u32::MAX; 2 * n];
        let mut base_rows = 0usize;
        let mut mirror_rows = 0usize;
        for (s, st) in shards.iter().enumerate() {
            for (j, &r) in st.rows.iter().enumerate() {
                row_pos[r as usize] = j as u32;
                if (r as usize) < n {
                    loc[r as usize] = s as i32;
                    base_rows += 1;
                } else {
                    mirror_rows += 1;
                }
            }
        }
        debug_assert_eq!(
            mirror_rows,
            if mirror { base_rows } else { 0 },
            "mirror flag does not match the shard layout ({base_rows} base rows, \
             {mirror_rows} mirror rows)"
        );
        let mut set = ShardSet {
            shards,
            n,
            mirror,
            threshold,
            loc,
            row_pos,
            cum_rows: Vec::new(),
            total_rows: 0,
            generation: 0,
            stats: ShardSetStats::default(),
        };
        set.refresh_cum();
        set
    }

    fn refresh_cum(&mut self) {
        self.cum_rows.clear();
        self.total_rows = 0;
        for s in &self.shards {
            self.total_rows += s.stored.rows();
            self.cum_rows.push(self.total_rows);
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shard `s`.
    pub fn shard(&self, s: usize) -> &ShardTables<H> {
        &self.shards[s]
    }

    /// All shards.
    pub fn shards(&self) -> &[ShardTables<H>] {
        &self.shards
    }

    /// Unwrap into the shard tables.
    pub fn into_shards(self) -> Vec<ShardTables<H>> {
        self.shards
    }

    /// Total stored rows `R` across shards (2× present examples when
    /// mirrored).
    pub fn total_rows(&self) -> usize {
        self.total_rows
    }

    /// Base-row count of the backing matrix.
    pub fn base_len(&self) -> usize {
        self.n
    }

    /// Number of examples currently present (Σ per-shard counts).
    pub fn present_len(&self) -> usize {
        let mult = if self.mirror { 2 } else { 1 };
        self.total_rows / mult
    }

    /// Inclusive prefix sums of per-shard stored-row counts (the mixture's
    /// `R_s` accumulation; `cum_rows()[last] == total_rows()`).
    pub fn cum_rows(&self) -> &[usize] {
        &self.cum_rows
    }

    /// Shard owning global stored row `r` (prefix-sum scan; shard counts
    /// are tiny).
    #[inline]
    pub fn shard_of_row(&self, r: usize) -> usize {
        for (s, &cum) in self.cum_rows.iter().enumerate() {
            if r < cum {
                return s;
            }
        }
        self.cum_rows.len() - 1
    }

    /// Is example `id` currently stored?
    pub fn contains(&self, id: usize) -> bool {
        id < self.n && self.loc[id] >= 0
    }

    /// Shard owning example `id`, if present.
    pub fn shard_of(&self, id: usize) -> Option<usize> {
        if self.contains(id) {
            Some(self.loc[id] as usize)
        } else {
            None
        }
    }

    /// Present examples per shard (base rows only; mirrors excluded).
    pub fn counts(&self) -> Vec<usize> {
        let mult = if self.mirror { 2 } else { 1 };
        self.shards.iter().map(|s| s.rows.len() / mult).collect()
    }

    /// Imbalance = max/mean present-example count (1.0 is perfect or
    /// empty). Mirrors scale every shard equally, so base counts suffice.
    pub fn imbalance(&self) -> f64 {
        let counts = self.counts();
        let max = *counts.iter().max().unwrap_or(&0) as f64;
        let mean = counts.iter().sum::<usize>() as f64 / counts.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Migration/rebalance counters.
    pub fn stats(&self) -> ShardSetStats {
        self.stats
    }

    /// Current mutation generation: strictly increases across every
    /// membership change (insert, remove, rebalance that migrated). Draws
    /// pre-computed under generation `g` are only valid while
    /// `generation() == g` — the async engine's staleness contract.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Current rebalance trigger (0 / non-finite = disabled).
    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// Restore persisted set-level counters after a snapshot load: the
    /// mutation generation (the async engine's staleness contract must
    /// survive a restart — a candidate pre-drawn before a save can never be
    /// served after a load *and* a mutation) and the accumulated
    /// migration/rebalance statistics.
    pub(crate) fn restore_counters(&mut self, generation: u64, stats: ShardSetStats) {
        self.generation = generation;
        self.stats = stats;
    }

    /// Set the rebalance trigger: rebalance whenever `imbalance()` exceeds
    /// `t` after a mutation. 0 (or any non-finite / sub-1.0 value)
    /// disables automatic rebalancing.
    pub fn set_threshold(&mut self, t: f64) {
        self.threshold = t;
    }

    /// Insert example `id` (hash row `base.row(id)` plus, when mirrored,
    /// its negation) into the least-loaded shard (ties → lowest index).
    /// Returns the chosen shard. Triggers an automatic rebalance when the
    /// imbalance threshold is exceeded.
    pub fn insert(&mut self, id: usize, base: &Matrix) -> Result<usize> {
        let counts = self.counts();
        let (s, _) = counts
            .iter()
            .enumerate()
            .min_by_key(|&(_, &c)| c)
            .ok_or_else(|| Error::Data("shard set has zero shards".into()))?;
        self.insert_into(s, id, base)?;
        Ok(s)
    }

    /// Insert example `id` into a specific shard (skewed-arrival
    /// simulations route through this). Errors if `id` is out of range or
    /// already present.
    pub fn insert_into(&mut self, shard: usize, id: usize, base: &Matrix) -> Result<()> {
        if shard >= self.shards.len() {
            return Err(Error::Data(format!(
                "shard {shard} out of {}",
                self.shards.len()
            )));
        }
        if id >= self.n || base.rows() != self.n {
            return Err(Error::Data(format!(
                "example {id} out of base matrix with {} rows (set built over n = {})",
                base.rows(),
                self.n
            )));
        }
        if self.loc[id] >= 0 {
            return Err(Error::Data(format!("example {id} already present")));
        }
        self.push_rows(shard, id, base)?;
        self.loc[id] = shard as i32;
        self.generation += 1;
        self.refresh_cum();
        self.maybe_rebalance(base)?;
        self.maybe_compact(shard);
        Ok(())
    }

    /// Remove example `id` (base and mirror rows). Returns false if it was
    /// not present. Triggers an automatic rebalance when the removal tips
    /// the imbalance past the threshold.
    pub fn remove(&mut self, id: usize, base: &Matrix) -> Result<bool> {
        if id >= self.n || base.rows() != self.n {
            return Err(Error::Data(format!(
                "example {id} out of base matrix with {} rows (set built over n = {})",
                base.rows(),
                self.n
            )));
        }
        let s = match self.loc[id] {
            s if s >= 0 => s as usize,
            _ => return Ok(false),
        };
        self.take_rows(s, id);
        self.loc[id] = -1;
        self.generation += 1;
        self.refresh_cum();
        self.maybe_rebalance(base)?;
        self.maybe_compact(s);
        Ok(true)
    }

    /// Rebalance the present examples until `imbalance() ≤ target` (or no
    /// move helps): builds a [`ShardPlan`] over the current membership,
    /// asks it for the move list, and migrates each reported example's
    /// rows between shard tables (O(1) per id via the member index).
    /// After a rebalance that moved anything, sealed shard tables are
    /// compacted — overlay entries fold back into the CSR arena.
    /// Returns the number of examples migrated.
    pub fn rebalance_to(&mut self, target: f64, base: &Matrix) -> Result<usize> {
        let t0 = Instant::now();
        let target = target.max(1.0);
        // Feasibility gate, O(shards): when the set is already under
        // target, or no single move can help (max ≤ min + 1 — the target
        // is unreachable), skip the O(n) membership scan entirely instead
        // of burning a futile pass per mutation.
        {
            let counts = self.counts();
            let max = *counts.iter().max().unwrap_or(&0);
            let min = *counts.iter().min().unwrap_or(&0);
            if self.imbalance() <= target || max <= min + 1 {
                self.stats.rebalance_secs += t0.elapsed().as_secs_f64();
                return Ok(0);
            }
        }
        let mut present: Vec<u32> = Vec::new();
        let mut assign: Vec<u32> = Vec::new();
        for id in 0..self.n {
            if self.loc[id] >= 0 {
                present.push(id as u32);
                assign.push(self.loc[id] as u32);
            }
        }
        let mut plan = ShardPlan::from_assignments(self.shards.len(), assign)?;
        let moves = plan.rebalance(target);
        let mut touched = vec![false; self.shards.len()];
        for &(slot, from, to) in &moves {
            let id = present[slot] as usize;
            debug_assert_eq!(self.loc[id], from as i32, "plan/membership desync");
            self.take_rows(from, id);
            self.push_rows(to, id, base)?;
            self.loc[id] = to as i32;
            touched[from] = true;
            touched[to] = true;
        }
        if !moves.is_empty() {
            self.stats.rebalances += 1;
            self.stats.migrations += moves.len() as u64;
            self.generation += 1;
            self.refresh_cum();
            for (s, t) in touched.iter().enumerate() {
                if *t {
                    self.shards[s].tables.compact();
                }
            }
        }
        self.stats.rebalance_secs += t0.elapsed().as_secs_f64();
        Ok(moves.len())
    }

    fn maybe_rebalance(&mut self, base: &Matrix) -> Result<usize> {
        if !(self.threshold.is_finite() && self.threshold >= 1.0) {
            return Ok(0);
        }
        if self.imbalance() <= self.threshold {
            return Ok(0);
        }
        self.rebalance_to(self.threshold, base)
    }

    /// Compact a sealed shard's delta overlay back into its arena once the
    /// overlay outgrows a fixed fraction (1/8) of the table entries.
    /// Balanced streaming churn never triggers a rebalance, so this is the
    /// recovery path that keeps the sealed layout cache-linear under
    /// long-running insert/remove streams; compaction cost O(R_s·L) is
    /// amortised over the ≥ R_s·L/8 overlay-building mutations since the
    /// last one. Order-preserving, so draws are unchanged. No-op on the
    /// Vec layout (`overlay_len` is 0).
    fn maybe_compact(&mut self, s: usize) {
        let st = &mut self.shards[s];
        let overlay = st.tables.overlay_len();
        if overlay == 0 {
            return;
        }
        let entries = st.rows.len() * st.tables.hasher().l();
        if overlay * 8 > entries.max(64) {
            st.tables.compact();
        }
    }

    /// Append example `id`'s stored rows at the end of `shard`.
    fn push_rows(&mut self, shard: usize, id: usize, base: &Matrix) -> Result<()> {
        let st = &mut self.shards[shard];
        let v = base.row(id);
        let j = st.stored.rows();
        st.tables.insert(j as u32, v)?;
        st.stored.push_row(v).map_err(|e| Error::Pipeline(e.to_string()))?;
        st.norms.push(crate::core::matrix::norm2(v));
        st.rows.push(id as u32);
        self.row_pos[id] = j as u32;
        if self.mirror {
            let neg: Vec<f32> = v.iter().map(|x| -x).collect();
            let jm = st.stored.rows();
            st.tables.insert(jm as u32, &neg)?;
            st.stored.push_row(&neg).map_err(|e| Error::Pipeline(e.to_string()))?;
            st.norms.push(crate::core::matrix::norm2(&neg));
            st.rows.push((id + self.n) as u32);
            self.row_pos[id + self.n] = jm as u32;
        }
        Ok(())
    }

    /// Remove every stored row of example `id` from shard `s` (base and,
    /// when mirrored, the negation). O(1) lookups via the member index;
    /// the mirror position is re-read after the first removal because the
    /// swap-remove may have relocated it.
    fn take_rows(&mut self, s: usize, id: usize) {
        let j = self.row_pos[id];
        debug_assert_ne!(j, u32::MAX, "take_rows of an absent example");
        self.remove_local_row(s, j as usize);
        if self.mirror {
            let jm = self.row_pos[id + self.n];
            debug_assert_ne!(jm, u32::MAX, "mirror row missing from member index");
            self.remove_local_row(s, jm as usize);
        }
    }

    /// Swap-remove local row `j` of shard `s`: drop its table entries, move
    /// the last row into its slot and rewrite that row's table id (bucket
    /// ids are local row indices, so the moved row must be re-keyed), and
    /// keep the member index in sync.
    fn remove_local_row(&mut self, s: usize, j: usize) {
        let st = &mut self.shards[s];
        let last = st.stored.rows() - 1;
        let vj = st.stored.row(j).to_vec();
        st.tables.remove(j as u32, &vj);
        if j != last {
            let vlast = st.stored.row(last).to_vec();
            st.tables.remove(last as u32, &vlast);
            st.tables
                .insert(j as u32, &vlast)
                .expect("re-keying a row that was already stored");
        }
        self.row_pos[st.rows[j] as usize] = u32::MAX;
        st.stored.swap_remove_row(j);
        st.rows.swap_remove(j);
        st.norms.swap_remove(j);
        if j < st.rows.len() {
            // the previous last row now lives at j — re-point its index
            self.row_pos[st.rows[j] as usize] = j as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::lsh::srp::DenseSrp;

    fn build_both(
        n: usize,
        d: usize,
        workers: usize,
    ) -> (Preprocessed, LshTables<DenseSrp>, Preprocessed, LshTables<DenseSrp>) {
        let ds = SynthSpec::power_law("p", n, d, 3).generate().unwrap();
        let hasher = DenseSrp::new(d + 1, 4, 10, 7);
        // batch path
        let pre_b = preprocess(ds.clone(), &PreprocessOptions::default()).unwrap();
        let tb = LshTables::build(
            hasher.clone(),
            (0..pre_b.data.len()).map(|i| pre_b.hashed.row(i)),
        )
        .unwrap();
        // streaming path
        let m = Metrics::new();
        let cfg = PipelineConfig { channel_cap: 8, hash_workers: workers };
        let (pre_s, ts, rep) = streaming_build(ds, hasher, &cfg, &m).unwrap();
        assert_eq!(rep.records, n);
        assert_eq!(m.counter("pipeline.records"), n as u64);
        (pre_b, tb, pre_s, ts)
    }

    #[test]
    fn streaming_matches_batch_path() {
        let (pre_b, tb, pre_s, ts) = build_both(200, 12, 3);
        // identical preprocessed data
        assert_eq!(pre_b.data.y, pre_s.data.y);
        assert_eq!(pre_b.hashed, pre_s.hashed);
        assert_eq!(pre_b.norms, pre_s.norms);
        // identical table contents (same hasher -> same codes); bucket order
        // within a table may differ, compare as sets
        assert_eq!(tb.len(), ts.len());
        for t in 0..10 {
            for code in 0..(1u32 << 4) {
                let mut a: Vec<u32> = tb.bucket(t, code).to_vec();
                let mut b: Vec<u32> = ts.bucket(t, code).to_vec();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "table {t} code {code}");
            }
        }
    }

    #[test]
    fn single_worker_and_many_workers_agree() {
        let (_, _, _, t1) = build_both(100, 8, 1);
        let (_, _, _, t8) = build_both(100, 8, 8);
        for t in 0..10 {
            for code in 0..(1u32 << 4) {
                let mut a: Vec<u32> = t1.bucket(t, code).to_vec();
                let mut b: Vec<u32> = t8.bucket(t, code).to_vec();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn tiny_channel_capacity_still_completes() {
        let ds = SynthSpec::power_law("p", 150, 6, 9).generate().unwrap();
        let hasher = DenseSrp::new(7, 3, 6, 1);
        let m = Metrics::new();
        let cfg = PipelineConfig { channel_cap: 1, hash_workers: 2 };
        let (pre, tables, rep) = streaming_build(ds, hasher, &cfg, &m).unwrap();
        assert_eq!(rep.records, 150);
        assert_eq!(pre.data.len(), 150);
        assert_eq!(tables.len(), 150);
    }

    #[test]
    fn dim_mismatch_fails_fast() {
        let ds = SynthSpec::power_law("p", 10, 6, 9).generate().unwrap();
        let hasher = DenseSrp::new(6, 3, 4, 1); // should be 7 (augmented)
        let m = Metrics::new();
        let r = streaming_build(ds, hasher, &PipelineConfig::default(), &m);
        assert!(r.is_err());
    }

    #[test]
    fn shard_build_partitions_all_rows() {
        let ds = SynthSpec::power_law("s", 300, 10, 17).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(11, 4, 6, 19);
        let plan = ShardPlan::round_robin(300, 4).unwrap();
        let m = Metrics::new();
        let shards = build_shard_tables(&pre.hashed, &plan, false, &hasher, &m).unwrap();
        assert_eq!(shards.len(), 4);
        let mut seen: Vec<u32> = shards.iter().flat_map(|s| s.rows.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..300u32).collect::<Vec<_>>(), "shards must partition the rows");
        for s in &shards {
            assert_eq!(s.tables.len(), s.rows.len());
            assert_eq!(s.stored.rows(), s.rows.len());
            assert_eq!(s.norms.len(), s.rows.len());
        }
        assert_eq!(m.counter("pipeline.shard_rows"), 300);
        assert_eq!(m.timer("pipeline.shard_build").unwrap().0, 4);
    }

    /// shards = 1 reproduces the unsharded table build bucket-for-bucket.
    #[test]
    fn single_shard_matches_unsharded_build() {
        let ds = SynthSpec::power_law("s", 200, 8, 21).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(9, 4, 8, 23);
        let full = LshTables::build(hasher.clone(), (0..200).map(|i| pre.hashed.row(i))).unwrap();
        let plan = ShardPlan::round_robin(200, 1).unwrap();
        let m = Metrics::new();
        let shards = build_shard_tables(&pre.hashed, &plan, false, &hasher, &m).unwrap();
        assert_eq!(shards.len(), 1);
        let st = &shards[0];
        assert_eq!(st.rows, (0..200u32).collect::<Vec<_>>());
        for t in 0..8 {
            for code in 0..(1u32 << 4) {
                let (a, b) = (full.bucket(t, code), st.tables.query_bucket_coded(t, code));
                assert_eq!(a, b.to_vec(), "table {t} code {code}");
            }
        }
    }

    /// Mirrored builds keep each example's row and its on-the-fly negation
    /// on the same shard, and a plan/matrix row-count mismatch is rejected.
    #[test]
    fn mirrored_shard_build_owns_both_signs() {
        let ds = SynthSpec::power_law("s", 60, 6, 27).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(7, 3, 5, 29);
        let plan = ShardPlan::round_robin(60, 3).unwrap();
        let m = Metrics::new();
        let shards = build_shard_tables(&pre.hashed, &plan, true, &hasher, &m).unwrap();
        for (s_idx, s) in shards.iter().enumerate() {
            let cnt = s.rows.len() / 2;
            assert_eq!(s.rows.len(), 2 * cnt);
            for j in 0..cnt {
                assert_eq!(s.rows[j + cnt] as usize, s.rows[j] as usize + 60);
                assert_eq!(plan.shard_of(s.rows[j] as usize), s_idx);
                for (a, b) in s.stored.row(j).iter().zip(s.stored.row(j + cnt)) {
                    assert_eq!(*a, -*b, "mirror row must be the exact negation");
                }
            }
        }
        assert_eq!(m.counter("pipeline.shard_rows"), 120);
        let short_plan = ShardPlan::round_robin(50, 3).unwrap();
        assert!(
            build_shard_tables(&pre.hashed, &short_plan, true, &hasher, &m).is_err(),
            "plan/matrix row-count mismatch must be rejected"
        );
    }

    /// The streaming sharded build must reproduce the batch
    /// `build_shard_tables` layout *byte-for-byte* — same row order, same
    /// stored vectors, same norms and, crucially, the same bucket order
    /// (uniform in-bucket picks make bucket order part of the draw stream).
    #[test]
    fn streaming_sharded_matches_batch_shard_tables() {
        let ds = SynthSpec::power_law("ss", 240, 10, 31).generate().unwrap();
        let hasher = DenseSrp::new(11, 4, 8, 33);
        let pre_b = preprocess(ds.clone(), &PreprocessOptions::default()).unwrap();
        let plan = ShardPlan::round_robin(240, 3).unwrap();
        let m = Metrics::new();
        for &mirror in &[false, true] {
            let batch = build_shard_tables(&pre_b.hashed, &plan, mirror, &hasher, &m).unwrap();
            let cfg = PipelineConfig { channel_cap: 8, hash_workers: 2 };
            let (pre_s, streamed, rep) =
                streaming_build_sharded(ds.clone(), hasher.clone(), 3, mirror, &cfg, &m)
                    .unwrap();
            assert_eq!(rep.records, 240);
            assert_eq!(pre_b.hashed, pre_s.hashed);
            assert_eq!(pre_b.norms, pre_s.norms);
            assert_eq!(batch.len(), streamed.len());
            for (a, b) in batch.iter().zip(&streamed) {
                assert_eq!(a.rows, b.rows, "mirror={mirror}: row order diverged");
                assert_eq!(a.stored, b.stored);
                assert_eq!(a.norms, b.norms);
                assert_eq!(a.tables.len(), b.tables.len());
                for t in 0..8 {
                    for code in 0..(1u32 << 4) {
                        assert_eq!(
                            a.tables.query_bucket_coded(t, code).to_vec(),
                            b.tables.query_bucket_coded(t, code).to_vec(),
                            "mirror={mirror} table {t} code {code}: bucket order must \
                             match for draw-for-draw identity"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn streaming_sharded_dim_mismatch_fails_fast() {
        let ds = SynthSpec::power_law("ss", 20, 6, 9).generate().unwrap();
        let hasher = DenseSrp::new(6, 3, 4, 1); // should be 7 (augmented)
        let m = Metrics::new();
        let r = streaming_build_sharded(ds, hasher, 2, true, &PipelineConfig::default(), &m);
        assert!(r.is_err());
    }

    /// Every shard's tables stay internally consistent: each local row id
    /// appears exactly once per table, stored vectors are ± the base rows
    /// they claim to be, and norms match.
    fn check_set_integrity(set: &ShardSet<DenseSrp>, base: &Matrix) {
        let n = set.base_len();
        let mut seen = vec![0usize; n];
        for s in 0..set.shard_count() {
            let st = set.shard(s);
            assert!(
                st.stored.zero_tail_ok(),
                "shard {s}: aligned zero-tail invariant broken by migration"
            );
            assert_eq!(st.rows.len(), st.stored.rows());
            assert_eq!(st.rows.len(), st.norms.len());
            assert_eq!(st.tables.len(), st.rows.len());
            let l = st.tables.hasher().l();
            let k = st.tables.hasher().k();
            for t in 0..l {
                let mut hits = vec![0usize; st.rows.len()];
                for code in 0..(1u32 << k) {
                    for id in st.tables.query_bucket_coded(t, code).iter() {
                        hits[id as usize] += 1;
                    }
                }
                assert!(
                    hits.iter().all(|&h| h == 1),
                    "shard {s} table {t}: some local id lost or duplicated"
                );
            }
            for (j, &r) in st.rows.iter().enumerate() {
                assert_eq!(
                    set.row_pos[r as usize], j as u32,
                    "shard {s}: member index desynced for virtual row {r}"
                );
                let (ex, sign) =
                    if (r as usize) < n { (r as usize, 1.0f32) } else { (r as usize - n, -1.0) };
                for (a, b) in st.stored.row(j).iter().zip(base.row(ex)) {
                    assert_eq!(*a, sign * *b, "shard {s} local row {j} vector corrupt");
                }
                let want = crate::core::matrix::norm2(st.stored.row(j));
                assert_eq!(st.norms[j], want, "shard {s} local row {j} stale norm");
                if (r as usize) < n {
                    seen[r as usize] += 1;
                    assert_eq!(set.shard_of(r as usize), Some(s));
                }
            }
        }
        let total: usize = (0..set.shard_count()).map(|s| set.shard(s).stored.rows()).sum();
        assert_eq!(total, set.total_rows(), "stale prefix sums");
        assert!(seen.iter().all(|&c| c <= 1), "example owned by two shards");
    }

    #[test]
    fn shard_set_insert_remove_rebalance_keeps_tables_consistent() {
        let ds = SynthSpec::power_law("live", 120, 8, 41).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(9, 3, 6, 43);
        let plan = ShardPlan::round_robin(120, 3).unwrap();
        let m = Metrics::new();
        let mut set = ShardSet::build(&pre.hashed, &plan, true, &hasher, 0.0, &m).unwrap();
        assert_eq!(set.total_rows(), 2 * 120);
        assert!((set.imbalance() - 1.0).abs() < 1e-9);

        // remove a block, then put a few back
        for id in 0..30 {
            assert!(set.remove(id, &pre.hashed).unwrap());
            assert!(!set.contains(id));
        }
        assert!(!set.remove(5, &pre.hashed).unwrap(), "double remove must be clean");
        for id in 0..10 {
            set.insert(id, &pre.hashed).unwrap();
        }
        assert!(set.insert(3, &pre.hashed).is_err(), "duplicate insert rejected");
        assert_eq!(set.counts().iter().sum::<usize>(), 100);
        assert_eq!(set.total_rows(), 2 * 100);
        check_set_integrity(&set, &pre.hashed);

        // skew shard 0 hard with the still-absent ids, then rebalance
        for id in 10..30 {
            set.insert_into(0, id, &pre.hashed).unwrap();
        }
        assert!(set.imbalance() > 1.3, "skew failed: {}", set.imbalance());
        let moved = set.rebalance_to(1.05, &pre.hashed).unwrap();
        assert!(moved > 0);
        assert!(set.imbalance() <= 1.06, "imbalance {}", set.imbalance());
        assert_eq!(set.stats().migrations, moved as u64);
        assert_eq!(set.stats().rebalances, 1);
        assert_eq!(set.counts().iter().sum::<usize>(), 120);
        check_set_integrity(&set, &pre.hashed);
    }

    /// Automatic rebalancing: a fully skewed arrival stream (everything
    /// routed to shard 0) with a 1.3 threshold keeps the set balanced
    /// without any manual intervention.
    #[test]
    fn shard_set_auto_rebalances_skewed_arrivals() {
        let ds = SynthSpec::power_law("skew", 90, 6, 51).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(7, 3, 5, 53);
        let shards: Vec<ShardTables<DenseSrp>> = (0..3)
            .map(|_| ShardTables {
                rows: Vec::new(),
                stored: Matrix::zeros(0, 0),
                norms: Vec::new(),
                tables: TableStore::Vec(LshTables::new(hasher.clone())),
                build_secs: 0.0,
            })
            .collect();
        let mut set = ShardSet::from_shards(shards, 90, true, 1.3);
        for id in 0..90 {
            set.insert_into(0, id, &pre.hashed).unwrap();
        }
        let counts = set.counts();
        assert_eq!(counts.iter().sum::<usize>(), 90);
        assert!(
            set.imbalance() <= 1.3,
            "auto rebalance left imbalance {} (counts {:?})",
            set.imbalance(),
            counts
        );
        assert!(set.stats().migrations > 0, "skewed arrivals must trigger migration");
        assert!(set.stats().rebalances > 0);
        check_set_integrity(&set, &pre.hashed);
        // disabled threshold: mutations no longer migrate anything
        set.set_threshold(0.0);
        let before = set.stats().migrations;
        for id in 0..30 {
            set.remove(id, &pre.hashed).unwrap();
        }
        assert_eq!(set.stats().migrations, before, "disabled threshold must not migrate");
        check_set_integrity(&set, &pre.hashed);
    }

    /// Unreachable targets exit the rebalance pass early (O(shards), no
    /// membership scan, no moves) — the ROADMAP "futile re-pass" item. A
    /// set at max ≤ min + 1 cannot improve, however strict the target.
    #[test]
    fn rebalance_unreachable_target_is_cheap_noop() {
        let ds = SynthSpec::power_law("noop", 7, 6, 61).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(7, 3, 4, 63);
        let plan = ShardPlan::round_robin(7, 3).unwrap(); // counts 3/2/2
        let m = Metrics::new();
        let mut set = ShardSet::build(&pre.hashed, &plan, true, &hasher, 0.0, &m).unwrap();
        assert!(set.imbalance() > 1.0 + 1e-9, "3/2/2 must be imbalanced");
        let moved = set.rebalance_to(1.0, &pre.hashed).unwrap();
        assert_eq!(moved, 0, "max <= min + 1: no move can help");
        assert_eq!(set.stats().rebalances, 0, "a no-op pass must not count as a rebalance");
        assert_eq!(set.stats().migrations, 0);
        // an aggressive auto-threshold on an unreachable set must not spin
        set.set_threshold(1.0);
        set.remove(0, &pre.hashed).unwrap();
        set.insert(0, &pre.hashed).unwrap();
        assert_eq!(set.stats().migrations, 0);
        check_set_integrity(&set, &pre.hashed);
    }

    /// Sealed shard tables stay bucket-for-bucket identical to Vec-layout
    /// shards through live insert/remove/rebalance, and rebalancing
    /// compacts the overlay back into the arena.
    #[test]
    fn sealed_shard_set_matches_vec_through_mutation() {
        let ds = SynthSpec::power_law("sealed-live", 90, 8, 71).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(9, 3, 6, 73);
        let plan = ShardPlan::round_robin(90, 3).unwrap();
        let m = Metrics::new();
        let mut vec_set = ShardSet::build(&pre.hashed, &plan, true, &hasher, 0.0, &m).unwrap();
        let sealed_shards: Vec<ShardTables<DenseSrp>> =
            build_shard_tables(&pre.hashed, &plan, true, &hasher, &m)
                .unwrap()
                .into_iter()
                .map(ShardTables::seal)
                .collect();
        let mut sealed_set = ShardSet::from_shards(sealed_shards, 90, true, 0.0);
        let compare = |a: &ShardSet<DenseSrp>, b: &ShardSet<DenseSrp>| {
            for s in 0..a.shard_count() {
                let (x, y) = (a.shard(s), b.shard(s));
                assert_eq!(x.rows, y.rows, "shard {s}: row order diverged");
                for t in 0..6 {
                    for code in 0..(1u32 << 3) {
                        assert_eq!(
                            x.tables.query_bucket_coded(t, code).to_vec(),
                            y.tables.query_bucket_coded(t, code).to_vec(),
                            "shard {s} table {t} code {code}"
                        );
                    }
                }
            }
        };
        compare(&vec_set, &sealed_set);
        for id in 0..30 {
            assert!(vec_set.remove(id, &pre.hashed).unwrap());
            assert!(sealed_set.remove(id, &pre.hashed).unwrap());
        }
        for id in 0..30 {
            vec_set.insert_into(0, id, &pre.hashed).unwrap();
            sealed_set.insert_into(0, id, &pre.hashed).unwrap();
        }
        compare(&vec_set, &sealed_set);
        let mv = vec_set.rebalance_to(1.05, &pre.hashed).unwrap();
        let ms = sealed_set.rebalance_to(1.05, &pre.hashed).unwrap();
        assert_eq!(mv, ms);
        assert!(ms > 0, "the skew must migrate something");
        compare(&vec_set, &sealed_set);
        for s in 0..sealed_set.shard_count() {
            if let TableStore::Sealed(t) = &sealed_set.shard(s).tables {
                assert_eq!(t.overlay_len(), 0, "shard {s}: rebalance must compact the overlay");
            } else {
                panic!("shard {s} lost its sealed layout");
            }
        }
        check_set_integrity(&vec_set, &pre.hashed);
        // Balanced churn (no rebalance ever fires): the overlay-size
        // trigger must keep every sealed shard's overlay bounded, while
        // staying bucket-for-bucket identical to the Vec layout.
        for round in 0..6 {
            for id in 0..90 {
                assert!(vec_set.remove(id, &pre.hashed).unwrap());
                assert!(sealed_set.remove(id, &pre.hashed).unwrap());
                vec_set.insert(id, &pre.hashed).unwrap();
                sealed_set.insert(id, &pre.hashed).unwrap();
            }
            compare(&vec_set, &sealed_set);
            for s in 0..sealed_set.shard_count() {
                let st = sealed_set.shard(s);
                let bound = (st.rows.len() * st.tables.hasher().l()).max(64) / 8;
                assert!(
                    st.tables.overlay_len() <= bound,
                    "round {round} shard {s}: overlay {} exceeds churn bound {bound}",
                    st.tables.overlay_len()
                );
            }
        }
        check_set_integrity(&sealed_set, &pre.hashed);
    }

    /// The built tables must be usable by the LGD estimator end-to-end.
    #[test]
    fn streaming_tables_feed_lgd() {
        use crate::estimator::lgd::{LgdEstimator, LgdOptions};
        use crate::estimator::GradientEstimator;
        let ds = SynthSpec::power_law("p", 300, 10, 11).generate().unwrap();
        let hasher = DenseSrp::new(11, 4, 12, 5);
        let m = Metrics::new();
        let (pre, tables, _) = streaming_build(ds, hasher, &PipelineConfig::default(), &m).unwrap();
        let mut est = LgdEstimator::from_parts(&pre, tables, 13, LgdOptions::default());
        let theta = vec![0.05f32; 10];
        for _ in 0..500 {
            let d = est.draw(&theta);
            assert!(d.index < 300);
            assert!(d.weight > 0.0);
        }
        assert_eq!(est.stats().fallbacks, 0);
    }
}
