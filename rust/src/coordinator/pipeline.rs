//! Streaming ingestion pipeline: Source → Preprocess → Hash → Tables.
//!
//! LGD's one-time preprocessing (normalise, embed into hash space, compute
//! K·L codes, insert into tables) is the natural streaming stage of the
//! system: records flow through bounded channels (backpressure), hash
//! workers parallelise the code computation across the L tables, and a
//! single owner thread applies coded inserts so the table structure never
//! needs locks. The result is bit-identical to the batch
//! [`crate::data::preprocess::preprocess`] + [`LshTables::build`] path
//! (tested below), so the trainer can consume either.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::core::error::{Error, Result};
use crate::core::matrix::{normalize, Matrix};
use crate::coordinator::metrics::Metrics;
use crate::data::dataset::Dataset;
use crate::data::preprocess::{HashSpace, Preprocessed};
use crate::data::shard::ShardPlan;
use crate::lsh::srp::SrpHasher;
use crate::lsh::tables::LshTables;

/// Pipeline tuning knobs.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Bounded-channel capacity between stages (records).
    pub channel_cap: usize,
    /// Parallel hash workers (tables are striped across them).
    pub hash_workers: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { channel_cap: 256, hash_workers: 4 }
    }
}

/// Timing/throughput report of a pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Records processed.
    pub records: usize,
    /// End-to-end wall seconds.
    pub wall_secs: f64,
    /// Records/second.
    pub throughput: f64,
}

struct RawRecord {
    id: u32,
    x: Vec<f32>,
    y: f32,
}

struct HashJob {
    id: u32,
    v: Arc<Vec<f32>>,
}

struct CodedInsert {
    id: u32,
    table: u32,
    code: u32,
}

/// Run the streaming build: consumes `ds`, returns the preprocessed data,
/// the fully-built tables, and a throughput report.
pub fn streaming_build<H>(
    ds: Dataset,
    hasher: H,
    cfg: &PipelineConfig,
    metrics: &Metrics,
) -> Result<(Preprocessed, LshTables<H>, PipelineReport)>
where
    H: SrpHasher + Clone + 'static,
{
    let _n = ds.len();
    let d = ds.dim();
    let task = ds.task;
    let space = HashSpace::for_task(task);
    let hd = space.dim(d);
    if hasher.dim() != hd {
        return Err(Error::Pipeline(format!(
            "hasher dim {} but hash space needs {hd}",
            hasher.dim()
        )));
    }
    let workers = cfg.hash_workers.max(1);
    let l = hasher.l();
    let t0 = Instant::now();

    // Stage channels.
    let (src_tx, src_rx) = sync_channel::<RawRecord>(cfg.channel_cap);
    let mut hash_txs: Vec<SyncSender<HashJob>> = Vec::with_capacity(workers);
    let mut hash_rxs: Vec<Receiver<HashJob>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = sync_channel::<HashJob>(cfg.channel_cap);
        hash_txs.push(tx);
        hash_rxs.push(rx);
    }
    let (ins_tx, ins_rx) = sync_channel::<CodedInsert>(cfg.channel_cap * workers.max(1));

    // --- Source: stream the dataset out of this thread. ---
    let name = ds.name.clone();
    let src = thread::spawn(move || {
        let mut rows = 0usize;
        for i in 0..ds.len() {
            let (x, y) = ds.example(i);
            if src_tx
                .send(RawRecord { id: i as u32, x: x.to_vec(), y })
                .is_err()
            {
                break; // downstream died; it will report the error
            }
            rows += 1;
        }
        rows
    });

    // --- Preprocess: normalise + hash-space embed; fan out to workers. ---
    let pre_handle = thread::spawn(move || -> Result<(Matrix, Vec<f32>, Matrix, Vec<f64>)> {
        let mut xmat = Matrix::zeros(0, 0);
        let mut ys = Vec::new();
        let mut hashed = Matrix::zeros(0, 0);
        let mut norms = Vec::new();
        for mut rec in src_rx.iter() {
            let norm = normalize(&mut rec.x);
            norms.push(norm);
            let mut hv = Vec::with_capacity(hd);
            match space {
                HashSpace::LinRegAugmented => {
                    hv.extend_from_slice(&rec.x);
                    hv.push(rec.y);
                }
                HashSpace::LogRegSigned => {
                    hv.extend(rec.x.iter().map(|v| rec.y * v));
                }
            }
            let hv = Arc::new(hv);
            for tx in &hash_txs {
                tx.send(HashJob { id: rec.id, v: hv.clone() })
                    .map_err(|_| Error::Pipeline("hash worker hung up".into()))?;
            }
            xmat.push_row(&rec.x).map_err(|e| Error::Pipeline(e.to_string()))?;
            ys.push(rec.y);
            hashed
                .push_row(&hv)
                .map_err(|e| Error::Pipeline(e.to_string()))?;
        }
        drop(hash_txs);
        Ok((xmat, ys, hashed, norms))
    });

    // --- Hash workers: tables striped worker w -> tables {w, w+W, ...} ---
    let mut worker_handles = Vec::new();
    for (w, rx) in hash_rxs.into_iter().enumerate() {
        let h = hasher.clone();
        let tx = ins_tx.clone();
        worker_handles.push(thread::spawn(move || -> Result<u64> {
            let mut codes = 0u64;
            for job in rx.iter() {
                let mut t = w;
                while t < l {
                    let code = h.code(t, &job.v);
                    codes += 1;
                    tx.send(CodedInsert { id: job.id, table: t as u32, code })
                        .map_err(|_| Error::Pipeline("table owner hung up".into()))?;
                    t += workers;
                }
            }
            Ok(codes)
        }));
    }
    drop(ins_tx);

    // --- Table owner (this thread): apply coded inserts. ---
    let mut tables = LshTables::new(hasher);
    let mut inserts = 0u64;
    for ins in ins_rx.iter() {
        tables.insert_coded(ins.table as usize, ins.code, ins.id);
        inserts += 1;
    }

    // Join + propagate errors.
    let rows = src.join().map_err(|_| Error::Pipeline("source panicked".into()))?;
    let (xmat, ys, hashed, norms) =
        pre_handle.join().map_err(|_| Error::Pipeline("preprocess panicked".into()))??;
    let mut total_codes = 0u64;
    for h in worker_handles {
        total_codes += h.join().map_err(|_| Error::Pipeline("hash worker panicked".into()))??;
    }
    if inserts != total_codes || inserts != (rows as u64) * l as u64 {
        return Err(Error::Pipeline(format!(
            "insert count {inserts} != codes {total_codes} != rows*L {}",
            rows as u64 * l as u64
        )));
    }
    tables.finish_coded_inserts(rows);

    let wall = t0.elapsed().as_secs_f64();
    metrics.count("pipeline.records", rows as u64);
    metrics.count("pipeline.codes", total_codes);
    metrics.observe("pipeline.wall", wall);

    let data = Dataset::new(name, xmat, ys, task).map_err(|e| Error::Pipeline(e.to_string()))?;
    let pre = Preprocessed { data, hashed, space, center: Vec::new(), norms };
    let report = PipelineReport {
        records: rows,
        wall_secs: wall,
        throughput: rows as f64 / wall.max(1e-12),
    };
    Ok((pre, tables, report))
}

/// One shard of the sharded sampling engine: the slice of stored rows it
/// owns, its copy of those vectors, their norms, and the LSH tables built
/// over them. Row ids index the *virtual* stored matrix `[base; −base]`:
/// id `i < n` is `base.row(i)`, id `i + n` is its negation (mirrored
/// storage) — matching `LgdEstimator`'s stored-row layout.
pub struct ShardTables<H: SrpHasher> {
    /// Virtual stored-row id of each local row (local row j ↔ rows\[j\]).
    pub rows: Vec<u32>,
    /// Local copy of the owned vectors (row j = the vector of rows\[j\]).
    pub stored: Matrix,
    /// Precomputed ‖row‖ for the sampling hot path.
    pub norms: Vec<f64>,
    /// Tables over the local rows (bucket ids are local row indices).
    pub tables: LshTables<H>,
    /// Wall-clock seconds this shard's build took on its worker thread.
    pub build_secs: f64,
}

/// Build per-shard LSH tables concurrently, one worker thread per shard
/// (`std::thread::scope`). `base` holds one hash-space row per example
/// (e.g. `Preprocessed::hashed`); `plan` partitions the examples, and each
/// shard copies the rows of its member examples — plus their negations when
/// `mirror`, materialized on the fly so the full mirrored matrix never
/// exists (the peak-memory win of sharded builds). Every shard clones the
/// same hasher, so query codes agree across shards and a single
/// [`crate::lsh::sampler::QueryCache`] can serve all of them. Per-shard
/// build time is recorded under the `pipeline.shard_build` timer and row
/// counts under the `pipeline.shard_rows` counter.
pub fn build_shard_tables<H>(
    base: &Matrix,
    plan: &ShardPlan,
    mirror: bool,
    hasher: &H,
    metrics: &Metrics,
) -> Result<Vec<ShardTables<H>>>
where
    H: SrpHasher + Clone,
{
    let n: usize = plan.counts().iter().sum();
    if base.rows() != n {
        return Err(Error::Pipeline(format!(
            "shard plan covers {n} examples but base matrix has {} rows",
            base.rows()
        )));
    }
    let results: Vec<std::thread::Result<Result<ShardTables<H>>>> = thread::scope(|scope| {
        let mut handles = Vec::with_capacity(plan.shards());
        for s in 0..plan.shards() {
            let members = plan.members(s);
            let h = hasher.clone();
            handles.push(scope.spawn(move || -> Result<ShardTables<H>> {
                let t0 = Instant::now();
                let mut rows: Vec<u32> = members.iter().map(|&i| i as u32).collect();
                let mut local = Matrix::zeros(0, 0);
                for &i in &members {
                    local.push_row(base.row(i)).map_err(|e| Error::Pipeline(e.to_string()))?;
                }
                if mirror {
                    rows.extend(members.iter().map(|&i| (i + n) as u32));
                    for &i in &members {
                        let neg: Vec<f32> = base.row(i).iter().map(|v| -v).collect();
                        local.push_row(&neg).map_err(|e| Error::Pipeline(e.to_string()))?;
                    }
                }
                let norms: Vec<f64> =
                    (0..local.rows()).map(|i| crate::core::matrix::norm2(local.row(i))).collect();
                let tables = LshTables::build(h, (0..local.rows()).map(|i| local.row(i)))?;
                Ok(ShardTables {
                    rows,
                    stored: local,
                    norms,
                    tables,
                    build_secs: t0.elapsed().as_secs_f64(),
                })
            }));
        }
        handles.into_iter().map(|h| h.join()).collect()
    });
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        let st = r.map_err(|_| Error::Pipeline("shard build worker panicked".into()))??;
        metrics.observe("pipeline.shard_build", st.build_secs);
        metrics.count("pipeline.shard_rows", st.rows.len() as u64);
        out.push(st);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::lsh::srp::DenseSrp;

    fn build_both(
        n: usize,
        d: usize,
        workers: usize,
    ) -> (Preprocessed, LshTables<DenseSrp>, Preprocessed, LshTables<DenseSrp>) {
        let ds = SynthSpec::power_law("p", n, d, 3).generate().unwrap();
        let hasher = DenseSrp::new(d + 1, 4, 10, 7);
        // batch path
        let pre_b = preprocess(ds.clone(), &PreprocessOptions::default()).unwrap();
        let tb = LshTables::build(
            hasher.clone(),
            (0..pre_b.data.len()).map(|i| pre_b.hashed.row(i)),
        )
        .unwrap();
        // streaming path
        let m = Metrics::new();
        let cfg = PipelineConfig { channel_cap: 8, hash_workers: workers };
        let (pre_s, ts, rep) = streaming_build(ds, hasher, &cfg, &m).unwrap();
        assert_eq!(rep.records, n);
        assert_eq!(m.counter("pipeline.records"), n as u64);
        (pre_b, tb, pre_s, ts)
    }

    #[test]
    fn streaming_matches_batch_path() {
        let (pre_b, tb, pre_s, ts) = build_both(200, 12, 3);
        // identical preprocessed data
        assert_eq!(pre_b.data.y, pre_s.data.y);
        assert_eq!(pre_b.hashed.as_slice(), pre_s.hashed.as_slice());
        assert_eq!(pre_b.norms, pre_s.norms);
        // identical table contents (same hasher -> same codes); bucket order
        // within a table may differ, compare as sets
        assert_eq!(tb.len(), ts.len());
        for t in 0..10 {
            for code in 0..(1u32 << 4) {
                let mut a: Vec<u32> = tb.bucket(t, code).to_vec();
                let mut b: Vec<u32> = ts.bucket(t, code).to_vec();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "table {t} code {code}");
            }
        }
    }

    #[test]
    fn single_worker_and_many_workers_agree() {
        let (_, _, _, t1) = build_both(100, 8, 1);
        let (_, _, _, t8) = build_both(100, 8, 8);
        for t in 0..10 {
            for code in 0..(1u32 << 4) {
                let mut a: Vec<u32> = t1.bucket(t, code).to_vec();
                let mut b: Vec<u32> = t8.bucket(t, code).to_vec();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn tiny_channel_capacity_still_completes() {
        let ds = SynthSpec::power_law("p", 150, 6, 9).generate().unwrap();
        let hasher = DenseSrp::new(7, 3, 6, 1);
        let m = Metrics::new();
        let cfg = PipelineConfig { channel_cap: 1, hash_workers: 2 };
        let (pre, tables, rep) = streaming_build(ds, hasher, &cfg, &m).unwrap();
        assert_eq!(rep.records, 150);
        assert_eq!(pre.data.len(), 150);
        assert_eq!(tables.len(), 150);
    }

    #[test]
    fn dim_mismatch_fails_fast() {
        let ds = SynthSpec::power_law("p", 10, 6, 9).generate().unwrap();
        let hasher = DenseSrp::new(6, 3, 4, 1); // should be 7 (augmented)
        let m = Metrics::new();
        let r = streaming_build(ds, hasher, &PipelineConfig::default(), &m);
        assert!(r.is_err());
    }

    #[test]
    fn shard_build_partitions_all_rows() {
        let ds = SynthSpec::power_law("s", 300, 10, 17).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(11, 4, 6, 19);
        let plan = ShardPlan::round_robin(300, 4).unwrap();
        let m = Metrics::new();
        let shards = build_shard_tables(&pre.hashed, &plan, false, &hasher, &m).unwrap();
        assert_eq!(shards.len(), 4);
        let mut seen: Vec<u32> = shards.iter().flat_map(|s| s.rows.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..300u32).collect::<Vec<_>>(), "shards must partition the rows");
        for s in &shards {
            assert_eq!(s.tables.len(), s.rows.len());
            assert_eq!(s.stored.rows(), s.rows.len());
            assert_eq!(s.norms.len(), s.rows.len());
        }
        assert_eq!(m.counter("pipeline.shard_rows"), 300);
        assert_eq!(m.timer("pipeline.shard_build").unwrap().0, 4);
    }

    /// shards = 1 reproduces the unsharded table build bucket-for-bucket.
    #[test]
    fn single_shard_matches_unsharded_build() {
        let ds = SynthSpec::power_law("s", 200, 8, 21).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(9, 4, 8, 23);
        let full = LshTables::build(hasher.clone(), (0..200).map(|i| pre.hashed.row(i))).unwrap();
        let plan = ShardPlan::round_robin(200, 1).unwrap();
        let m = Metrics::new();
        let shards = build_shard_tables(&pre.hashed, &plan, false, &hasher, &m).unwrap();
        assert_eq!(shards.len(), 1);
        let st = &shards[0];
        assert_eq!(st.rows, (0..200u32).collect::<Vec<_>>());
        for t in 0..8 {
            for code in 0..(1u32 << 4) {
                let (a, b) = (full.bucket(t, code), st.tables.bucket(t, code));
                assert_eq!(a, b, "table {t} code {code}");
            }
        }
    }

    /// Mirrored builds keep each example's row and its on-the-fly negation
    /// on the same shard, and a plan/matrix row-count mismatch is rejected.
    #[test]
    fn mirrored_shard_build_owns_both_signs() {
        let ds = SynthSpec::power_law("s", 60, 6, 27).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(7, 3, 5, 29);
        let plan = ShardPlan::round_robin(60, 3).unwrap();
        let m = Metrics::new();
        let shards = build_shard_tables(&pre.hashed, &plan, true, &hasher, &m).unwrap();
        for (s_idx, s) in shards.iter().enumerate() {
            let cnt = s.rows.len() / 2;
            assert_eq!(s.rows.len(), 2 * cnt);
            for j in 0..cnt {
                assert_eq!(s.rows[j + cnt] as usize, s.rows[j] as usize + 60);
                assert_eq!(plan.shard_of(s.rows[j] as usize), s_idx);
                for (a, b) in s.stored.row(j).iter().zip(s.stored.row(j + cnt)) {
                    assert_eq!(*a, -*b, "mirror row must be the exact negation");
                }
            }
        }
        assert_eq!(m.counter("pipeline.shard_rows"), 120);
        let short_plan = ShardPlan::round_robin(50, 3).unwrap();
        assert!(
            build_shard_tables(&pre.hashed, &short_plan, true, &hasher, &m).is_err(),
            "plan/matrix row-count mismatch must be rejected"
        );
    }

    /// The built tables must be usable by the LGD estimator end-to-end.
    #[test]
    fn streaming_tables_feed_lgd() {
        use crate::estimator::lgd::{LgdEstimator, LgdOptions};
        use crate::estimator::GradientEstimator;
        let ds = SynthSpec::power_law("p", 300, 10, 11).generate().unwrap();
        let hasher = DenseSrp::new(11, 4, 12, 5);
        let m = Metrics::new();
        let (pre, tables, _) =
            streaming_build(ds, hasher, &PipelineConfig::default(), &m).unwrap();
        let mut est = LgdEstimator::from_parts(&pre, tables, 13, LgdOptions::default());
        let theta = vec![0.05f32; 10];
        for _ in 0..500 {
            let d = est.draw(&theta);
            assert!(d.index < 300);
            assert!(d.weight > 0.0);
        }
        assert_eq!(est.stats().fallbacks, 0);
    }
}
