//! `lgd` — the LGD coordinator CLI.
//!
//! Subcommands:
//! * `train --config run.toml` — run one training configuration
//!   (`--snapshot/--autosave-epochs/--resume` persist + warm-start the
//!   engine through `store::snapshot`).
//! * `snapshot save|inspect|load` — build-and-persist, verify, and
//!   warm-start-serve an engine snapshot.
//! * `experiments --id <table4|fig9|fig10|fig11|fig12|fig13|variance|sampling|fig5|all>`
//!   — regenerate a paper table/figure series into `results/`.
//! * `gen-data --name <spec> --out file.csv` — dump a synthetic dataset.
//! * `serve` — build the index once and serve it concurrently: the
//!   in-process N-client harness reports draws/sec vs client count, and
//!   `--addr host:port` additionally exposes the length-prefixed TCP
//!   front (`runtime::serving`).
//! * `stats --addr host:port` — query a running server: STATS (wire
//!   counters + registry dump) and METRICS (validated Prometheus text).
//! * `trace summarize --path file.jsonl` — aggregate a telemetry trace
//!   file into a per-span table.
//! * `runtime-smoke` — load an AOT artifact, execute it, cross-check
//!   against the native Rust gradient (three-layer health check).
//! * `help` — this text.

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lgd::cli::Args;
use lgd::config::spec::{parse_quarantine, Backend, RunConfig};
use lgd::config::toml::TomlDoc;
use lgd::coordinator::trainer::{
    build_sharded_estimator, lgd_options, train, train_resumed, GradSource,
};
use lgd::core::error::{Error, Result};
use lgd::core::telemetry::registry::Registry;
use lgd::core::telemetry::{probes, prom, trace};
use lgd::data::csv::CsvWriter;
use lgd::data::preprocess::{preprocess, PreprocessOptions, Preprocessed};
use lgd::estimator::GradientEstimator;
use lgd::experiments::ExpOptions;
use lgd::lsh::{AnyHasher, HasherVisitor};
use lgd::runtime::{run_harness, serve_supervised, Runtime, ServeOptions, ServingCore};
use lgd::store::snapshot::{self, LoadedSnapshot, SnapshotHasher};

const USAGE: &str = "\
lgd — LSH-sampled Stochastic Gradient Descent (paper reproduction)

USAGE:
  lgd train --config <run.toml> [--out <dir>] [--shards <n>]
            [--rebalance-threshold <f>] [--sealed <true|false>]
            [--async-workers <n>] [--queue-depth <n>] [--kernel <auto|scalar>]
            [--snapshot <file.lgdsnap>] [--autosave-epochs <n>] [--keep <n>] [--resume]
            [--health <on|off>] [--quarantine <id,id,...>] [--allow-nonfinite]
            [--inject <grad-nan|theta-poison|loss-corrupt>:<once|always|times:N>[:<arg>]]
            [--telemetry <on|off>] [--trace] [--trace-path <file.jsonl>]
  lgd snapshot save --config <run.toml> --out <file.lgdsnap>
               [--shards <n>] [--sealed <true|false>]
  lgd snapshot inspect --path <file.lgdsnap>
  lgd snapshot load --path <file.lgdsnap>
  lgd experiments --id <table4|fig9|fig10|fig11|fig12|fig13|variance|sampling|fig5|all>
                  [--scale <f>] [--out <dir>] [--seed <n>] [--quick] [--artifacts <dir>]
  lgd gen-data --name <yearmsd-like|slice-like|ujiindoor-like|pareto|uniform>
               --out <file.csv> [--scale <f>] [--seed <n>]
  lgd serve [--config <run.toml>] [--clients <n>] [--batch <m>] [--requests <n>]
            [--addr <host:port>] [--shards <n>] [--sealed <true|false>]
            [--max-clients <n>] [--idle-timeout-ms <n>] [--io-timeout-ms <n>]
            [--metrics]
  lgd stats --addr <host:port> [--seed <n>]
  lgd trace summarize --path <file.jsonl>
  lgd runtime-smoke [--artifacts <dir>]
  lgd help
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    // `lgd snapshot <save|inspect|load>` carries a sub-verb, which the flag
    // grammar does not allow as a second positional — route it before the
    // general parse.
    if argv.first().map(|s| s.as_str()) == Some("snapshot") {
        return cmd_snapshot(&argv[1..]);
    }
    // `lgd trace summarize` carries a sub-verb too.
    if argv.first().map(|s| s.as_str()) == Some("trace") {
        return cmd_trace(&argv[1..]);
    }
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "experiments" => cmd_experiments(&args),
        "gen-data" => cmd_gen_data(&args),
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "runtime-smoke" => cmd_runtime_smoke(&args),
        "" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::Config(format!("unknown subcommand '{other}'\n{USAGE}"))),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    args.allow(&[
        "config", "out", "shards", "rebalance-threshold", "sealed", "async-workers",
        "queue-depth", "kernel", "snapshot", "autosave-epochs", "keep", "resume",
        "health", "quarantine", "allow-nonfinite", "inject", "telemetry", "trace",
        "trace-path",
    ])?;
    let cfg_path = args.require("config")?;
    let doc = TomlDoc::load(std::path::Path::new(&cfg_path))?;
    let mut cfg = RunConfig::from_toml(&doc)?;
    if let Some(out) = args.has("out").then(|| args.str_or("out", "results")) {
        cfg.out_dir = PathBuf::from(out);
    }
    // --shards / --rebalance-threshold override the config's [lsh] knobs;
    // explicit out-of-range values (e.g. 0 shards) are rejected by
    // validation, not ignored.
    if !args.str_or("shards", "").is_empty() {
        cfg.lsh.shards = args.usize_or("shards", 1)?;
        cfg.validate()?;
    }
    if !args.str_or("rebalance-threshold", "").is_empty() {
        cfg.lsh.rebalance_threshold = args.f64_or("rebalance-threshold", 0.0)?;
        cfg.validate()?;
    }
    // --sealed overrides the [lsh] sealed knob (CSR arena vs Vec buckets).
    cfg.lsh.sealed = args.bool_or("sealed", cfg.lsh.sealed)?;
    // --async-workers / --queue-depth override the async draw engine
    // knobs (0 workers = synchronous draws, the default).
    if !args.str_or("async-workers", "").is_empty() {
        cfg.lsh.async_workers = args.usize_or("async-workers", 0)?;
        cfg.validate()?;
    }
    if !args.str_or("queue-depth", "").is_empty() {
        cfg.lsh.queue_depth = args.usize_or("queue-depth", 1024)?;
        cfg.validate()?;
    }
    // --kernel A/Bs the aligned-numerics dispatch (bitwise-invisible; see
    // docs/numerics.md).
    let kernel = args.str_or("kernel", "");
    if !kernel.is_empty() {
        cfg.lsh.kernel = lgd::core::numerics::KernelMode::from_name(&kernel)
            .ok_or_else(|| Error::Config(format!("unknown kernel '{kernel}' (auto|scalar)")))?;
    }
    // --snapshot / --autosave-epochs / --resume override the [store] block
    // (persistence + warm start).
    if !args.str_or("snapshot", "").is_empty() {
        cfg.store.path = Some(PathBuf::from(args.str_or("snapshot", "")));
    }
    if !args.str_or("autosave-epochs", "").is_empty() {
        cfg.store.autosave_epochs = args.usize_or("autosave-epochs", 0)?;
    }
    if !args.str_or("keep", "").is_empty() {
        cfg.store.keep = args.usize_or("keep", 2)?;
    }
    // Accept both spellings: bare `--resume` and `--resume true|false`
    // (the sibling bool flags take values, so the valued form is an easy
    // reach — it must not silently fall through to a cold run that then
    // overwrites the checkpoint).
    if args.has("resume") || args.bool_or("resume", false)? {
        cfg.store.resume = true;
    }
    // --health arms/disarms the training-loop supervisor ([health] block);
    // --quarantine / --allow-nonfinite override the [data] robustness knobs.
    match args.str_or("health", "").as_str() {
        "" => {}
        "on" | "true" => cfg.health.enabled = true,
        "off" | "false" => cfg.health.enabled = false,
        other => return Err(Error::Config(format!("--health {other}: expected on|off"))),
    }
    if !args.str_or("quarantine", "").is_empty() {
        cfg.data.quarantine = parse_quarantine(&args.str_or("quarantine", ""))?;
    }
    if args.has("allow-nonfinite") || args.bool_or("allow-nonfinite", false)? {
        cfg.data.allow_nonfinite = true;
    }
    // --telemetry / --trace / --trace-path override the [telemetry] block
    // (docs/observability.md). Telemetry is passive: armed or not, a
    // seeded run is bit-for-bit identical.
    match args.str_or("telemetry", "").as_str() {
        "" => {}
        "on" | "true" => cfg.telemetry.enabled = true,
        "off" | "false" => cfg.telemetry.enabled = false,
        other => return Err(Error::Config(format!("--telemetry {other}: expected on|off"))),
    }
    if args.has("trace") || args.bool_or("trace", false)? {
        cfg.telemetry.trace = true;
    }
    if !args.str_or("trace-path", "").is_empty() {
        cfg.telemetry.trace_path = PathBuf::from(args.str_or("trace-path", ""));
    }
    // --inject arms a failpoint for chaos smoke runs; only builds carrying
    // the `failpoints` feature have an armable registry.
    let inject = args.str_or("inject", "");
    if !inject.is_empty() {
        arm_injection(&inject)?;
    }
    cfg.validate()?;

    // dataset: the test split always comes from the config; the training
    // split is either preprocessed here (cold) or restored from the
    // snapshot (warm — the whole point is not touching the raw data again)
    let ds =
        build_dataset(&cfg.data.name, cfg.data.scale, cfg.data.seed, cfg.data.allow_nonfinite)?;
    let (tr, te) = ds.split(cfg.data.train_frac, cfg.data.seed)?;

    // Arm the passive telemetry before the first draw: the probes watch
    // the training split's draw stream, tracing appends JSONL span events
    // to the configured file (rotated at trace_max_bytes). Neither touches
    // the RNG — a seeded run is bit-for-bit identical either way.
    if cfg.telemetry.enabled {
        probes::arm(cfg.telemetry.probe_window, tr.len());
    }
    if cfg.telemetry.trace {
        trace::arm(&cfg.telemetry.trace_path, cfg.telemetry.trace_max_bytes).map_err(|e| {
            Error::Io(format!("trace {}: {e}", cfg.telemetry.trace_path.display()))
        })?;
        println!("telemetry: tracing spans to {}", cfg.telemetry.trace_path.display());
    }

    let outcome = if cfg.store.resume {
        let base = cfg.store.path.clone().expect("validated: resume requires a path");
        let t0 = Instant::now();
        // Newest-valid-wins: a crash mid-autosave (or a corrupt newest
        // file) falls back to the previous rotated generation instead of
        // refusing to start.
        let rec = snapshot::recover(&base, cfg.store.keep)?;
        if rec.slot > 0 {
            println!(
                "newest snapshot at {} is unreadable — falling back to rotated \
                 generation {} ({} newer file(s) skipped)",
                base.display(),
                rec.path.display(),
                rec.skipped
            );
        }
        let path = rec.path;
        let snap = rec.snap;
        // The test split above is regenerated from the [data] config while
        // the training rows come from the snapshot — if the config's
        // dataset drifted since the save, the reported test losses would be
        // measured against a split of data the model never trained on.
        if tr.len() != snap.meta.n || tr.name != snap.pre.data.name {
            return Err(Error::Config(format!(
                "snapshot trains on '{}' ({} examples) but the [data] config regenerates \
                 '{}' ({} examples) — resume with the original [data] block or re-index",
                snap.pre.data.name,
                snap.meta.n,
                tr.name,
                tr.len()
            )));
        }
        println!(
            "warm start from {} ({} examples, {} shard(s), {} layout, generation {}) \
             in {:.3}s — no table build",
            path.display(),
            snap.meta.n,
            snap.meta.shards,
            if snap.meta.sealed { "sealed" } else { "vec" },
            snap.meta.generation,
            t0.elapsed().as_secs_f64()
        );
        match cfg.train.backend {
            Backend::Native => train_resumed(&cfg, &te, GradSource::Native, snap)?,
            Backend::Pjrt => {
                let mut rt = Runtime::new(&lgd::runtime::default_artifacts_dir())?;
                train_resumed(&cfg, &te, GradSource::Pjrt(&mut rt), snap)?
            }
        }
    } else {
        let pre = preprocess(tr, &PreprocessOptions { center: cfg.lsh.center })?;
        match cfg.train.backend {
            Backend::Native => train(&cfg, &pre, &te, GradSource::Native)?,
            Backend::Pjrt => {
                let mut rt = Runtime::new(&lgd::runtime::default_artifacts_dir())?;
                train(&cfg, &pre, &te, GradSource::Pjrt(&mut rt))?
            }
        }
    };

    // write the curve
    let path = cfg.out_dir.join(format!("{}.csv", cfg.name));
    let mut w = CsvWriter::create(
        &path,
        &["iter", "epoch", "wall_secs", "train_loss", "test_loss"],
    )?;
    for p in &outcome.curve {
        w.row(&[p.iter as f64, p.epoch, p.wall, p.train_loss, p.test_loss])?;
    }
    w.flush()?;
    println!(
        "run '{}' [{}]: {} iters in {:.3}s (preprocess {:.3}s), loss {:.5} -> {:.5}; curve -> {}",
        cfg.name,
        outcome.estimator,
        outcome.iterations,
        outcome.wall_secs,
        outcome.preprocess_secs,
        outcome.curve.first().unwrap().train_loss,
        outcome.curve.last().unwrap().train_loss,
        path.display()
    );
    if !outcome.shard_build_secs.is_empty() {
        let slowest = outcome.shard_build_secs.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "  sharded build: {} shards, slowest worker {:.3}s",
            outcome.shard_build_secs.len(),
            slowest
        );
    }
    if outcome.estimator == "lgd-async" {
        let st = &outcome.est_stats;
        let served = st.prefetch_hits + st.queue_stalls;
        println!(
            "  async serving: {} of {} batches prefetched ({} stalls)",
            st.prefetch_hits, served, st.queue_stalls
        );
    }
    if outcome.est_stats.migrations > 0 {
        println!(
            "  rebalancing: {} examples migrated in {} passes ({:.3}s)",
            outcome.est_stats.migrations,
            outcome.est_stats.rebalances,
            outcome.est_stats.rebalance_secs
        );
    }
    if outcome.resumed {
        println!("  warm start: restored engine, zero table-build work");
    }
    if cfg.health.enabled {
        let h = &outcome.health;
        println!(
            "  health: trips={} (grad={} theta={} loss={}) quarantined={} rollbacks={}",
            h.sentinel_trips(),
            h.grad_trips,
            h.theta_trips,
            h.loss_trips,
            h.quarantined,
            h.rollbacks
        );
    }
    if outcome.autosaves > 0 {
        if let Some(p) = &cfg.store.path {
            println!("  snapshots: {} written to {}", outcome.autosaves, p.display());
        }
    }
    if cfg.telemetry.enabled {
        let reg = Registry::global();
        probes::publish(reg);
        println!(
            "  telemetry: {} draws probed, fallback rate {:.4}, {:.2} probes/draw, \
             tv-distance {:.4}; {} epoch metric snapshot(s)",
            reg.gauge_value("probe.draws"),
            reg.gauge_value("probe.fallback_rate"),
            reg.gauge_value("probe.probes_per_draw"),
            reg.gauge_value("probe.tv_distance"),
            outcome.epoch_metrics.len()
        );
        probes::disarm();
    }
    if cfg.telemetry.trace {
        trace::disarm();
        match trace::summarize_file(&cfg.telemetry.trace_path) {
            Ok(table) => print!("{table}"),
            Err(e) => println!("  trace summarize failed: {e}"),
        }
    }
    Ok(())
}

/// `lgd snapshot <save|inspect|load>` — build-and-persist, verify, and
/// warm-start-serve an engine snapshot.
fn cmd_snapshot(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "save" => cmd_snapshot_save(&args),
        "inspect" => cmd_snapshot_inspect(&args),
        "load" => cmd_snapshot_load(&args),
        other => Err(Error::Config(format!(
            "snapshot needs a verb: save|inspect|load (got '{other}')\n{USAGE}"
        ))),
    }
}

/// Cold-build the engine a config describes, then persist it. The visitor
/// monomorphizes over the configured hash family.
struct ColdSave<'a> {
    cfg: &'a RunConfig,
    pre: &'a Preprocessed,
    out: &'a Path,
}

impl<'a> HasherVisitor for ColdSave<'a> {
    type Out = Result<(u64, f64)>;

    fn visit<H>(self, hasher: H) -> Self::Out
    where
        H: SnapshotHasher + Clone + 'static,
    {
        let t0 = Instant::now();
        let est = build_sharded_estimator(self.cfg, self.pre, hasher)?;
        let build_secs = t0.elapsed().as_secs_f64();
        let bytes = snapshot::save(self.out, &est, None)?;
        Ok((bytes, build_secs))
    }
}

fn cmd_snapshot_save(args: &Args) -> Result<()> {
    args.allow(&["config", "out", "shards", "sealed"])?;
    let cfg_path = args.require("config")?;
    let out = PathBuf::from(args.require("out")?);
    let doc = TomlDoc::load(Path::new(&cfg_path))?;
    let mut cfg = RunConfig::from_toml(&doc)?;
    if !args.str_or("shards", "").is_empty() {
        cfg.lsh.shards = args.usize_or("shards", 1)?;
    }
    cfg.lsh.sealed = args.bool_or("sealed", cfg.lsh.sealed)?;
    cfg.validate()?;
    let ds =
        build_dataset(&cfg.data.name, cfg.data.scale, cfg.data.seed, cfg.data.allow_nonfinite)?;
    let (tr, _te) = ds.split(cfg.data.train_frac, cfg.data.seed)?;
    let pre = preprocess(tr, &PreprocessOptions { center: cfg.lsh.center })?;
    let hd = pre.hashed.cols();
    let saver = ColdSave { cfg: &cfg, pre: &pre, out: &out };
    let (bytes, build_secs) = AnyHasher::from_lsh_config(&cfg.lsh, hd).visit(saver)?;
    println!(
        "snapshot: built {} examples x {} shard(s) in {build_secs:.3}s, wrote {bytes} bytes \
         to {}",
        pre.data.len(),
        cfg.lsh.shards,
        out.display()
    );
    Ok(())
}

fn cmd_snapshot_inspect(args: &Args) -> Result<()> {
    args.allow(&["path"])?;
    let path = PathBuf::from(args.require("path")?);
    let info = snapshot::inspect(&path)?;
    println!("{} — {} bytes, format v{}", path.display(), info.file_bytes, info.version);
    println!("{:<12} {:>12} {:>12}", "section", "bytes", "crc32");
    for s in &info.sections {
        println!("{:<12} {:>12} {:>12}", s.name, s.bytes, format!("{:08x}", s.crc));
    }
    let m = &info.meta;
    println!(
        "engine: {} examples (d={}, hash dim {}), task {}, hasher {} (K={}, L={})",
        m.n, m.d, m.hash_dim, m.task, m.hasher, m.k, m.l
    );
    println!(
        "        {} shard(s), mirror {}, layout {}, generation {}, {} stored rows, \
         {} present",
        m.shards,
        m.mirror,
        if m.sealed { "sealed" } else { "vec" },
        m.generation,
        m.total_rows,
        m.present
    );
    println!(
        "        training state: {}",
        if m.has_train { "present (resumable mid-run)" } else { "none (index only)" }
    );
    println!("all section CRCs verified OK");
    Ok(())
}

fn cmd_snapshot_load(args: &Args) -> Result<()> {
    args.allow(&["path", "draws"])?;
    let path = PathBuf::from(args.require("path")?);
    let draws = args.usize_or("draws", 5)?;
    let t0 = Instant::now();
    let snap = snapshot::load(&path)?;
    let load_secs = t0.elapsed().as_secs_f64();
    let LoadedSnapshot { meta, pre, hasher, engine, .. } = snap;
    let handle = hasher.clone();
    let t1 = Instant::now();
    let mut est = snapshot::restore_boxed(hasher, &pre, engine)?;
    let restore_secs = t1.elapsed().as_secs_f64();
    let stats = handle.hash_stats();
    println!(
        "loaded {} in {load_secs:.3}s, restored engine in {restore_secs:.3}s \
         ({} examples, {} shard(s), {} layout)",
        path.display(),
        meta.n,
        meta.shards,
        if meta.sealed { "sealed" } else { "vec" }
    );
    println!(
        "zero-rebuild proof: {} row hashes, {} fused query hashes during restore",
        stats.code_calls, stats.fused_calls
    );
    if draws > 0 {
        let theta = vec![0.0f32; pre.data.dim()];
        for i in 0..draws {
            let d = est.draw(&theta);
            println!(
                "  draw {i}: example {} (p = {:.3e}, weight {:.3})",
                d.index, d.prob, d.weight
            );
        }
    }
    Ok(())
}

/// Arm one failpoint from an `--inject site:mode[:n]` spec — chaos smoke
/// runs for CI and operators. Site names: `grad-nan`, `theta-poison`,
/// `loss-corrupt`. Modes: `once`, `always`, `times:N`, `nth:N`.
#[cfg(feature = "failpoints")]
fn arm_injection(spec: &str) -> Result<()> {
    use lgd::testkit::faults;
    let parts: Vec<&str> = spec.split(':').collect();
    let site = match parts[0] {
        "grad-nan" => faults::GRAD_NAN,
        "theta-poison" => faults::THETA_POISON,
        "loss-corrupt" => faults::LOSS_CORRUPT,
        other => {
            return Err(Error::Config(format!(
                "--inject: unknown site '{other}' (grad-nan|theta-poison|loss-corrupt)"
            )))
        }
    };
    let parse_n = |s: Option<&&str>, what: &str| -> Result<u64> {
        s.and_then(|v| v.parse::<u64>().ok())
            .ok_or_else(|| Error::Config(format!("--inject: {what} needs a count, got '{spec}'")))
    };
    let mode = match parts.get(1).copied() {
        Some("once") => faults::Mode::Once,
        Some("always") => faults::Mode::Always,
        Some("times") => faults::Mode::Times(parse_n(parts.get(2), "times")?),
        Some("nth") => faults::Mode::Nth(parse_n(parts.get(2), "nth")?),
        other => {
            return Err(Error::Config(format!(
                "--inject: unknown mode '{}' (once|always|times:N|nth:N)",
                other.unwrap_or("")
            )))
        }
    };
    faults::arm(site, mode);
    println!("chaos: armed failpoint {site} ({})", &spec[parts[0].len() + 1..]);
    Ok(())
}

/// Without the `failpoints` feature there is no armable registry — make
/// the flag an explicit error rather than a silent no-op.
#[cfg(not(feature = "failpoints"))]
fn arm_injection(_spec: &str) -> Result<()> {
    Err(Error::Config(
        "--inject requires a build with --features failpoints".into(),
    ))
}

fn build_dataset(
    name: &str,
    scale: f64,
    seed: u64,
    allow_nonfinite: bool,
) -> Result<lgd::data::Dataset> {
    use lgd::data::SynthSpec;
    let spec = match name {
        "yearmsd-like" => SynthSpec::power_law("yearmsd-like", scaled(463_715, scale), 90, seed),
        "slice-like" => SynthSpec::power_law("slice-like", scaled(53_500, scale), 385, seed),
        "ujiindoor-like" => {
            SynthSpec::power_law("ujiindoor-like", scaled(21_048, scale), 529, seed)
        }
        "pareto" => SynthSpec::power_law("pareto", scaled(50_000, scale), 32, seed),
        "uniform" => SynthSpec::uniform_control("uniform", scaled(50_000, scale), 32, seed),
        other => {
            // fall back to CSV path
            let p = std::path::Path::new(other);
            if p.exists() {
                return lgd::data::csv::load_csv_with(
                    p,
                    lgd::data::csv::TargetColumn::Last,
                    lgd::data::Task::Regression,
                    allow_nonfinite,
                );
            }
            return Err(Error::Config(format!("unknown dataset '{other}'")));
        }
    };
    spec.generate()
}

fn scaled(n: usize, scale: f64) -> usize {
    ((n as f64 * scale).round() as usize).max(64)
}

fn cmd_experiments(args: &Args) -> Result<()> {
    args.allow(&["id", "scale", "out", "seed", "quick", "artifacts"])?;
    let id = args.str_or("id", "all");
    let opts = ExpOptions {
        scale: args.f64_or("scale", 0.02)?,
        out_dir: PathBuf::from(args.str_or("out", "results")),
        seed: args.u64_or("seed", 42)?,
        quick: args.has("quick"),
        artifacts: {
            let a = args.str_or("artifacts", "");
            if a.is_empty() {
                None
            } else {
                Some(PathBuf::from(a))
            }
        },
    };
    lgd::experiments::run(&id, &opts)
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    args.allow(&["name", "out", "scale", "seed"])?;
    let name = args.require("name")?;
    let out = PathBuf::from(args.require("out")?);
    let ds = build_dataset(&name, args.f64_or("scale", 0.02)?, args.u64_or("seed", 42)?, false)?;
    let mut header: Vec<String> = (0..ds.dim()).map(|j| format!("x{j}")).collect();
    header.push("y".into());
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut w = CsvWriter::create(&out, &hrefs)?;
    for i in 0..ds.len() {
        let (x, y) = ds.example(i);
        let mut row: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        row.push(y as f64);
        w.row(&row)?;
    }
    w.flush()?;
    println!("wrote {} rows x {} cols to {}", ds.len(), ds.dim() + 1, out.display());
    Ok(())
}

/// Build the serving core a config describes and drive the in-process
/// N-client harness (plus the TCP wire front when `serve.addr` is set).
/// The visitor monomorphizes over the configured hash family, like the
/// snapshot-save path.
struct ServeRun<'a> {
    cfg: &'a RunConfig,
    pre: Arc<Preprocessed>,
    /// `--metrics`: print the Prometheus exposition after the harness
    /// sweep (the TCP front always answers the METRICS op regardless).
    metrics: bool,
}

impl<'a> HasherVisitor for ServeRun<'a> {
    type Out = Result<()>;

    fn visit<H>(self, hasher: H) -> Self::Out
    where
        H: SnapshotHasher + Clone + 'static,
    {
        let cfg = self.cfg;
        let t0 = Instant::now();
        let core =
            ServingCore::build(Arc::clone(&self.pre), hasher, lgd_options(cfg), cfg.lsh.shards)?;
        println!(
            "serving core: {} examples x {} shard(s), {} layout, generation {}, \
             built in {:.3}s",
            self.pre.data.len(),
            cfg.lsh.shards,
            if cfg.lsh.sealed { "sealed" } else { "vec" },
            core.generation(),
            t0.elapsed().as_secs_f64()
        );

        // Scaling sweep: client counts {1, 2, 4, 8} up to the configured
        // ceiling, always ending on serve.clients itself.
        let theta = vec![0.0f32; self.pre.data.dim()];
        let mut counts: Vec<usize> =
            [1usize, 2, 4, 8].into_iter().filter(|&c| c < cfg.serve.clients).collect();
        counts.push(cfg.serve.clients);
        println!(
            "{:>8} {:>12} {:>14} {:>12} {:>10}",
            "clients", "draws", "draws/sec", "stale_rej", "degraded"
        );
        for &c in &counts {
            let rep = run_harness(
                &core,
                c,
                cfg.serve.requests,
                cfg.serve.batch,
                &theta,
                cfg.train.seed,
            )?;
            println!(
                "{:>8} {:>12} {:>14.0} {:>12} {:>10}",
                rep.clients, rep.draws, rep.draws_per_sec, rep.stale_rejected, rep.degraded
            );
        }

        if self.metrics {
            probes::publish(Registry::global());
            print!("{}", prom::render(Registry::global()));
        }

        if !cfg.serve.addr.is_empty() {
            let listener = std::net::TcpListener::bind(&cfg.serve.addr)
                .map_err(|e| Error::Io(format!("bind {}: {e}", cfg.serve.addr)))?;
            let opts = ServeOptions {
                max_clients: cfg.serve.max_clients,
                idle_timeout: Duration::from_millis(cfg.serve.idle_timeout_ms),
                io_timeout: Duration::from_millis(cfg.serve.io_timeout_ms),
            };
            println!(
                "listening on {} (max {} clients, idle {}ms, io {}ms) — kill the \
                 process to stop",
                cfg.serve.addr,
                opts.max_clients,
                cfg.serve.idle_timeout_ms,
                cfg.serve.io_timeout_ms
            );
            // The CLI front runs until the process is killed; the stop flag
            // exists for embedders (tests flip it from another thread).
            let stop = AtomicBool::new(false);
            let totals = serve_supervised(&core, listener, &stop, &opts)?;
            println!(
                "served {} draws over {} TCP connection(s) ({} errored, {} rejected \
                 at capacity)",
                totals.draws, totals.connections, totals.conn_errors, totals.rejected_at_capacity
            );
        }
        Ok(())
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.allow(&[
        "config", "clients", "batch", "requests", "addr", "shards", "sealed", "max-clients",
        "idle-timeout-ms", "io-timeout-ms", "metrics",
    ])?;
    let mut cfg = match args.str_or("config", "").as_str() {
        "" => RunConfig::default(),
        path => RunConfig::from_toml(&TomlDoc::load(Path::new(path))?)?,
    };
    // Flag overrides for the [serve] block (and the shard/layout knobs the
    // serving core inherits from [lsh]); out-of-range values are rejected
    // by validation, not ignored.
    if !args.str_or("clients", "").is_empty() {
        cfg.serve.clients = args.usize_or("clients", 4)?;
    }
    if !args.str_or("batch", "").is_empty() {
        cfg.serve.batch = args.usize_or("batch", 32)?;
    }
    if !args.str_or("requests", "").is_empty() {
        cfg.serve.requests = args.usize_or("requests", 200)?;
    }
    if !args.str_or("addr", "").is_empty() {
        cfg.serve.addr = args.str_or("addr", "");
    }
    if !args.str_or("shards", "").is_empty() {
        cfg.lsh.shards = args.usize_or("shards", 1)?;
    }
    cfg.lsh.sealed = args.bool_or("sealed", cfg.lsh.sealed)?;
    if !args.str_or("max-clients", "").is_empty() {
        cfg.serve.max_clients = args.usize_or("max-clients", 64)?;
    }
    if !args.str_or("idle-timeout-ms", "").is_empty() {
        cfg.serve.idle_timeout_ms = args.u64_or("idle-timeout-ms", 30_000)?;
    }
    if !args.str_or("io-timeout-ms", "").is_empty() {
        cfg.serve.io_timeout_ms = args.u64_or("io-timeout-ms", 5_000)?;
    }
    cfg.validate()?;

    let ds =
        build_dataset(&cfg.data.name, cfg.data.scale, cfg.data.seed, cfg.data.allow_nonfinite)?;
    let (tr, _te) = ds.split(cfg.data.train_frac, cfg.data.seed)?;
    let pre = Arc::new(preprocess(tr, &PreprocessOptions { center: cfg.lsh.center })?);
    // Sampling-quality probes watch the serving draw streams too (passive
    // — the wire draws are bit-for-bit identical armed or not).
    if cfg.telemetry.enabled {
        probes::arm(cfg.telemetry.probe_window, pre.data.len());
    }
    let metrics = args.has("metrics") || args.bool_or("metrics", false)?;
    let hd = pre.hashed.cols();
    AnyHasher::from_lsh_config(&cfg.lsh, hd).visit(ServeRun { cfg: &cfg, pre, metrics })
}

/// `lgd stats --addr host:port` — query a running server's wire counters,
/// dump the registry appendix, and validate the Prometheus exposition.
fn cmd_stats(args: &Args) -> Result<()> {
    args.allow(&["addr", "seed"])?;
    let addr = args.require("addr")?;
    let seed = args.u64_or("seed", 0)?;
    let mut client = lgd::runtime::ServeClient::connect(addr.as_str(), seed)?;
    let (stats, registry) = client.stats_full()?;
    println!("server at {addr} (generation {}):", client.generation);
    println!(
        "  flips={} sessions={} draws_served={} stale_rejected={} degraded={}",
        stats.flips, stats.sessions, stats.draws_served, stats.stale_rejected,
        stats.degraded_sessions
    );
    println!(
        "  connections={} conn_errors={} rejected_at_capacity={}",
        stats.connections, stats.conn_errors, stats.rejected_at_capacity
    );
    println!("registry appendix: {} metrics", registry.len());
    for (name, value) in &registry {
        println!("  {name} = {value}");
    }
    let text = client.metrics()?;
    let sum = prom::validate(&text)
        .map_err(|e| Error::Pipeline(format!("METRICS failed Prometheus validation: {e}")))?;
    println!(
        "METRICS: valid Prometheus text ({} counters, {} gauges, {} histograms, {} samples)",
        sum.counters, sum.gauges, sum.histograms, sum.samples
    );
    print!("{text}");
    client.bye()
}

/// `lgd trace summarize --path file.jsonl` — aggregate a JSONL span trace
/// (plus its rotated predecessor, when present) into a per-span table.
fn cmd_trace(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "summarize" => {
            args.allow(&["path"])?;
            let path = PathBuf::from(args.require("path")?);
            let table = trace::summarize_file(&path)
                .map_err(|e| Error::Io(format!("trace {}: {e}", path.display())))?;
            print!("{table}");
            Ok(())
        }
        other => {
            Err(Error::Config(format!("trace needs a verb: summarize (got '{other}')\n{USAGE}")))
        }
    }
}

fn cmd_runtime_smoke(args: &Args) -> Result<()> {
    args.allow(&["artifacts"])?;
    let dir = {
        let a = args.str_or("artifacts", "");
        if a.is_empty() {
            lgd::runtime::default_artifacts_dir()
        } else {
            PathBuf::from(a)
        }
    };
    let mut rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    println!("entries:  {}", rt.manifest().entries.len());

    // Execute linreg_grad_b1_d90 and cross-check against the native model.
    use lgd::model::{LinReg, Model};
    use lgd::runtime::executor::{lit_f32, to_vec_f32};
    let d = 90usize;
    let x: Vec<f32> = (0..d).map(|i| ((i * 37 % 97) as f32 / 97.0) - 0.5).collect();
    let y = 0.25f32;
    let theta: Vec<f32> = (0..d).map(|i| ((i * 17 % 89) as f32 / 89.0) - 0.5).collect();
    let args_lit = [
        lit_f32(&x, &[1, d])?,
        lit_f32(&[y], &[1])?,
        lit_f32(&theta, &[d])?,
        lit_f32(&[1.0], &[1])?,
    ];
    let outs = rt.execute("linreg_grad_b1_d90", &args_lit)?;
    let got = to_vec_f32(&outs[0])?;
    let mut want = vec![0.0f32; d];
    LinReg.grad(&x, y, &theta, &mut want);
    let mut max_err = 0.0f32;
    for i in 0..d {
        max_err = max_err.max((got[i] - want[i]).abs());
    }
    println!("linreg_grad_b1_d90 vs native: max |err| = {max_err:.2e}");
    if max_err > 1e-4 {
        return Err(Error::Runtime(format!("runtime smoke mismatch: {max_err}")));
    }
    println!("runtime-smoke OK");
    Ok(())
}
