//! `bench_gate` — the bench-counter regression gate.
//!
//! Diffs a freshly emitted `BENCH_*.json` perf-trajectory file against the
//! committed baseline and fails (exit 1) when a *work* counter regressed:
//! mults/draw, probes/draw, fused hash invocations/batch and friends are
//! deterministic under fixed seeds, so "more work per draw" is a real
//! regression, not noise. Timing rows and advisory counters (draws/sec,
//! stall/hit counts, anything machine-dependent) are reported but never
//! gate. CI stashes the committed baselines before the bench smoke
//! overwrites them, then runs:
//!
//! ```text
//! bench_gate --fresh BENCH_sampling.json --baseline /tmp/baseline_sampling.json
//! ```

use std::path::Path;
use std::process::exit;

use lgd::benchkit::gate_counters;
use lgd::cli::Args;
use lgd::config::json::Json;
use lgd::core::error::{Error, Result};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(true) => {}
        Ok(false) => exit(1),
        Err(e) => {
            eprintln!("error: {e}");
            exit(2);
        }
    }
}

fn load(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(Path::new(path))
        .map_err(|e| Error::Io(format!("{path}: {e}")))?;
    Json::parse(text.trim())
}

fn run(argv: &[String]) -> Result<bool> {
    let args = Args::parse(argv)?;
    args.allow(&["fresh", "baseline", "tolerance"])?;
    let fresh_path = args.require("fresh")?;
    let base_path = args.require("baseline")?;
    let tol = args.f64_or("tolerance", 0.1)?;
    let fresh = load(&fresh_path)?;
    let baseline = load(&base_path)?;
    let out = gate_counters(&fresh, &baseline, tol);
    println!(
        "bench_gate {fresh_path} vs {base_path}: {} gated, {} advisory, {} skipped",
        out.compared, out.advisory, out.skipped
    );
    for f in &out.failures {
        println!("REGRESSION {f}");
    }
    if out.failures.is_empty() {
        println!("counter gate OK (timing rows advisory)");
    }
    Ok(out.failures.is_empty())
}
