//! Least-squares regression: `f(x, θ) = (θ·x − y)²`.
//!
//! Gradient `∇f = 2(θ·x − y)·x`, with norm `2|θ·x − y|·‖x‖` — the absolute-
//! inner-product form of eq. 4 that LGD's hash space targets.

use crate::core::matrix::{dot_f64, norm2, scale_into};
use crate::model::Model;

/// Least-squares model (no regularisation — matching the paper's "plain"
/// comparisons; regularisation lives in the optimizer if needed).
#[derive(Debug, Clone, Copy, Default)]
pub struct LinReg;

impl Model for LinReg {
    #[inline]
    fn loss(&self, x: &[f32], y: f32, theta: &[f32]) -> f64 {
        let r = dot_f64(x, theta) - y as f64;
        r * r
    }

    #[inline]
    fn grad(&self, x: &[f32], y: f32, theta: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), out.len());
        let r = (dot_f64(x, theta) - y as f64) as f32;
        scale_into(2.0 * r, x, out);
    }

    #[inline]
    fn grad_norm(&self, x: &[f32], y: f32, theta: &[f32]) -> f64 {
        2.0 * (dot_f64(x, theta) - y as f64).abs() * norm2(x)
    }

    fn name(&self) -> &'static str {
        "linreg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::normalize;
    use crate::core::rng::{Pcg64, Rng};
    use crate::data::dataset::{Dataset, Task};
    use crate::core::matrix::Matrix;

    #[test]
    fn grad_matches_finite_difference() {
        let m = LinReg;
        let x = [0.3f32, -0.7, 0.2];
        let y = 0.5f32;
        let theta = [0.1f32, 0.4, -0.2];
        let mut g = [0.0f32; 3];
        m.grad(&x, y, &theta, &mut g);
        let eps = 1e-3f32;
        for i in 0..3 {
            let mut tp = theta;
            tp[i] += eps;
            let mut tm = theta;
            tm[i] -= eps;
            let fd = (m.loss(&x, y, &tp) - m.loss(&x, y, &tm)) / (2.0 * eps as f64);
            assert!((fd - g[i] as f64).abs() < 1e-3, "coord {i}: fd {fd} vs {}", g[i]);
        }
    }

    #[test]
    fn grad_norm_matches_explicit_gradient() {
        let m = LinReg;
        let mut rng = Pcg64::seeded(3);
        for _ in 0..50 {
            let x: Vec<f32> = (0..6).map(|_| rng.gaussian() as f32).collect();
            let theta: Vec<f32> = (0..6).map(|_| rng.gaussian() as f32).collect();
            let y = rng.gaussian() as f32;
            let mut g = vec![0.0f32; 6];
            m.grad(&x, y, &theta, &mut g);
            let explicit = norm2(&g);
            let closed = m.grad_norm(&x, y, &theta);
            assert!((explicit - closed).abs() < 1e-4, "{explicit} vs {closed}");
        }
    }

    #[test]
    fn full_grad_is_mean_of_pointwise() {
        let m = LinReg;
        let mut x = Matrix::zeros(0, 0);
        let mut rng = Pcg64::seeded(5);
        let mut ys = Vec::new();
        for _ in 0..10 {
            let mut row: Vec<f32> = (0..4).map(|_| rng.gaussian() as f32).collect();
            normalize(&mut row);
            x.push_row(&row).unwrap();
            ys.push(rng.gaussian() as f32);
        }
        let ds = Dataset::new("t", x, ys, Task::Regression).unwrap();
        let theta = [0.2f32, -0.1, 0.3, 0.0];
        let mut full = vec![0.0f32; 4];
        m.full_grad(&ds, &theta, &mut full);
        let mut acc = vec![0.0f64; 4];
        let mut g = vec![0.0f32; 4];
        for i in 0..ds.len() {
            let (xi, yi) = ds.example(i);
            m.grad(xi, yi, &theta, &mut g);
            for j in 0..4 {
                acc[j] += g[j] as f64 / 10.0;
            }
        }
        for j in 0..4 {
            assert!((full[j] as f64 - acc[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_residual_zero_gradient() {
        let m = LinReg;
        let x = [1.0f32, 0.0];
        let theta = [2.0f32, 5.0];
        let y = 2.0f32; // θ·x = 2 = y
        assert_eq!(m.loss(&x, y, &theta), 0.0);
        assert_eq!(m.grad_norm(&x, y, &theta), 0.0);
    }
}
