//! Native-Rust model math: least squares and logistic regression.
//!
//! These are the paper's two linear models (§2.1, §2.3 "LGD for Logistic
//! Regression"). They serve three roles: the training hot path of the pure-
//! Rust backend, the correctness oracle the PJRT artifacts are checked
//! against, and the source of per-example gradient norms for the variance
//! experiments.

pub mod linreg;
pub mod logreg;

use crate::data::dataset::Dataset;

/// A pointwise-differentiable model over (x, y) pairs.
pub trait Model: Send + Sync {
    /// Loss of a single example at `theta`.
    fn loss(&self, x: &[f32], y: f32, theta: &[f32]) -> f64;

    /// Gradient of the single-example loss into `out` (len = dim).
    fn grad(&self, x: &[f32], y: f32, theta: &[f32], out: &mut [f32]);

    /// L2 norm of the single-example gradient — computed *without* forming
    /// the gradient (the closed forms of eq. 4 / eq. 11).
    fn grad_norm(&self, x: &[f32], y: f32, theta: &[f32]) -> f64;

    /// Mean loss over a dataset.
    fn mean_loss(&self, ds: &Dataset, theta: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..ds.len() {
            let (x, y) = ds.example(i);
            acc += self.loss(x, y, theta);
        }
        acc / ds.len().max(1) as f64
    }

    /// Full (average) gradient over a dataset into `out`.
    fn full_grad(&self, ds: &Dataset, theta: &[f32], out: &mut [f32]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        let n = ds.len().max(1) as f32;
        let mut g = vec![0.0f32; theta.len()];
        for i in 0..ds.len() {
            let (x, y) = ds.example(i);
            self.grad(x, y, theta, &mut g);
            crate::core::matrix::axpy(1.0 / n, &g, out);
        }
    }

    /// Model name for logs.
    fn name(&self) -> &'static str;
}

pub use linreg::LinReg;
pub use logreg::LogReg;
