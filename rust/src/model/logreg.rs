//! Logistic regression with ±1 labels:
//! `f(x, θ) = ln(1 + e^{−y·θ·x})`, `∇f = −y·x·σ(−y·θ·x)`,
//! `‖∇f‖ = ‖x‖ / (e^{y·θ·x} + 1)` (paper eq. 11).

use crate::core::matrix::{dot_f64, norm2, scale_into};
use crate::model::Model;

/// Binary logistic regression model.
#[derive(Debug, Clone, Copy, Default)]
pub struct LogReg;

#[inline]
fn log1p_exp(z: f64) -> f64 {
    // ln(1 + e^z), overflow-safe
    if z > 30.0 {
        z
    } else {
        (1.0 + z.exp()).ln()
    }
}

impl Model for LogReg {
    #[inline]
    fn loss(&self, x: &[f32], y: f32, theta: &[f32]) -> f64 {
        debug_assert!(y == 1.0 || y == -1.0, "logreg labels must be ±1");
        let m = y as f64 * dot_f64(x, theta);
        log1p_exp(-m)
    }

    #[inline]
    fn grad(&self, x: &[f32], y: f32, theta: &[f32], out: &mut [f32]) {
        let m = y as f64 * dot_f64(x, theta);
        // σ(−m) = 1/(1+e^m)
        let s = (1.0 / (1.0 + m.exp())) as f32;
        scale_into(-y * s, x, out);
    }

    #[inline]
    fn grad_norm(&self, x: &[f32], y: f32, theta: &[f32]) -> f64 {
        let m = y as f64 * dot_f64(x, theta);
        norm2(x) / (m.exp() + 1.0)
    }

    fn name(&self) -> &'static str {
        "logreg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::{Pcg64, Rng};

    #[test]
    fn grad_matches_finite_difference() {
        let m = LogReg;
        let x = [0.5f32, -0.25, 0.8];
        let theta = [0.2f32, 0.3, -0.6];
        for &y in &[1.0f32, -1.0] {
            let mut g = [0.0f32; 3];
            m.grad(&x, y, &theta, &mut g);
            let eps = 1e-3f32;
            for i in 0..3 {
                let mut tp = theta;
                tp[i] += eps;
                let mut tm = theta;
                tm[i] -= eps;
                let fd = (m.loss(&x, y, &tp) - m.loss(&x, y, &tm)) / (2.0 * eps as f64);
                assert!((fd - g[i] as f64).abs() < 1e-4, "y={y} coord {i}");
            }
        }
    }

    #[test]
    fn grad_norm_matches_eq11() {
        let m = LogReg;
        let mut rng = Pcg64::seeded(7);
        for _ in 0..50 {
            let x: Vec<f32> = (0..5).map(|_| rng.gaussian() as f32).collect();
            let theta: Vec<f32> = (0..5).map(|_| rng.gaussian() as f32).collect();
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            let mut g = vec![0.0f32; 5];
            m.grad(&x, y, &theta, &mut g);
            assert!((norm2(&g) - m.grad_norm(&x, y, &theta)).abs() < 1e-5);
        }
    }

    #[test]
    fn loss_decreases_with_margin() {
        let m = LogReg;
        let x = [1.0f32, 0.0];
        // increasing positive margin ⇒ smaller loss
        let l1 = m.loss(&x, 1.0, &[0.5, 0.0]);
        let l2 = m.loss(&x, 1.0, &[1.5, 0.0]);
        let l3 = m.loss(&x, 1.0, &[3.0, 0.0]);
        assert!(l1 > l2 && l2 > l3);
        // wrong-side prediction costs more than ln 2
        assert!(m.loss(&x, -1.0, &[3.0, 0.0]) > (2.0f64).ln());
    }

    #[test]
    fn overflow_safe_extreme_margins() {
        let m = LogReg;
        let x = [1.0f32];
        let l = m.loss(&x, -1.0, &[100.0]);
        assert!(l.is_finite() && (l - 100.0).abs() < 1e-6);
        let g = m.grad_norm(&x, 1.0, &[100.0]);
        assert!(g.is_finite() && g < 1e-20);
    }
}
