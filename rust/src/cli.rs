//! Hand-rolled CLI argument parser (clap unavailable offline).
//!
//! Grammar: `lgd <subcommand> [--flag value]... [--switch]...`.
//! Unknown flags are errors; every subcommand documents its flags in
//! `main.rs`'s usage text.

use std::collections::BTreeMap;

use crate::core::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (subcommand).
    pub command: String,
    /// `--key value` pairs.
    flags: BTreeMap<String, String>,
    /// Bare `--switch` flags.
    switches: Vec<String>,
}

impl Args {
    /// Parse from raw argv (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0usize;
        if i < argv.len() && !argv[i].starts_with("--") {
            a.command = argv[i].clone();
            i += 1;
        }
        while i < argv.len() {
            let tok = &argv[i];
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --flag, got '{tok}'")))?;
            if key.is_empty() {
                return Err(Error::Config("empty flag".into()));
            }
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                a.flags.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                a.switches.push(key.to_string());
                i += 1;
            }
        }
        Ok(a)
    }

    /// String flag with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<String> {
        self.flags
            .get(key)
            .cloned()
            .ok_or_else(|| Error::Config(format!("missing required --{key}")))
    }

    /// Float flag with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: bad float '{v}'"))),
        }
    }

    /// Integer flag with default.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: bad integer '{v}'"))),
        }
    }

    /// Unsigned-size flag with default (e.g. `--shards 4`).
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(key, default as u64)? as usize)
    }

    /// Boolean flag with default (e.g. `--sealed false`); accepts
    /// true/false/1/0/on/off.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.flags.get(key).map(|v| v.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("on") => Ok(true),
            Some("false") | Some("0") | Some("off") => Ok(false),
            Some(v) => Err(Error::Config(format!("--{key}: bad bool '{v}'"))),
        }
    }

    /// Is a bare switch present?
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// Validate that only the listed flags/switches were used.
    pub fn allow(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(Error::Config(format!("unknown flag --{k}")));
            }
        }
        for k in &self.switches {
            if !allowed.contains(&k.as_str()) {
                return Err(Error::Config(format!("unknown switch --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(&v(&["train", "--config", "x.toml", "--quick", "--seed", "7"]))
            .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.str_or("config", ""), "x.toml");
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert_eq!(a.usize_or("seed", 0).unwrap(), 7);
        assert_eq!(a.usize_or("shards", 1).unwrap(), 1);
        assert!(a.has("quick"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn typed_errors() {
        let a = Args::parse(&v(&["x", "--n", "abc"])).unwrap();
        assert!(a.u64_or("n", 0).is_err());
        assert!(a.f64_or("n", 0.0).is_err());
        assert!(a.bool_or("n", true).is_err());
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn bool_flags_parse() {
        let a = Args::parse(&v(&["x", "--sealed", "false", "--other", "1"])).unwrap();
        assert!(!a.bool_or("sealed", true).unwrap());
        assert!(a.bool_or("other", false).unwrap());
        assert!(a.bool_or("absent", true).unwrap());
    }

    #[test]
    fn allow_rejects_unknown() {
        let a = Args::parse(&v(&["x", "--good", "1", "--bad", "2"])).unwrap();
        assert!(a.allow(&["good"]).is_err());
        assert!(a.allow(&["good", "bad"]).is_ok());
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(&v(&["--help"])).unwrap();
        assert_eq!(a.command, "");
        assert!(a.has("help"));
    }

    #[test]
    fn rejects_positional_after_flags() {
        assert!(Args::parse(&v(&["cmd", "stray"])).is_err());
    }
}
