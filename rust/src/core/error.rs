//! Error types shared across the LGD library.
//!
//! Most library routines return [`Result<T>`], aliased to this crate's
//! [`Error`]. The runtime layer wraps `xla::Error` values; everything else is
//! constructed directly.

use std::fmt;

/// Crate-wide error type.
#[derive(Debug)]
pub enum Error {
    /// Shape/dimension mismatch in linear-algebra or dataset plumbing.
    Shape(String),
    /// Configuration parse or validation failure.
    Config(String),
    /// Dataset loading / generation failure.
    Data(String),
    /// LSH table or sampler invariant violation.
    Lsh(String),
    /// PJRT runtime failure (compile, execute, artifact load).
    Runtime(String),
    /// I/O failure, annotated with the path when available.
    Io(String),
    /// Pipeline/coordination failure (channel closed, worker panicked...).
    Pipeline(String),
    /// Snapshot-store failure: unreadable, truncated or corrupted persisted
    /// state (bad magic/version, CRC mismatch, inconsistent sections). A
    /// damaged snapshot must always surface as this — never UB and never a
    /// silently wrong index.
    Store(String),
    /// Training-health failure: a sentinel tripped (non-finite gradient or
    /// θ, NaN/spiking loss) and recovery was impossible — no healthy
    /// snapshot to roll back to, or `health.max_rollbacks` exhausted. A
    /// diverged run must always surface as this, never as silently
    /// poisoned parameters.
    Health(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Lsh(m) => write!(f, "lsh error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Pipeline(m) => write!(f, "pipeline error: {m}"),
            Error::Store(m) => write!(f, "store error: {m}"),
            Error::Health(m) => write!(f, "health error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Helper: build a `Shape` error from a format-style message.
#[macro_export]
macro_rules! shape_err {
    ($($arg:tt)*) => { $crate::core::error::Error::Shape(format!($($arg)*)) };
}

/// Helper: bail out with a `Config` error.
#[macro_export]
macro_rules! config_err {
    ($($arg:tt)*) => { $crate::core::error::Error::Config(format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        let e = Error::Shape("3x4 vs 5x4".into());
        assert_eq!(e.to_string(), "shape error: 3x4 vs 5x4");
        let e = Error::Runtime("compile failed".into());
        assert!(e.to_string().contains("runtime"));
        let e = Error::Store("crc mismatch in section 3".into());
        assert_eq!(e.to_string(), "store error: crc mismatch in section 3");
        let e = Error::Health("3 rollbacks exhausted".into());
        assert_eq!(e.to_string(), "health error: 3 rollbacks exhausted");
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn macros_build_errors() {
        let e = shape_err!("{} vs {}", 3, 4);
        assert!(matches!(e, Error::Shape(_)));
        let e = config_err!("bad key {}", "k");
        assert!(matches!(e, Error::Config(_)));
    }
}
