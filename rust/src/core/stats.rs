//! Streaming and batch statistics used by experiments and the metrics layer.

/// Online mean/variance accumulator (Welford). Numerically stable for the
/// long, skewed series the variance experiments produce.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 for empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance (0 for n < 2).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Standard deviation (population).
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n as f64;
        self.m2 += other.m2 + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
    }
}

/// Batch mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Batch population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Quantile by linear interpolation over the sorted copy. `q` in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = pos - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

/// Median convenience.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Pearson correlation of two equal-length series (0 if degenerate). Used to
/// verify monotonic-sampling claims (gradient norm vs collision probability).
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.len() < 2 {
        return 0.0;
    }
    let (ma, mb) = (mean(a), mean(b));
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..a.len() {
        let (da, db) = (a[i] - ma, b[i] - mb);
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

/// Spearman rank correlation — the right check for *monotonicity* (the paper
/// argues LGD samples from any monotone transform of the optimal weights).
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0, -3.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), xs.len() as u64);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn quantiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b: Vec<f64> = a.iter().map(|x: &f64| x.exp()).collect(); // monotone, nonlinear
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0, 1.0, 2.0, 3.0];
        let b = [1.0, 1.0, 2.0, 3.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }
}
