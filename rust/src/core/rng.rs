//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is not available in the offline build environment, so the
//! library carries its own small, well-tested PRNG substrate:
//!
//! * [`SplitMix64`] — fast 64-bit state mixer, used to seed other generators
//!   and to derive independent streams from a single experiment seed.
//! * [`Pcg64`] — PCG-XSH-RR 64/32-based generator with 128-bit state; the
//!   workhorse generator for all sampling in the library.
//!
//! Distribution helpers (uniform, Gaussian via Box–Muller, exponential,
//! Pareto, Rademacher, Fisher–Yates shuffle) live on the [`Rng`] trait so the
//! whole library is generic over the generator.

/// Minimal RNG interface implemented by the generators in this module.
pub trait Rng {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Next 32 random bits.
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire-style rejection to avoid modulo
    /// bias. `n` must be > 0.
    #[inline]
    fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0, "below(0) is undefined");
        // Widening-multiply rejection sampling (Lemire 2019).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal deviate (Box–Muller, no caching — branch-free and
    /// stateless; costs two uniforms per call).
    #[inline]
    fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential deviate with rate `lambda`.
    #[inline]
    fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Pareto (Type I) deviate with minimum `xm` and shape `alpha`, i.e.
    /// `P(X > x) = (xm / x)^alpha` for `x > xm`. This is the power-law
    /// distribution the paper's Lemma 1 analysis assumes for collision
    /// probabilities / gradient norms.
    #[inline]
    fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        debug_assert!(xm > 0.0 && alpha > 0.0);
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return xm / u.powf(1.0 / alpha);
            }
        }
    }

    /// Rademacher deviate: ±1 with probability 1/2 each.
    #[inline]
    fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm). Returned
    /// order is unspecified. Panics if k > n.
    fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

/// SplitMix64: tiny, high-quality 64-bit mixer (Steele et al. 2014). Used to
/// expand one user seed into independent generator seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a raw seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64 (pcg64): 128-bit LCG state with an xorshift-rotate
/// output function. Fast, statistically strong, tiny state.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    /// Seed the generator; `seed` selects the stream start, `stream` the
    /// increment (sequence). Two generators with different streams are
    /// independent for practical purposes.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ 0xDEADBEEFCAFEF00D);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut sm2 = SplitMix64::new(stream.wrapping_add(0x1234_5678_9ABC_DEF0));
        let i0 = sm2.next_u64() as u128;
        let i1 = sm2.next_u64() as u128;
        let mut g = Pcg64 {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1, // must be odd
        };
        // Warm up past the seed correlation window.
        g.next_u64();
        g.next_u64();
        g
    }

    /// Seed with stream 0 — the common case.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child generator with an independent stream. Used to hand
    /// each pipeline worker / experiment arm its own reproducible stream.
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }

    /// Raw `(state, inc)` pair — the persistence-layer view of the
    /// generator. Together with [`Self::from_raw_state`] this round-trips
    /// the generator at its exact position, so a restored stream continues
    /// bit-for-bit where the saved one stopped.
    pub fn raw_state(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Reconstruct a generator from [`Self::raw_state`]. No warm-up is
    /// applied (the raw state is already past it); `inc` is forced odd — the
    /// LCG invariant — so even a corrupted pair yields a working generator.
    pub fn from_raw_state(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc: inc | 1 }
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = SplitMix64::new(8);
        assert_ne!(SplitMix64::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg64::new(1, 0);
        let mut b = Pcg64::new(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams should be independent, {same} collisions");
    }

    #[test]
    fn uniform_bounds() {
        let mut g = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = g.next_f32();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut g = Pcg64::seeded(11);
        let n = 7u64;
        let mut counts = [0usize; 7];
        let trials = 70_000;
        for _ in 0..trials {
            counts[g.below(n) as usize] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!(
                (c as f64 - expect).abs() < expect * 0.1,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut g = Pcg64::seeded(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = g.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "gaussian mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "gaussian var {var}");
    }

    #[test]
    fn pareto_tail() {
        let mut g = Pcg64::seeded(9);
        let (xm, alpha) = (1.0, 2.0);
        let n = 100_000;
        let above2 = (0..n).filter(|_| g.pareto(xm, alpha) > 2.0).count();
        // P(X > 2) = (1/2)^2 = 0.25
        let frac = above2 as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "pareto tail {frac}");
        // all samples >= xm
        for _ in 0..1000 {
            assert!(g.pareto(xm, alpha) >= xm);
        }
    }

    #[test]
    fn exponential_mean() {
        let mut g = Pcg64::seeded(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| g.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "exp mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Pcg64::seeded(17);
        let mut xs: Vec<usize> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle left input fixed");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut g = Pcg64::seeded(19);
        for _ in 0..100 {
            let k = g.index(50);
            let s = g.sample_indices(50, k);
            assert_eq!(s.len(), k);
            let mut dedup = s.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), k, "indices not distinct");
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn rademacher_balance() {
        let mut g = Pcg64::seeded(23);
        let n = 100_000;
        let pos = (0..n).filter(|_| g.rademacher() > 0.0).count();
        let frac = pos as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01);
    }

    #[test]
    fn raw_state_roundtrip_continues_stream() {
        let mut g = Pcg64::new(99, 7);
        for _ in 0..17 {
            g.next_u64();
        }
        let (state, inc) = g.raw_state();
        let mut restored = Pcg64::from_raw_state(state, inc);
        for i in 0..64 {
            assert_eq!(g.next_u64(), restored.next_u64(), "draw {i} diverged after restore");
        }
    }

    #[test]
    fn fork_gives_distinct_streams() {
        let mut root = Pcg64::seeded(42);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
