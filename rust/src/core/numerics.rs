//! The aligned-block numerics layer: ONE kernel suite under `Matrix`, the
//! SRP hashers and the model gradient kernels.
//!
//! Storage: [`AlignedRows`] keeps every row padded to a multiple of
//! [`LANES`] f32 lanes inside `#[repr(align(64))]` [`AlignedBlock`]s — one
//! cache line per block — with a **guaranteed-zero tail** (every padded
//! position beyond the logical width holds exactly `+0.0`). Callers that
//! want the logical row use `row(i)`; kernels that want the full padded
//! stride use `row_block(i)`.
//!
//! Kernels: lane-width chunked loops the compiler auto-vectorizes, plus an
//! optional `std::arch` AVX2 path behind runtime
//! `is_x86_feature_detected!` with a portable fallback — zero external
//! dependencies. Dispatch is a *pure perf A/B*: the AVX2 paths use no FMA
//! and reduce through the same fixed pairwise tree as the portable paths,
//! so `auto` and `scalar` ([`KernelMode`]) produce bitwise-identical
//! results on every input.
//!
//! Determinism contract (see `docs/numerics.md`):
//! * `dot`, `dot_f64`, `norm2`, `normalize`, `dot_norm`, `cosine` are
//!   **sequential-order f64** accumulations — never re-associated, never
//!   vectorized. Hash code-sign decisions (`s >= 0.0`) and every bitwise
//!   parity gate (fused-vs-per-table, sealed-vs-Vec, sync-vs-async,
//!   snapshot resume) ride on these. The zero tail makes them safe over
//!   padded blocks too: a `+0.0` product added to a non-negative or
//!   sign-preserved accumulator does not change its bits.
//! * `dot_fast` is the re-associated throughput kernel ([`LANES`] virtual
//!   lanes, fixed tree reduction). Its only consumers are collision
//!   probabilities, which feed statistical gates (TV/chi-square) and
//!   parity suites where both sides share this kernel.
//! * `axpy`, `scale`, `scale_into` are elementwise — vectorizing them is
//!   bitwise-safe, so they take the AVX2 path under `auto`.

use std::sync::atomic::{AtomicU8, Ordering};

/// f32 lanes per aligned block (64 bytes = one cache line).
pub const LANES: usize = 16;

/// One cache-line-aligned block of [`LANES`] f32 values.
///
/// `#[repr(C, align(64))]` over `[f32; LANES]` has size 64 with no padding,
/// so a contiguous `[AlignedBlock]` reinterprets soundly as a flat `[f32]`.
#[derive(Debug, Clone, Copy, PartialEq)]
#[repr(C, align(64))]
pub struct AlignedBlock(pub [f32; LANES]);

impl AlignedBlock {
    /// The all-zero block (every lane `+0.0`).
    pub const ZERO: AlignedBlock = AlignedBlock([0.0; LANES]);
}

/// Blocks needed to hold `cols` logical values (0 for an empty width).
#[inline]
pub fn blocks_for(cols: usize) -> usize {
    cols.div_ceil(LANES)
}

#[inline]
fn flat(blocks: &[AlignedBlock]) -> &[f32] {
    // SAFETY: AlignedBlock is #[repr(C, align(64))] over [f32; LANES],
    // size 64 == LANES * size_of::<f32>() with no padding bytes, and f32's
    // alignment divides the block's, so the contiguous block storage is
    // exactly blocks.len() * LANES valid, initialized f32 values.
    unsafe { std::slice::from_raw_parts(blocks.as_ptr() as *const f32, blocks.len() * LANES) }
}

#[inline]
fn flat_mut(blocks: &mut [AlignedBlock]) -> &mut [f32] {
    // SAFETY: as `flat`, plus exclusive access through the &mut borrow.
    unsafe {
        std::slice::from_raw_parts_mut(blocks.as_mut_ptr() as *mut f32, blocks.len() * LANES)
    }
}

/// Row-major f32 storage with every row padded to a [`LANES`] multiple of
/// cache-line-aligned blocks and a guaranteed-zero tail.
///
/// This is the storage under [`crate::core::matrix::Matrix`]; the zero-tail
/// invariant is what lets the sequential-f64 kernels run over full padded
/// blocks without changing a single output bit, and what makes padded
/// equality coincide with logical equality (`PartialEq` derives).
#[derive(Debug, Clone, PartialEq)]
pub struct AlignedRows {
    blocks: Vec<AlignedBlock>,
    rows: usize,
    cols: usize,
    /// Blocks per row (0 iff `cols == 0`).
    stride: usize,
}

impl AlignedRows {
    /// Empty storage of logical width `cols` (0 rows).
    pub fn new(cols: usize) -> AlignedRows {
        AlignedRows { blocks: Vec::new(), rows: 0, cols, stride: blocks_for(cols) }
    }

    /// `rows x cols` of zeros.
    pub fn zeros(rows: usize, cols: usize) -> AlignedRows {
        let stride = blocks_for(cols);
        AlignedRows { blocks: vec![AlignedBlock::ZERO; rows * stride], rows, cols, stride }
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Padded row length in f32 lanes (`stride * LANES`).
    #[inline]
    pub fn padded_cols(&self) -> usize {
        self.stride * LANES
    }

    /// Logical row `i` (exactly `cols` values).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let start = i * self.stride * LANES;
        &flat(&self.blocks)[start..start + self.cols]
    }

    /// Mutable logical row `i` — the padding tail stays untouched, so the
    /// zero-tail invariant survives any write through this.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let start = i * self.stride * LANES;
        let cols = self.cols;
        &mut flat_mut(&mut self.blocks)[start..start + cols]
    }

    /// Full padded row `i` (`padded_cols()` values, tail guaranteed zero) —
    /// what the kernels want.
    #[inline]
    pub fn row_block(&self, i: usize) -> &[f32] {
        let w = self.stride * LANES;
        let start = i * w;
        &flat(&self.blocks)[start..start + w]
    }

    /// Append a row. On the first push into width-0 empty storage the
    /// logical width is adopted from the row (and persists even if the
    /// storage empties again). The caller validates width agreement.
    pub fn push_row(&mut self, row: &[f32]) {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
            self.stride = blocks_for(row.len());
        }
        debug_assert_eq!(row.len(), self.cols, "push_row width mismatch");
        let start = self.blocks.len();
        self.blocks.resize(start + self.stride, AlignedBlock::ZERO);
        flat_mut(&mut self.blocks)[start * LANES..start * LANES + row.len()]
            .copy_from_slice(row);
        self.rows += 1;
    }

    /// Remove row `i` by moving the last row into its place (O(stride)).
    /// Whole padded blocks move, so the zero tail is preserved verbatim.
    pub fn swap_remove_row(&mut self, i: usize) {
        debug_assert!(i < self.rows, "swap_remove_row out of range");
        let last = self.rows - 1;
        if i != last {
            let (head, tail) = self.blocks.split_at_mut(last * self.stride);
            head[i * self.stride..(i + 1) * self.stride]
                .copy_from_slice(&tail[..self.stride]);
        }
        self.blocks.truncate(last * self.stride);
        self.rows = last;
    }

    /// True when every padded position beyond the logical width holds
    /// exactly `+0.0` (bit pattern zero) — the invariant every kernel and
    /// the derived `PartialEq` rely on.
    pub fn zero_tail_ok(&self) -> bool {
        let w = self.stride * LANES;
        (0..self.rows).all(|i| {
            flat(&self.blocks)[i * w + self.cols..(i + 1) * w]
                .iter()
                .all(|v| v.to_bits() == 0)
        })
    }
}

// ---------------------------------------------------------------------------
// Kernel-mode dispatch
// ---------------------------------------------------------------------------

/// Which kernel path the re-associable/elementwise kernels take.
///
/// `Auto` uses the AVX2 path when the CPU has it; `Scalar` forces the
/// portable lane-chunked loops. The two are bitwise identical by
/// construction (no FMA, shared tree reduction), so the knob is a pure
/// perf A/B — `lsh.kernel` / `lgd train --kernel` set it process-wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Runtime-detected best path (default).
    #[default]
    Auto,
    /// Portable loops only.
    Scalar,
}

impl KernelMode {
    /// Parse the config/CLI spelling (`auto` | `scalar`).
    pub fn from_name(s: &str) -> Option<KernelMode> {
        match s {
            "auto" => Some(KernelMode::Auto),
            "scalar" => Some(KernelMode::Scalar),
            _ => None,
        }
    }

    /// The config/CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            KernelMode::Auto => "auto",
            KernelMode::Scalar => "scalar",
        }
    }
}

static KERNEL_MODE: AtomicU8 = AtomicU8::new(0);

/// Set the process-wide kernel mode (the trainer applies `lsh.kernel`).
pub fn set_kernel_mode(mode: KernelMode) {
    KERNEL_MODE.store(mode as u8, Ordering::Relaxed);
}

/// Current process-wide kernel mode.
pub fn kernel_mode() -> KernelMode {
    match KERNEL_MODE.load(Ordering::Relaxed) {
        1 => KernelMode::Scalar,
        _ => KernelMode::Auto,
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    // 0 = unknown, 1 = yes, 2 = no — probed once, then a relaxed load.
    static AVX2: AtomicU8 = AtomicU8::new(0);
    match AVX2.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2");
            AVX2.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
            yes
        }
    }
}

#[inline]
fn use_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        kernel_mode() == KernelMode::Auto && avx2_available()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// True when the `auto` path currently dispatches to `std::arch` SIMD —
/// reported by the benches so an A/B row is interpretable.
pub fn simd_active() -> bool {
    use_avx2()
}

// ---------------------------------------------------------------------------
// Sequential-order f64 kernels (never re-associated — parity-gate safe)
// ---------------------------------------------------------------------------

/// Dot product with a sequential f64 accumulator, returned as f32.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_f64(a, b) as f32
}

/// Dot product with a sequential f64 accumulator — the code-sign kernel.
/// Element order is the contract: hash bits test `dot_f64(..) >= 0.0`.
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let mut acc = 0.0f64;
    for i in 0..n {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc
}

/// L2 norm with a sequential f64 accumulator.
#[inline]
pub fn norm2(v: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &x in v {
        let xf = x as f64;
        acc += xf * xf;
    }
    acc.sqrt()
}

/// Fused single-pass dot + both norms: `(a·b, ‖a‖, ‖b‖)`. Three independent
/// sequential f64 accumulators, so each output is bitwise identical to the
/// separate `dot_f64`/`norm2` calls it replaces.
#[inline]
pub fn dot_norm(a: &[f32], b: &[f32]) -> (f64, f64, f64) {
    let n = a.len().min(b.len());
    let (mut d, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..n {
        let x = a[i] as f64;
        let y = b[i] as f64;
        d += x * y;
        na += x * x;
        nb += y * y;
    }
    (d, na.sqrt(), nb.sqrt())
}

/// Normalize `v` to unit L2 norm in place; returns the original norm.
/// Zero vectors are left untouched.
pub fn normalize(v: &mut [f32]) -> f64 {
    let n = norm2(v);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        scale(inv, v);
    }
    n
}

/// Cosine similarity in [-1, 1]; 0 when either vector has zero norm.
/// One fused pass (`dot_norm`) — bitwise identical to the historical
/// three-pass form.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (d, na, nb) = dot_norm(a, b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (d / (na * nb)).clamp(-1.0, 1.0)
}

/// SimHash angular similarity `1 − θ/π` (paper eq. 14).
pub fn angular_similarity(a: &[f32], b: &[f32]) -> f64 {
    1.0 - cosine(a, b).acos() / std::f64::consts::PI
}

// ---------------------------------------------------------------------------
// Collision-probability helpers (the ONE copy of the clamp logic)
// ---------------------------------------------------------------------------

/// Floor/ceiling for collision probabilities: Algorithm-1 weights divide by
/// the probability, so it must stay inside `(0, 1)` strictly.
pub const PROB_FLOOR: f64 = 1e-9;

/// Clamp a collision probability into `[PROB_FLOOR, 1 − PROB_FLOOR]`.
#[inline]
pub fn clamp_prob(p: f64) -> f64 {
    p.clamp(PROB_FLOOR, 1.0 - PROB_FLOOR)
}

/// Cosine from a precomputed dot product and norms, clamped into [-1, 1].
/// The caller guards zero norms (families differ on the convention there).
#[inline]
pub fn normed_cosine(dot: f64, na: f64, nb: f64) -> f64 {
    (dot / (na * nb)).clamp(-1.0, 1.0)
}

/// SimHash collision law `1 − arccos(cos)/π`, clamped by [`clamp_prob`].
#[inline]
pub fn angular_cp(cos: f64) -> f64 {
    clamp_prob(1.0 - cos.acos() / std::f64::consts::PI)
}

/// Quadratic-SRP collision law: the implicit feature map squares the
/// cosine, then the angular law applies. `clamp` before `acos` keeps the
/// argument in domain when `|cos|` exceeds 1 from rounding.
#[inline]
pub fn quadratic_angular_cp(cos: f64) -> f64 {
    angular_cp((cos * cos).clamp(-1.0, 1.0))
}

// ---------------------------------------------------------------------------
// Re-associated throughput kernel: dot_fast
// ---------------------------------------------------------------------------

/// Fixed pairwise tree reduction over the [`LANES`] virtual-SIMD lanes.
/// Shared by the portable and AVX2 paths — the reason dispatch is bitwise
/// invisible.
#[inline]
fn tree_reduce(l: &[f32; LANES]) -> f32 {
    let q0 = (l[0] + l[1]) + (l[2] + l[3]);
    let q1 = (l[4] + l[5]) + (l[6] + l[7]);
    let q2 = (l[8] + l[9]) + (l[10] + l[11]);
    let q3 = (l[12] + l[13]) + (l[14] + l[15]);
    (q0 + q1) + (q2 + q3)
}

#[inline]
fn dot_fast_portable(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let mut lanes = [0.0f32; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for (j, lane) in lanes.iter_mut().enumerate() {
            *lane += a[base + j] * b[base + j];
        }
    }
    for (j, i) in (chunks * LANES..n).enumerate() {
        lanes[j] += a[i] * b[i];
    }
    tree_reduce(&lanes)
}

/// Throughput f32 dot product: [`LANES`] virtual lanes, fixed tree
/// reduction. Re-associates relative to `dot_f64` — consumers are the
/// collision-probability paths, whose gates are statistical. The AVX2 and
/// portable paths are bitwise identical (no FMA, same per-lane order, same
/// reduction), so [`KernelMode`] never changes a result.
#[inline]
pub fn dot_fast(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 presence was runtime-verified by `use_avx2`.
        return unsafe { dot_fast_avx2(a, b) };
    }
    dot_fast_portable(a, b)
}

// ---------------------------------------------------------------------------
// Elementwise kernels (vectorization is bitwise-safe)
// ---------------------------------------------------------------------------

/// `y += alpha * x` elementwise.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 presence was runtime-verified by `use_avx2`.
        unsafe { axpy_avx2(alpha, x, y) };
        return;
    }
    let n = x.len().min(y.len());
    for i in 0..n {
        y[i] += alpha * x[i];
    }
}

/// `v *= alpha` in place.
#[inline]
pub fn scale(alpha: f32, v: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 presence was runtime-verified by `use_avx2`.
        unsafe { scale_avx2(alpha, v) };
        return;
    }
    for x in v.iter_mut() {
        *x *= alpha;
    }
}

/// `out = alpha * x` elementwise — the model gradient kernel
/// (`∇f = c·x` for both linear models).
#[inline]
pub fn scale_into(alpha: f32, x: &[f32], out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 presence was runtime-verified by `use_avx2`.
        unsafe { scale_into_avx2(alpha, x, out) };
        return;
    }
    let n = x.len().min(out.len());
    for i in 0..n {
        out[i] = alpha * x[i];
    }
}

/// `a − b` into `out`.
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == b.len() && b.len() == out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// True iff every value is finite (no NaN, no ±Inf) — the sentinel kernel
/// `coordinator::health` runs over batch gradients and θ each step. One
/// pass, early exit at the first non-finite chunk. The AVX2 path classifies
/// by exponent bits, which is exactly `f32::is_finite` per element, so
/// dispatch cannot change the answer; and the guaranteed-zero tail of
/// padded blocks is finite, so padded and logical slices always agree.
#[inline]
pub fn all_finite(xs: &[f32]) -> bool {
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 presence was runtime-verified by `use_avx2`.
        return unsafe { all_finite_avx2(xs) };
    }
    xs.iter().all(|x| x.is_finite())
}

// ---------------------------------------------------------------------------
// AVX2 paths — no FMA, scalar-identical rounding per element
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_fast_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    for c in 0..chunks {
        let base = c * LANES;
        let x0 = _mm256_loadu_ps(ap.add(base));
        let y0 = _mm256_loadu_ps(bp.add(base));
        let x1 = _mm256_loadu_ps(ap.add(base + 8));
        let y1 = _mm256_loadu_ps(bp.add(base + 8));
        // mul then add (no FMA): two roundings per lane, exactly like the
        // portable `lanes[j] += a*b` — dispatch stays bitwise invisible.
        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(x0, y0));
        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(x1, y1));
    }
    let mut lanes = [0.0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc0);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc1);
    for (j, i) in (chunks * LANES..n).enumerate() {
        lanes[j] += a[i] * b[i];
    }
    tree_reduce(&lanes)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len().min(y.len());
    let va = _mm256_set1_ps(alpha);
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_mul_ps(va, xv)));
        i += 8;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_avx2(alpha: f32, v: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = v.len();
    let va = _mm256_set1_ps(alpha);
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(v.as_ptr().add(i));
        _mm256_storeu_ps(v.as_mut_ptr().add(i), _mm256_mul_ps(xv, va));
        i += 8;
    }
    while i < n {
        v[i] *= alpha;
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn all_finite_avx2(xs: &[f32]) -> bool {
    use std::arch::x86_64::*;
    let n = xs.len();
    // An f32 is finite iff its exponent bits are not all ones — the same
    // classification `f32::is_finite` performs, lifted to 8 lanes.
    let expo = _mm256_set1_epi32(0x7f80_0000u32 as i32);
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_si256(xs.as_ptr().add(i) as *const __m256i);
        let bad = _mm256_cmpeq_epi32(_mm256_and_si256(v, expo), expo);
        if _mm256_movemask_epi8(bad) != 0 {
            return false;
        }
        i += 8;
    }
    xs[i..].iter().all(|x| x.is_finite())
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_into_avx2(alpha: f32, x: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len().min(out.len());
    let va = _mm256_set1_ps(alpha);
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(va, xv));
        i += 8;
    }
    while i < n {
        out[i] = alpha * x[i];
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ref_vec(seed: u64, n: usize) -> Vec<f32> {
        // cheap deterministic pseudo-data without pulling in core::rng
        let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect()
    }

    #[test]
    fn aligned_block_is_one_cache_line() {
        assert_eq!(std::mem::size_of::<AlignedBlock>(), 64);
        assert_eq!(std::mem::align_of::<AlignedBlock>(), 64);
        assert_eq!(LANES * std::mem::size_of::<f32>(), 64);
    }

    #[test]
    fn aligned_rows_zero_tail_invariant() {
        // ragged widths around the lane boundary, through every mutation
        for cols in [1usize, 7, 15, 16, 17, 31, 33, 91] {
            let mut m = AlignedRows::new(0);
            assert_eq!(m.cols(), 0);
            for r in 0..9 {
                m.push_row(&ref_vec(r as u64 + 1, cols));
                assert!(m.zero_tail_ok(), "cols={cols} after push {r}");
            }
            assert_eq!(m.cols(), cols);
            assert_eq!(m.padded_cols() % LANES, 0);
            // writes through row_mut cannot touch the tail
            m.row_mut(3).iter_mut().for_each(|v| *v = -1.25);
            assert!(m.zero_tail_ok(), "cols={cols} after row_mut");
            // swap-remove moves whole padded blocks
            m.swap_remove_row(0);
            m.swap_remove_row(m.rows() - 1);
            m.swap_remove_row(2);
            assert!(m.zero_tail_ok(), "cols={cols} after swap_remove");
            assert_eq!(m.rows(), 6);
            // width persists through emptying
            while m.rows() > 0 {
                m.swap_remove_row(0);
            }
            assert_eq!(m.cols(), cols, "width persists when emptied");
            m.push_row(&ref_vec(99, cols));
            assert!(m.zero_tail_ok());
        }
    }

    #[test]
    fn rows_roundtrip_logical_values() {
        let a = ref_vec(1, 21);
        let b = ref_vec(2, 21);
        let mut m = AlignedRows::new(21);
        m.push_row(&a);
        m.push_row(&b);
        assert_eq!(m.row(0), &a[..]);
        assert_eq!(m.row(1), &b[..]);
        assert_eq!(&m.row_block(0)[..21], &a[..]);
        assert!(m.row_block(0)[21..].iter().all(|v| v.to_bits() == 0));
    }

    #[test]
    fn sequential_kernels_are_padding_invariant() {
        // the zero tail must not change a single bit of the sequential
        // f64 kernels — this is what lets callers hand kernels either the
        // logical row or the padded block
        for cols in [5usize, 16, 23, 91] {
            let a = ref_vec(3, cols);
            let b = ref_vec(4, cols);
            let mut m = AlignedRows::new(cols);
            m.push_row(&a);
            m.push_row(&b);
            let (pa, pb) = (m.row_block(0), m.row_block(1));
            assert_eq!(dot_f64(&a, &b).to_bits(), dot_f64(pa, pb).to_bits());
            assert_eq!(norm2(&a).to_bits(), norm2(pa).to_bits());
            let (d, na, nb) = dot_norm(&a, &b);
            let (dp, nap, nbp) = dot_norm(pa, pb);
            assert_eq!(d.to_bits(), dp.to_bits());
            assert_eq!(na.to_bits(), nap.to_bits());
            assert_eq!(nb.to_bits(), nbp.to_bits());
        }
    }

    #[test]
    fn dot_norm_matches_separate_kernels_bitwise() {
        let a = ref_vec(5, 137);
        let b = ref_vec(6, 137);
        let (d, na, nb) = dot_norm(&a, &b);
        assert_eq!(d.to_bits(), dot_f64(&a, &b).to_bits());
        assert_eq!(na.to_bits(), norm2(&a).to_bits());
        assert_eq!(nb.to_bits(), norm2(&b).to_bits());
    }

    #[test]
    fn dot_fast_matches_reference_within_tolerance() {
        for n in [0usize, 1, 15, 16, 17, 64, 91, 385, 530] {
            let a = ref_vec(7, n);
            let b = ref_vec(8, n);
            let reference = dot_f64(&a, &b);
            let fast = dot_fast(&a, &b) as f64;
            let tol = 1e-4 * (1.0 + reference.abs());
            assert!((fast - reference).abs() < tol, "n={n}: {fast} vs {reference}");
        }
    }

    #[test]
    fn kernel_mode_dispatch_is_bitwise_invisible() {
        // auto vs scalar must agree bit for bit on every kernel — the knob
        // is a perf A/B, never a numerics A/B. (On non-AVX2 hosts both
        // modes take the portable path and the test is trivially green.)
        let prev = kernel_mode();
        for n in [1usize, 8, 15, 16, 17, 47, 91, 386, 530] {
            let a = ref_vec(9, n);
            let b = ref_vec(10, n);
            set_kernel_mode(KernelMode::Auto);
            let df_auto = dot_fast(&a, &b);
            let mut ya = b.clone();
            axpy(0.37, &a, &mut ya);
            let mut sa = a.clone();
            scale(-1.83, &mut sa);
            let mut oa = vec![0.0f32; n];
            scale_into(2.5, &a, &mut oa);

            set_kernel_mode(KernelMode::Scalar);
            let df_scalar = dot_fast(&a, &b);
            let mut ys = b.clone();
            axpy(0.37, &a, &mut ys);
            let mut ss = a.clone();
            scale(-1.83, &mut ss);
            let mut os = vec![0.0f32; n];
            scale_into(2.5, &a, &mut os);

            assert_eq!(df_auto.to_bits(), df_scalar.to_bits(), "dot_fast n={n}");
            for i in 0..n {
                assert_eq!(ya[i].to_bits(), ys[i].to_bits(), "axpy n={n} i={i}");
                assert_eq!(sa[i].to_bits(), ss[i].to_bits(), "scale n={n} i={i}");
                assert_eq!(oa[i].to_bits(), os[i].to_bits(), "scale_into n={n} i={i}");
            }
        }
        set_kernel_mode(prev);
    }

    #[test]
    fn all_finite_detects_every_position_and_dispatch_agrees() {
        // every planted NaN/±Inf position is caught on both dispatch paths,
        // across sizes straddling the 8-lane AVX2 chunk and its remainder
        let prev = kernel_mode();
        for n in [1usize, 7, 8, 9, 15, 16, 17, 47, 91] {
            let base = ref_vec(13, n);
            for mode in [KernelMode::Auto, KernelMode::Scalar] {
                set_kernel_mode(mode);
                assert!(all_finite(&base), "clean vector must be finite (n={n})");
                assert!(all_finite(&[]), "empty slice is vacuously finite");
            }
            for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                for i in 0..n {
                    let mut v = base.clone();
                    v[i] = bad;
                    set_kernel_mode(KernelMode::Auto);
                    let a = all_finite(&v);
                    set_kernel_mode(KernelMode::Scalar);
                    let s = all_finite(&v);
                    assert!(!a && !s, "n={n} i={i} {bad}: non-finite value missed");
                }
            }
        }
        // subnormals are finite; padded blocks agree with logical rows
        // because the zero tail is finite
        assert!(all_finite(&[f32::MIN_POSITIVE / 2.0, -0.0, f32::MAX]));
        let mut m = AlignedRows::new(5);
        m.push_row(&[1.0, 2.0, f32::NAN, 4.0, 5.0]);
        assert!(!all_finite(m.row_block(0)));
        m.row_mut(0)[2] = 3.0;
        assert!(all_finite(m.row_block(0)));
        set_kernel_mode(prev);
    }

    #[test]
    fn elementwise_kernels_match_naive_loops() {
        let n = 93;
        let x = ref_vec(11, n);
        let mut y = ref_vec(12, n);
        let mut y_ref = y.clone();
        axpy(1.75, &x, &mut y);
        for i in 0..n {
            y_ref[i] += 1.75 * x[i];
        }
        assert_eq!(y, y_ref);
        let mut v = x.clone();
        let mut v_ref = x.clone();
        scale(0.31, &mut v);
        v_ref.iter_mut().for_each(|e| *e *= 0.31);
        assert_eq!(v, v_ref);
        let mut out = vec![0.0f32; n];
        scale_into(-2.0, &x, &mut out);
        let out_ref: Vec<f32> = x.iter().map(|&e| -2.0 * e).collect();
        assert_eq!(out, out_ref);
    }

    #[test]
    fn cosine_and_collision_helpers() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 2.0];
        assert_eq!(cosine(&a, &b), 0.0);
        assert_eq!(cosine(&a, &a), 1.0);
        assert_eq!(cosine(&a, &[0.0, 0.0]), 0.0, "zero norm convention");
        assert!((angular_similarity(&a, &b) - 0.5).abs() < 1e-12);
        // clamp floor/ceiling
        assert_eq!(angular_cp(1.0), 1.0 - PROB_FLOOR);
        assert_eq!(angular_cp(-1.0), PROB_FLOOR);
        // out-of-domain cosines clamp instead of NaN
        assert_eq!(normed_cosine(3.0, 1.0, 1.0), 1.0);
        assert_eq!(normed_cosine(-3.0, 1.0, 1.0), -1.0);
        // quadratic law: squaring first, then clamp-then-acos, matches the
        // historical clamp(c*c) form even when |c| > 1
        assert_eq!(
            quadratic_angular_cp(1.2f64.clamp(-1.0, 1.0)),
            clamp_prob(1.0 - (1.2f64 * 1.2).clamp(-1.0, 1.0).acos() / std::f64::consts::PI)
        );
        // squared cosine is never negative, so the quadratic law bottoms
        // out at 0.5 (orthogonal vectors), not at the probability floor
        assert!((quadratic_angular_cp(0.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn normalize_and_sub_semantics() {
        let mut v = vec![3.0f32, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((norm2(&v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0f32; 3];
        assert_eq!(normalize(&mut z), 0.0);
        assert!(z.iter().all(|&x| x == 0.0), "zero vectors untouched");
        let mut out = [0.0f32; 2];
        sub(&[3.0, 1.0], &[1.0, 4.0], &mut out);
        assert_eq!(out, [2.0, -3.0]);
    }

    #[test]
    fn kernel_mode_parses_names() {
        assert_eq!(KernelMode::from_name("auto"), Some(KernelMode::Auto));
        assert_eq!(KernelMode::from_name("scalar"), Some(KernelMode::Scalar));
        assert_eq!(KernelMode::from_name("fast"), None);
        assert_eq!(KernelMode::Auto.name(), "auto");
        assert_eq!(KernelMode::Scalar.name(), "scalar");
    }
}
