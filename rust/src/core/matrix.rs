//! Dense row-major matrix and vector helpers (BLAS-lite).
//!
//! The library deliberately avoids external linear-algebra crates (offline
//! build): all hot-path math is a handful of dot products and axpys, written
//! here once with explicit unit tests and reused everywhere. `f32` storage
//! matches the PJRT artifacts; accumulation happens in `f64` where it
//! protects a result (means, norms over long vectors).

use crate::core::error::{Error, Result};

/// Row-major dense matrix of `f32`.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major buffer. Errors if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer of {} for {rows}x{cols} matrix",
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Element access.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Element write.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Flat row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Matrix–vector product `y = A x`. `x.len()` must equal `cols`.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) -> Result<()> {
        if x.len() != self.cols || y.len() != self.rows {
            return Err(Error::Shape(format!(
                "matvec {}x{} with x[{}] y[{}]",
                self.rows, self.cols, x.len(), y.len()
            )));
        }
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
        Ok(())
    }

    /// Append a row (must match `cols`; first append on an empty matrix sets
    /// the width).
    pub fn push_row(&mut self, row: &[f32]) -> Result<()> {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
        }
        if row.len() != self.cols {
            return Err(Error::Shape(format!(
                "push_row of width {} into {} cols",
                row.len(), self.cols
            )));
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
        Ok(())
    }

    /// Remove row `i` by moving the last row into its place (O(cols), does
    /// not preserve row order). Live shard tables use this for streaming
    /// removals; the caller owns any external id ↔ row-index fix-up.
    pub fn swap_remove_row(&mut self, i: usize) {
        assert!(i < self.rows, "swap_remove_row({i}) of {} rows", self.rows);
        let last = self.rows - 1;
        if i != last {
            let (head, tail) = self.data.split_at_mut(last * self.cols);
            head[i * self.cols..(i + 1) * self.cols].copy_from_slice(&tail[..self.cols]);
        }
        self.data.truncate(last * self.cols);
        self.rows -= 1;
    }
}

/// Dot product with f64 accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc as f32
}

/// Dot product returning f64 (used where the caller keeps f64 precision).
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc
}

/// Fast f32 dot with 4 independent accumulators (auto-vectorizes; ~4×
/// faster than the f64-accumulated variant). Used on the sampling hot path
/// where float32 precision suffices (collision probabilities).
#[inline]
pub fn dot_fast(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        acc += a[i] * b[i];
    }
    acc
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Euclidean norm with f64 accumulation.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &v in x {
        acc += v as f64 * v as f64;
    }
    acc.sqrt()
}

/// Normalize `x` to unit L2 norm in place; returns the original norm.
/// Zero vectors are left untouched (returns 0).
#[inline]
pub fn normalize(x: &mut [f32]) -> f64 {
    let n = norm2(x);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        for v in x.iter_mut() {
            *v *= inv;
        }
    }
    n
}

/// Cosine similarity, clamped into [-1, 1]. Returns 0 if either vector is 0.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = norm2(a);
    let nb = norm2(b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot_f64(a, b) / (na * nb)).clamp(-1.0, 1.0)
}

/// Angular similarity `1 - acos(cos)/pi` — the quantity the paper plots in
/// Figure 9 and the SimHash collision probability (eq. 14).
#[inline]
pub fn angular_similarity(a: &[f32], b: &[f32]) -> f64 {
    1.0 - cosine(a, b).acos() / std::f64::consts::PI
}

/// `a - b` into `out`.
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == b.len() && b.len() == out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_len() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(m.row(0), &[1.0, 2.0]);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let x = [1.0, 0.5, -1.0];
        let mut y = [0.0; 2];
        m.matvec(&x, &mut y).unwrap();
        assert_eq!(y, [1.0 + 1.0 - 3.0, 4.0 + 2.5 - 6.0]);
        assert!(m.matvec(&[1.0], &mut y).is_err());
    }

    #[test]
    fn swap_remove_row_moves_last_into_place() {
        let mut m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        m.swap_remove_row(0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(0), &[5.0, 6.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        // removing the last row truncates without a move
        m.swap_remove_row(1);
        assert_eq!(m.rows(), 1);
        assert_eq!(m.row(0), &[5.0, 6.0]);
        // emptying keeps the width, so a same-width push still works
        m.swap_remove_row(0);
        assert_eq!(m.rows(), 0);
        m.push_row(&[7.0, 8.0]).unwrap();
        assert_eq!(m.row(0), &[7.0, 8.0]);
        assert!(m.push_row(&[1.0]).is_err(), "width must persist through emptying");
    }

    #[test]
    fn push_row_grows() {
        let mut m = Matrix::zeros(0, 0);
        m.push_row(&[1.0, 2.0]).unwrap();
        m.push_row(&[3.0, 4.0]).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert!(m.push_row(&[1.0]).is_err());
    }

    #[test]
    fn dot_and_axpy() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert_eq!(dot(&a, &b), 4.0 - 10.0 + 18.0);
        let mut y = [1.0f32; 3];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut x = [3.0f32, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        let mut z = [0.0f32; 4];
        assert_eq!(normalize(&mut z), 0.0);
    }

    #[test]
    fn cosine_bounds_and_orthogonal() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!(cosine(&a, &b).abs() < 1e-9);
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-9);
        assert!((angular_similarity(&a, &a) - 1.0).abs() < 1e-9);
        assert!((angular_similarity(&a, &b) - 0.5).abs() < 1e-9);
        let c = [-1.0f32, 0.0];
        assert!(angular_similarity(&a, &c).abs() < 1e-9);
    }

    #[test]
    fn scale_and_sub() {
        let mut x = [1.0f32, -2.0];
        scale(-3.0, &mut x);
        assert_eq!(x, [-3.0, 6.0]);
        let mut out = [0.0f32; 2];
        sub(&[5.0, 5.0], &[2.0, 7.0], &mut out);
        assert_eq!(out, [3.0, -2.0]);
    }
}
