//! Dense row-major matrix over the aligned-block numerics layer.
//!
//! The library deliberately avoids external linear-algebra crates (offline
//! build): all hot-path math is the single kernel suite in
//! [`crate::core::numerics`], re-exported here so call sites keep one
//! import path. Storage is [`AlignedRows`]: every row padded to a
//! [`LANES`](crate::core::numerics::LANES) multiple of 64-byte-aligned
//! blocks with a guaranteed-zero tail. `row(i)` is the logical slice
//! callers always saw; `row_block(i)` is the padded slice the kernels
//! want. `f32` storage matches the PJRT artifacts; accumulation happens in
//! `f64` where it protects a result (means, norms over long vectors).

use crate::core::error::{Error, Result};
use crate::core::numerics::AlignedRows;

// The ONE kernel suite — every caller that did `crate::core::matrix::dot`
// etc. now reaches the aligned-block kernels through the same path.
pub use crate::core::numerics::{
    angular_similarity, axpy, cosine, dot, dot_f64, dot_fast, dot_norm, norm2, normalize,
    scale, scale_into, sub,
};

/// Row-major dense matrix of `f32` in aligned padded storage.
///
/// Derived `PartialEq` compares the padded blocks; the zero-tail invariant
/// plus the deterministic stride make that coincide exactly with logical
/// equality (same dims, same values).
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    data: AlignedRows,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: AlignedRows::zeros(rows, cols) }
    }

    /// Build from a flat row-major buffer. Errors if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer of {} for {rows}x{cols} matrix",
                data.len()
            )));
        }
        let mut ar = AlignedRows::new(cols);
        for r in 0..rows {
            ar.push_row(&data[r * cols..(r + 1) * cols]);
        }
        Ok(Matrix { data: ar })
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.data.rows()
    }

    /// Number of (logical) columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.data.cols()
    }

    /// Borrow row `i` as its logical slice (exactly `cols` values).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows());
        self.data.row(i)
    }

    /// Mutable logical row access (padding tail stays untouched).
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows());
        self.data.row_mut(i)
    }

    /// Full padded row `i` — a [`LANES`](crate::core::numerics::LANES)
    /// multiple long with a guaranteed-zero tail; what the kernels want.
    #[inline]
    pub fn row_block(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows());
        self.data.row_block(i)
    }

    /// Element access.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.row(i)[j]
    }

    /// Element write.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.row_mut(i)[j] = v;
    }

    /// Matrix–vector product `y = A x`. `x.len()` must equal `cols`.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) -> Result<()> {
        if x.len() != self.cols() || y.len() != self.rows() {
            return Err(Error::Shape(format!(
                "matvec {}x{} with x[{}] y[{}]",
                self.rows(),
                self.cols(),
                x.len(),
                y.len()
            )));
        }
        for i in 0..self.rows() {
            y[i] = dot(self.row(i), x);
        }
        Ok(())
    }

    /// L2 norm of every row through the kernel suite (the estimator norm
    /// caches). Runs over the padded blocks — bitwise identical to the
    /// logical rows, because the zero tail only adds exact `+0.0` terms to
    /// a non-negative accumulator.
    pub fn row_norms(&self) -> Vec<f64> {
        (0..self.rows()).map(|i| norm2(self.row_block(i))).collect()
    }

    /// Append a row (must match `cols`; first append on an empty matrix sets
    /// the width).
    pub fn push_row(&mut self, row: &[f32]) -> Result<()> {
        if !(self.rows() == 0 && self.cols() == 0) && row.len() != self.cols() {
            return Err(Error::Shape(format!(
                "push_row of width {} into {} cols",
                row.len(),
                self.cols()
            )));
        }
        self.data.push_row(row);
        Ok(())
    }

    /// Remove row `i` by moving the last row into its place (O(cols), does
    /// not preserve row order). Live shard tables use this for streaming
    /// removals; the caller owns any external id ↔ row-index fix-up. Whole
    /// padded blocks move, so the zero-tail invariant is preserved.
    pub fn swap_remove_row(&mut self, i: usize) {
        assert!(i < self.rows(), "swap_remove_row({i}) of {} rows", self.rows());
        self.data.swap_remove_row(i);
    }

    /// True when every padded position beyond the logical width is exactly
    /// `+0.0` — the invariant tests assert across mutation, migration and
    /// snapshot load.
    pub fn zero_tail_ok(&self) -> bool {
        self.data.zero_tail_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::numerics::LANES;

    #[test]
    fn from_vec_checks_len() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(m.row(0), &[1.0, 2.0]);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let x = [1.0, 0.5, -1.0];
        let mut y = [0.0; 2];
        m.matvec(&x, &mut y).unwrap();
        assert_eq!(y, [1.0 + 1.0 - 3.0, 4.0 + 2.5 - 6.0]);
        assert!(m.matvec(&[1.0], &mut y).is_err());
    }

    #[test]
    fn swap_remove_row_moves_last_into_place() {
        let mut m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        m.swap_remove_row(0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(0), &[5.0, 6.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        // removing the last row truncates without a move
        m.swap_remove_row(1);
        assert_eq!(m.rows(), 1);
        assert_eq!(m.row(0), &[5.0, 6.0]);
        // emptying keeps the width, so a same-width push still works
        m.swap_remove_row(0);
        assert_eq!(m.rows(), 0);
        m.push_row(&[7.0, 8.0]).unwrap();
        assert_eq!(m.row(0), &[7.0, 8.0]);
        assert!(m.push_row(&[1.0]).is_err(), "width must persist through emptying");
    }

    #[test]
    fn push_row_grows() {
        let mut m = Matrix::zeros(0, 0);
        m.push_row(&[1.0, 2.0]).unwrap();
        m.push_row(&[3.0, 4.0]).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert!(m.push_row(&[1.0]).is_err());
    }

    #[test]
    fn aligned_layout_is_invisible_to_logical_callers() {
        // ragged widths around the lane boundary: logical reads unchanged,
        // padded blocks lane-multiple with zero tails, equality logical
        for cols in [1usize, LANES - 1, LANES, LANES + 1, 2 * LANES + 5, 91] {
            let flat: Vec<f32> = (0..3 * cols).map(|i| i as f32 - 7.5).collect();
            let m = Matrix::from_vec(3, cols, flat.clone()).unwrap();
            for r in 0..3 {
                assert_eq!(m.row(r), &flat[r * cols..(r + 1) * cols]);
                assert_eq!(m.row_block(r).len() % LANES, 0);
                assert_eq!(&m.row_block(r)[..cols], m.row(r));
            }
            assert!(m.zero_tail_ok(), "cols={cols}");
            let m2 = Matrix::from_vec(3, cols, flat).unwrap();
            assert_eq!(m, m2, "padded equality must coincide with logical equality");
        }
    }

    #[test]
    fn zero_tail_survives_mutation() {
        let mut m = Matrix::zeros(0, 0);
        for r in 0..10 {
            let row: Vec<f32> = (0..21).map(|j| (r * 21 + j) as f32).collect();
            m.push_row(&row).unwrap();
            assert!(m.zero_tail_ok(), "after push {r}");
        }
        m.row_mut(4).iter_mut().for_each(|v| *v = -3.25);
        m.set(2, 20, 1.5);
        assert!(m.zero_tail_ok(), "after writes");
        m.swap_remove_row(0);
        m.swap_remove_row(5);
        m.swap_remove_row(m.rows() - 1);
        assert!(m.zero_tail_ok(), "after swap_remove");
        assert_eq!(m.rows(), 7);
    }

    #[test]
    fn row_norms_match_per_row_kernel() {
        let m = Matrix::from_vec(4, 21, (0..84).map(|i| (i as f32).sin()).collect()).unwrap();
        let norms = m.row_norms();
        for i in 0..4 {
            assert_eq!(
                norms[i].to_bits(),
                norm2(m.row(i)).to_bits(),
                "padded row norm must be bitwise identical to the logical one"
            );
        }
    }

    #[test]
    fn dot_and_axpy() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert_eq!(dot(&a, &b), 4.0 - 10.0 + 18.0);
        let mut y = [1.0f32; 3];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut x = [3.0f32, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        let mut z = [0.0f32; 4];
        assert_eq!(normalize(&mut z), 0.0);
    }

    #[test]
    fn cosine_bounds_and_orthogonal() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!(cosine(&a, &b).abs() < 1e-9);
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-9);
        assert!((angular_similarity(&a, &a) - 1.0).abs() < 1e-9);
        assert!((angular_similarity(&a, &b) - 0.5).abs() < 1e-9);
        let c = [-1.0f32, 0.0];
        assert!(angular_similarity(&a, &c).abs() < 1e-9);
    }

    #[test]
    fn scale_and_sub() {
        let mut x = [1.0f32, -2.0];
        scale(-3.0, &mut x);
        assert_eq!(x, [-3.0, 6.0]);
        let mut out = [0.0f32; 2];
        sub(&[5.0, 5.0], &[2.0, 7.0], &mut out);
        assert_eq!(out, [3.0, -2.0]);
    }
}
