//! Prometheus text exposition (version 0.0.4) for the telemetry registry,
//! plus a strict validator shared by the tests, the `lgd stats` client and
//! the CI observability smoke.
//!
//! Naming scheme: dotted registry names map to `lgd_` + dots/dashes →
//! underscores (`serve.draws_served` → `lgd_serve_draws_served`).
//! Histograms are exported in seconds with the conventional
//! `_seconds_bucket{le=...}` / `_seconds_sum` / `_seconds_count` triplet
//! over the registry's power-of-two nanosecond bounds.

use crate::core::telemetry::registry::{Registry, SampleValue};

/// `lgd_`-prefixed exposition name for a dotted registry name.
pub fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 4);
    out.push_str("lgd_");
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    out
}

fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v == f64::INFINITY {
        "+Inf".into()
    } else if v == f64::NEG_INFINITY {
        "-Inf".into()
    } else {
        format!("{v}")
    }
}

/// Render the full registry as Prometheus text exposition. Metrics sharing
/// a base name (label variants) are grouped under one `# TYPE` header; the
/// registry's sorted enumeration keeps variants adjacent.
pub fn render(reg: &Registry) -> String {
    let mut out = String::new();
    let mut last_base = String::new();
    for s in reg.snapshot() {
        let base = prom_name(&s.name);
        let ty = match s.value {
            SampleValue::Counter(_) => "counter",
            SampleValue::Gauge(_) => "gauge",
            SampleValue::Histogram { .. } => "histogram",
        };
        // Histograms get a `_seconds` unit suffix on the exposition name.
        let ename = match s.value {
            SampleValue::Histogram { .. } => format!("{base}_seconds"),
            _ => base.clone(),
        };
        if ename != last_base {
            out.push_str(&format!("# HELP {ename} lgd runtime metric {}\n", s.name));
            out.push_str(&format!("# TYPE {ename} {ty}\n"));
            last_base = ename.clone();
        }
        let labels = |extra: &str| -> String {
            match (s.labels.is_empty(), extra.is_empty()) {
                (true, true) => String::new(),
                (true, false) => format!("{{{extra}}}"),
                (false, true) => format!("{{{}}}", s.labels),
                (false, false) => format!("{{{},{extra}}}", s.labels),
            }
        };
        match &s.value {
            SampleValue::Counter(v) => {
                out.push_str(&format!("{ename}{} {v}\n", labels("")));
            }
            SampleValue::Gauge(v) => {
                out.push_str(&format!("{ename}{} {}\n", labels(""), fmt_f64(*v)));
            }
            SampleValue::Histogram { buckets, sum_secs, count } => {
                for (le, c) in buckets {
                    let le = format!("le=\"{}\"", fmt_f64(*le));
                    out.push_str(&format!("{ename}_bucket{} {c}\n", labels(&le)));
                }
                out.push_str(&format!("{ename}_sum{} {}\n", labels(""), fmt_f64(*sum_secs)));
                out.push_str(&format!("{ename}_count{} {count}\n", labels("")));
            }
        }
    }
    out
}

/// What a validated exposition contained.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PromSummary {
    /// `# TYPE ... counter` declarations.
    pub counters: usize,
    /// `# TYPE ... gauge` declarations.
    pub gauges: usize,
    /// `# TYPE ... histogram` declarations.
    pub histograms: usize,
    /// Non-comment sample lines.
    pub samples: usize,
}

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn parse_value(s: &str) -> Option<f64> {
    match s {
        "+Inf" => Some(f64::INFINITY),
        "-Inf" => Some(f64::NEG_INFINITY),
        "NaN" => Some(f64::NAN),
        _ => s.parse::<f64>().ok(),
    }
}

/// Strictly validate a Prometheus text exposition: every sample line must
/// parse as `name[{labels}] value`, reference a preceding `# TYPE`
/// declaration (histogram samples via their `_bucket`/`_sum`/`_count`
/// suffixes), carry a parseable value, and histogram buckets must be
/// cumulative (non-decreasing in `le` order, ending at `+Inf`).
pub fn validate(text: &str) -> Result<PromSummary, String> {
    let mut sum = PromSummary::default();
    // Declared (name, type) pairs.
    let mut types: Vec<(String, String)> = Vec::new();
    // Per-histogram bucket trail: (name, last_count, saw_inf).
    let mut hist_state: Vec<(String, u64, bool)> = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().ok_or(format!("line {ln}: TYPE without a name"))?;
            let ty = it.next().ok_or(format!("line {ln}: TYPE without a type"))?;
            if !valid_name(name) {
                return Err(format!("line {ln}: invalid metric name '{name}'"));
            }
            match ty {
                "counter" => sum.counters += 1,
                "gauge" => sum.gauges += 1,
                "histogram" => {
                    sum.histograms += 1;
                    hist_state.push((name.to_string(), 0, false));
                }
                other => return Err(format!("line {ln}: unknown type '{other}'")),
            }
            types.push((name.to_string(), ty.to_string()));
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or comment
        }
        // Sample: name[{labels}] value
        let (name_part, value_part) = match line.rfind(' ') {
            Some(i) => (&line[..i], &line[i + 1..]),
            None => return Err(format!("line {ln}: sample without a value")),
        };
        let (name, labels) = match name_part.find('{') {
            Some(i) => {
                if !name_part.ends_with('}') {
                    return Err(format!("line {ln}: unbalanced label braces"));
                }
                (&name_part[..i], &name_part[i + 1..name_part.len() - 1])
            }
            None => (name_part, ""),
        };
        if !valid_name(name) {
            return Err(format!("line {ln}: invalid sample name '{name}'"));
        }
        let value = parse_value(value_part)
            .ok_or(format!("line {ln}: unparseable value '{value_part}'"))?;
        // Resolve the declaring TYPE: exact name, or histogram suffixes.
        let declared = types.iter().any(|(n, _)| n == name);
        let hist_parent = ["_bucket", "_sum", "_count"].iter().find_map(|suf| {
            name.strip_suffix(suf).filter(|base| {
                types.iter().any(|(n, t)| n == base && t == "histogram")
            })
        });
        if !declared && hist_parent.is_none() {
            return Err(format!("line {ln}: sample '{name}' has no preceding # TYPE"));
        }
        if let Some(base) = name.strip_suffix("_bucket") {
            let le = labels
                .split(',')
                .find_map(|kv| kv.strip_prefix("le=\""))
                .and_then(|v| v.strip_suffix('"'))
                .ok_or(format!("line {ln}: histogram bucket without an le label"))?;
            let le = parse_value(le).ok_or(format!("line {ln}: unparseable le '{le}'"))?;
            let count = value as u64;
            if let Some(st) = hist_state.iter_mut().find(|(n, _, _)| n == base) {
                if count < st.1 {
                    return Err(format!(
                        "line {ln}: histogram '{base}' buckets not cumulative"
                    ));
                }
                st.1 = count;
                if le.is_infinite() {
                    st.2 = true;
                }
            }
        }
        let _ = value;
        sum.samples += 1;
    }
    for (name, _, saw_inf) in &hist_state {
        if !saw_inf {
            return Err(format!("histogram '{name}' has no +Inf bucket"));
        }
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::telemetry::registry::HIST_BUCKETS;

    #[test]
    fn render_validates_and_covers_all_kinds() {
        let r = Registry::new();
        r.counter("serve.draws_served").add(42);
        r.gauge("probe.tv_distance").set(0.03125);
        r.gauge_labeled("serve.shard_rows", &[("shard", "0")]).set(100.0);
        r.gauge_labeled("serve.shard_rows", &[("shard", "1")]).set(96.0);
        r.histogram("serve.request_secs").observe_secs(0.002);
        let text = render(&r);
        let sum = validate(&text).expect("rendered exposition must validate");
        assert_eq!(sum.counters, 1);
        assert_eq!(sum.gauges, 2); // tv_distance + shard_rows (one TYPE for both labels)
        assert_eq!(sum.histograms, 1);
        // 1 counter + 1 gauge + 2 labeled gauges + buckets + sum + count
        assert_eq!(sum.samples, 4 + HIST_BUCKETS + 2);
        assert!(text.contains("lgd_serve_draws_served 42"));
        assert!(text.contains("lgd_probe_tv_distance 0.03125"));
        assert!(text.contains("lgd_serve_shard_rows{shard=\"0\"} 100"));
        assert!(text.contains("lgd_serve_request_secs_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("lgd_serve_request_secs_seconds_count 1"));
    }

    #[test]
    fn labeled_variants_share_one_type_header() {
        let r = Registry::new();
        r.gauge_labeled("g", &[("shard", "0")]).set(1.0);
        r.gauge_labeled("g", &[("shard", "1")]).set(2.0);
        let text = render(&r);
        assert_eq!(text.matches("# TYPE lgd_g gauge").count(), 1);
    }

    #[test]
    fn rejects_malformed_text() {
        assert!(validate("no_type_decl 1\n").is_err());
        assert!(validate("# TYPE x counter\nx notanumber\n").is_err());
        assert!(validate("# TYPE x counter\n9bad 1\n").is_err());
        assert!(validate("# TYPE x bogus\n").is_err());
        assert!(validate("# TYPE h histogram\nh_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\n")
            .is_err());
        // Histogram that never reaches +Inf.
        assert!(validate("# TYPE h histogram\nh_bucket{le=\"1\"} 5\n").is_err());
    }

    #[test]
    fn accepts_special_values() {
        let ok = "# TYPE g gauge\ng +Inf\ng2{x=\"y\"} NaN\n";
        // g2 undeclared — must fail.
        assert!(validate(ok).is_err());
        let ok = "# TYPE g gauge\ng +Inf\n# TYPE g2 gauge\ng2{x=\"y\"} NaN\n";
        let sum = validate(ok).unwrap();
        assert_eq!(sum.samples, 2);
    }

    #[test]
    fn prom_name_sanitizes() {
        assert_eq!(prom_name("pipeline.shard_build"), "lgd_pipeline_shard_build");
        assert_eq!(prom_name("a-b.c"), "lgd_a_b_c");
    }
}
