//! The unified metrics registry: lock-free hot-path counters, f64 gauges
//! and log-bucketed latency histograms behind register-once handles.
//!
//! The registration path (`counter`/`gauge`/`histogram`) takes the
//! registry mutex and returns a cloneable handle wrapping the metric's
//! atomics; every subsequent `add`/`set`/`observe` through the handle is a
//! relaxed atomic op with no lock and no map lookup. The name-keyed map
//! exists only for the slow paths — enumeration ([`Registry::snapshot`]),
//! ad-hoc reads in tests, and the Prometheus renderer. This is the fix for
//! the original `coordinator::metrics` defect where `count()` locked a
//! whole `BTreeMap` per increment.
//!
//! One process-global instance ([`Registry::global`]) backs the wire
//! surface (`METRICS` op, `lgd stats`) and the trainer's per-epoch
//! snapshots; private instances (`Registry::new`) keep unit tests and
//! per-build reports isolated.
//!
//! Everything here is *passive*: recording touches no RNG and reorders no
//! draws, which is what keeps armed-but-unread telemetry bitwise invisible
//! to draw streams and θ (the repo's standing contract, enforced by the
//! determinism gates in `coordinator::trainer` and `runtime::serving`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// First finite histogram bound: `2^10` ns (~1 µs). Latencies below land
/// in bucket 0.
pub const HIST_MIN_EXP: u32 = 10;
/// Last finite histogram bound: `2^36` ns (~68.7 s). Latencies above land
/// in the `+Inf` bucket.
pub const HIST_MAX_EXP: u32 = 36;
/// Bucket count: one per power of two in `MIN..=MAX`, plus `+Inf`.
pub const HIST_BUCKETS: usize = (HIST_MAX_EXP - HIST_MIN_EXP + 2) as usize;

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // Metric maps are plain data; a panicking holder poisons nothing
    // structurally. Recover like the serving layer does.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Shared counter cell: monotone u64, relaxed ordering (totals are read
/// after a happens-before edge — thread join or a later lock — so relaxed
/// is enough, the same argument the serving counters make).
#[derive(Clone)]
pub struct CounterHandle(Arc<AtomicU64>);

impl CounterHandle {
    /// Add `v` to the counter. Lock-free.
    #[inline]
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    /// Increment by one. Lock-free.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current total.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Shared gauge cell: an f64 stored as bits in an `AtomicU64` (last write
/// wins; no read-modify-write on the hot path needs locking).
#[derive(Clone)]
pub struct GaugeHandle(Arc<AtomicU64>);

impl GaugeHandle {
    /// Set the gauge. Lock-free.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Log-bucketed latency histogram core: per-bucket counts over power-of-two
/// nanosecond bounds, plus an exact nanosecond sum and a sample count. All
/// atomics, all relaxed — `observe` never locks.
pub struct HistogramCore {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl HistogramCore {
    fn new() -> Self {
        HistogramCore {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// The bucket a `ns`-long sample lands in: the smallest exponent `e`
    /// in `MIN..=MAX` with `ns <= 2^e`, clamped to bucket 0 below and the
    /// `+Inf` bucket above.
    pub fn bucket_index(ns: u64) -> usize {
        // ceil(log2(ns)) for ns >= 1; 0 for ns <= 1.
        let exp = 64 - ns.saturating_sub(1).leading_zeros();
        if exp <= HIST_MIN_EXP {
            0
        } else if exp > HIST_MAX_EXP {
            HIST_BUCKETS - 1
        } else {
            (exp - HIST_MIN_EXP) as usize
        }
    }

    /// Upper bound of bucket `i` in seconds (`+Inf` for the last bucket).
    pub fn bucket_bound_secs(i: usize) -> f64 {
        if i >= HIST_BUCKETS - 1 {
            f64::INFINITY
        } else {
            (1u64 << (HIST_MIN_EXP + i as u32)) as f64 / 1e9
        }
    }

    /// Record one duration in nanoseconds. Lock-free.
    #[inline]
    pub fn observe_ns(&self, ns: u64) {
        self.buckets[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one duration in seconds (negative clamps to zero).
    #[inline]
    pub fn observe_secs(&self, secs: f64) {
        self.observe_ns((secs.max(0.0) * 1e9) as u64);
    }

    /// Sample count.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total seconds observed.
    pub fn sum_secs(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Cumulative `(upper_bound_secs, count_le)` pairs, ending at `+Inf`.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        (0..HIST_BUCKETS)
            .map(|i| {
                acc += self.buckets[i].load(Ordering::Relaxed);
                (Self::bucket_bound_secs(i), acc)
            })
            .collect()
    }
}

/// Shared histogram handle.
#[derive(Clone)]
pub struct HistogramHandle(Arc<HistogramCore>);

impl HistogramHandle {
    /// Record one duration in seconds. Lock-free.
    #[inline]
    pub fn observe_secs(&self, secs: f64) {
        self.0.observe_secs(secs);
    }

    /// Record one duration in nanoseconds. Lock-free.
    #[inline]
    pub fn observe_ns(&self, ns: u64) {
        self.0.observe_ns(ns);
    }

    /// The shared core (for reads).
    pub fn core(&self) -> &HistogramCore {
        &self.0
    }
}

enum Entry {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<HistogramCore>),
}

/// One enumerated metric value (see [`Registry::snapshot`]).
#[derive(Debug, Clone)]
pub enum SampleValue {
    /// Monotone counter total.
    Counter(u64),
    /// Last-set gauge value.
    Gauge(f64),
    /// Histogram: cumulative `(le_secs, count)` buckets + sum + count.
    Histogram {
        /// Cumulative buckets ending at `+Inf`.
        buckets: Vec<(f64, u64)>,
        /// Total observed seconds.
        sum_secs: f64,
        /// Number of samples.
        count: u64,
    },
}

/// One enumerated metric: dotted base name, rendered label pairs (empty or
/// `k="v",...`), and the value.
#[derive(Debug, Clone)]
pub struct MetricSample {
    /// Dotted metric name (e.g. `serve.draws_served`).
    pub name: String,
    /// Label fragment without braces (e.g. `shard="3"`); empty when
    /// unlabeled.
    pub labels: String,
    /// The value.
    pub value: SampleValue,
}

/// The registry: a name-keyed map consulted only at registration and
/// enumeration time. Keys are `name` or `name{labels}`.
pub struct Registry {
    inner: Mutex<BTreeMap<String, Entry>>,
}

static GLOBAL: Registry = Registry::new();

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    /// Fresh private registry.
    pub const fn new() -> Self {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    /// The process-global registry backing the wire surface and the
    /// trainer's per-epoch snapshots.
    pub fn global() -> &'static Registry {
        &GLOBAL
    }

    fn key(name: &str, labels: &[(&str, &str)]) -> String {
        if labels.is_empty() {
            return name.to_string();
        }
        let mut k = String::with_capacity(name.len() + 16);
        k.push_str(name);
        k.push('{');
        for (i, (lk, lv)) in labels.iter().enumerate() {
            if i > 0 {
                k.push(',');
            }
            k.push_str(lk);
            k.push_str("=\"");
            k.push_str(lv);
            k.push('"');
        }
        k.push('}');
        k
    }

    /// Register-once counter: the first call creates it, later calls (from
    /// any thread) return a handle to the same cell. Panics if `name` is
    /// already registered as a different kind — metric kinds are a static
    /// property of the name.
    pub fn counter(&self, name: &str) -> CounterHandle {
        self.counter_labeled(name, &[])
    }

    /// [`Self::counter`] with labels (`shard="3"`-style).
    pub fn counter_labeled(&self, name: &str, labels: &[(&str, &str)]) -> CounterHandle {
        let key = Self::key(name, labels);
        let mut m = lock(&self.inner);
        match m.entry(key).or_insert_with(|| Entry::Counter(Arc::new(AtomicU64::new(0)))) {
            Entry::Counter(c) => CounterHandle(Arc::clone(c)),
            _ => panic!("metric '{name}' is already registered as a non-counter"),
        }
    }

    /// Register-once gauge (see [`Self::counter`] for the contract).
    pub fn gauge(&self, name: &str) -> GaugeHandle {
        self.gauge_labeled(name, &[])
    }

    /// [`Self::gauge`] with labels.
    pub fn gauge_labeled(&self, name: &str, labels: &[(&str, &str)]) -> GaugeHandle {
        let key = Self::key(name, labels);
        let mut m = lock(&self.inner);
        match m.entry(key).or_insert_with(|| Entry::Gauge(Arc::new(AtomicU64::new(0)))) {
            Entry::Gauge(g) => GaugeHandle(Arc::clone(g)),
            _ => panic!("metric '{name}' is already registered as a non-gauge"),
        }
    }

    /// Register-once log-bucketed latency histogram (see [`Self::counter`]
    /// for the contract).
    pub fn histogram(&self, name: &str) -> HistogramHandle {
        let key = Self::key(name, &[]);
        let mut m = lock(&self.inner);
        match m.entry(key).or_insert_with(|| Entry::Histogram(Arc::new(HistogramCore::new()))) {
            Entry::Histogram(h) => HistogramHandle(Arc::clone(h)),
            _ => panic!("metric '{name}' is already registered as a non-histogram"),
        }
    }

    /// Slow-path counter read: 0 when absent or not a counter. For tests
    /// and reports — hot paths hold a [`CounterHandle`].
    pub fn counter_value(&self, name: &str) -> u64 {
        match lock(&self.inner).get(name) {
            Some(Entry::Counter(c)) => c.load(Ordering::Relaxed),
            _ => 0,
        }
    }

    /// Slow-path gauge read: 0.0 when absent or not a gauge.
    pub fn gauge_value(&self, name: &str) -> f64 {
        match lock(&self.inner).get(name) {
            Some(Entry::Gauge(g)) => f64::from_bits(g.load(Ordering::Relaxed)),
            _ => 0.0,
        }
    }

    /// Enumerate every metric, sorted by key. The only path that walks the
    /// map — rendering, wire dumps and epoch snapshots all build on it.
    pub fn snapshot(&self) -> Vec<MetricSample> {
        let m = lock(&self.inner);
        m.iter()
            .map(|(key, entry)| {
                let (name, labels) = match key.find('{') {
                    Some(i) => (key[..i].to_string(), key[i + 1..key.len() - 1].to_string()),
                    None => (key.clone(), String::new()),
                };
                let value = match entry {
                    Entry::Counter(c) => SampleValue::Counter(c.load(Ordering::Relaxed)),
                    Entry::Gauge(g) => {
                        SampleValue::Gauge(f64::from_bits(g.load(Ordering::Relaxed)))
                    }
                    Entry::Histogram(h) => SampleValue::Histogram {
                        buckets: h.cumulative(),
                        sum_secs: h.sum_secs(),
                        count: h.count(),
                    },
                };
                MetricSample { name, labels, value }
            })
            .collect()
    }

    /// Flat `(name_or_labeled_name, value)` pairs for wire dumps and epoch
    /// snapshots: counters and gauges verbatim; histograms contribute
    /// `<name>.count` and `<name>.sum_secs`.
    pub fn flat(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for s in self.snapshot() {
            let key = if s.labels.is_empty() {
                s.name.clone()
            } else {
                format!("{}{{{}}}", s.name, s.labels)
            };
            match s.value {
                SampleValue::Counter(v) => out.push((key, v as f64)),
                SampleValue::Gauge(v) => out.push((key, v)),
                SampleValue::Histogram { sum_secs, count, .. } => {
                    out.push((format!("{key}.count"), count as f64));
                    out.push((format!("{key}.sum_secs"), sum_secs));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_once_returns_same_cell() {
        let r = Registry::new();
        let a = r.counter("c");
        let b = r.counter("c");
        a.add(2);
        b.add(3);
        assert_eq!(r.counter_value("c"), 5);
        assert_eq!(a.get(), 5);
    }

    #[test]
    fn gauge_last_write_wins() {
        let r = Registry::new();
        let g = r.gauge("g");
        g.set(1.5);
        g.set(-2.25);
        assert_eq!(r.gauge_value("g"), -2.25);
        assert_eq!(r.gauge_value("missing"), 0.0);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("m");
        r.gauge("m");
    }

    #[test]
    fn labeled_metrics_are_distinct() {
        let r = Registry::new();
        r.counter_labeled("s", &[("shard", "0")]).add(1);
        r.counter_labeled("s", &[("shard", "1")]).add(2);
        assert_eq!(r.counter_value("s{shard=\"0\"}"), 1);
        assert_eq!(r.counter_value("s{shard=\"1\"}"), 2);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].name, "s");
        assert_eq!(snap[0].labels, "shard=\"0\"");
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // Exactly on a power-of-two bound lands in that bucket; one past
        // it spills to the next; extremes clamp.
        assert_eq!(HistogramCore::bucket_index(0), 0);
        assert_eq!(HistogramCore::bucket_index(1), 0);
        assert_eq!(HistogramCore::bucket_index(1 << HIST_MIN_EXP), 0);
        assert_eq!(HistogramCore::bucket_index((1 << HIST_MIN_EXP) + 1), 1);
        assert_eq!(HistogramCore::bucket_index(1 << (HIST_MIN_EXP + 1)), 1);
        assert_eq!(
            HistogramCore::bucket_index(1u64 << HIST_MAX_EXP),
            HIST_BUCKETS - 2
        );
        assert_eq!(
            HistogramCore::bucket_index((1u64 << HIST_MAX_EXP) + 1),
            HIST_BUCKETS - 1
        );
        assert_eq!(HistogramCore::bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_cumulative_and_sum() {
        let r = Registry::new();
        let h = r.histogram("lat");
        h.observe_ns(1_000); // bucket 0 (1000 <= 1024)
        h.observe_ns(2_000); // bucket 1 (<= 2048)
        h.observe_ns(u64::MAX / 2); // +Inf bucket
        let core = h.core();
        assert_eq!(core.count(), 3);
        let cum = core.cumulative();
        assert_eq!(cum.len(), HIST_BUCKETS);
        assert_eq!(cum[0].1, 1);
        assert_eq!(cum[1].1, 2);
        assert_eq!(cum[HIST_BUCKETS - 1].1, 3);
        assert!(cum[HIST_BUCKETS - 1].0.is_infinite());
        // Cumulative counts never decrease.
        for w in cum.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn histogram_secs_roundtrip() {
        let r = Registry::new();
        let h = r.histogram("t");
        h.observe_secs(0.5);
        h.observe_secs(1.5);
        assert_eq!(h.core().count(), 2);
        assert!((h.core().sum_secs() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn concurrent_hammering_from_8_threads() {
        let r = std::sync::Arc::new(Registry::new());
        let c = r.counter("hits");
        let h = r.histogram("lat");
        let mut joins = Vec::new();
        for t in 0..8 {
            let c = c.clone();
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    c.add(1);
                    h.observe_ns((t * 1000 + i) * 1000);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
        assert_eq!(h.core().count(), 8000);
        let cum = h.core().cumulative();
        assert_eq!(cum[HIST_BUCKETS - 1].1, 8000);
    }

    #[test]
    fn flat_dump_covers_all_kinds() {
        let r = Registry::new();
        r.counter("c").add(7);
        r.gauge("g").set(0.25);
        r.histogram("h").observe_secs(1.0);
        let flat = r.flat();
        let get = |k: &str| flat.iter().find(|(n, _)| n == k).map(|(_, v)| *v);
        assert_eq!(get("c"), Some(7.0));
        assert_eq!(get("g"), Some(0.25));
        assert_eq!(get("h.count"), Some(1.0));
        assert!((get("h.sum_secs").unwrap() - 1.0).abs() < 1e-9);
    }
}
