//! Zero-dep structured span/event layer.
//!
//! A [`SpanGuard`] (usually via the [`crate::span!`] macro) scopes a named
//! region: on drop it records the duration into a global-registry
//! histogram (`span.<name>`, always on — the registry is passive), and,
//! when tracing is armed, appends one JSONL event to the rotating trace
//! file. Events carry monotonic timestamps (nanoseconds since
//! [`arm`] — wall clocks can step backwards, a monotonic anchor cannot),
//! a process-unique thread id, and span parentage via a thread-local span
//! stack.
//!
//! Event schema (one JSON object per line, numeric fields only):
//!
//! ```json
//! {"ts_ns":1234,"dur_ns":567,"span":"train.epoch","id":3,"parent":0,
//!  "thread":1,"fields":{"epoch":2}}
//! ```
//!
//! `parent` is 0 for root spans. The file rotates to `<path>.1` when it
//! exceeds the armed byte budget (one rotation generation is kept).
//! `lgd trace summarize` parses this format back via [`parse_line`] /
//! [`summarize_file`].
//!
//! The disarmed hot path is one relaxed atomic load — the same bar the
//! failpoint registry meets — so spans can sit on production paths
//! without a feature gate, and emitting touches no RNG (the bitwise
//! invisibility contract).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::core::telemetry::registry::Registry;

static ARMED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Sink>> = Mutex::new(None);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_THREAD_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static THREAD_ID: u64 = NEXT_THREAD_ID.fetch_add(1, Ordering::Relaxed);
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

struct Sink {
    file: File,
    path: PathBuf,
    max_bytes: u64,
    written: u64,
    /// Monotonic anchor: event timestamps are nanoseconds since arming.
    anchor: Instant,
}

fn sink() -> MutexGuard<'static, Option<Sink>> {
    SINK.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Arm tracing: truncate-create `path` and start appending span events,
/// rotating to `<path>.1` past `max_bytes`. Re-arming replaces the sink.
pub fn arm(path: &Path, max_bytes: u64) -> std::io::Result<()> {
    let file = File::create(path)?;
    *sink() = Some(Sink {
        file,
        path: path.to_path_buf(),
        max_bytes: max_bytes.max(4096),
        written: 0,
        anchor: Instant::now(),
    });
    ARMED.store(true, Ordering::Release);
    Ok(())
}

/// Disarm tracing and flush/close the trace file. Idempotent.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    if let Some(mut s) = sink().take() {
        let _ = s.file.flush();
    }
}

/// Is tracing armed? One relaxed load — the span emit guard.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

fn emit(line: &str) {
    let mut guard = sink();
    let Some(s) = guard.as_mut() else { return };
    if s.file.write_all(line.as_bytes()).is_err() {
        return;
    }
    s.written += line.len() as u64;
    if s.written > s.max_bytes {
        // Rotate: current file becomes `<path>.1` (replacing any previous
        // rotation), and a fresh file continues at the armed path.
        let _ = s.file.flush();
        let mut rot = s.path.as_os_str().to_os_string();
        rot.push(".1");
        let _ = std::fs::rename(&s.path, PathBuf::from(rot));
        if let Ok(f) = File::create(&s.path) {
            s.file = f;
            s.written = 0;
        }
    }
}

/// An open span: created by [`enter`](SpanGuard::enter) (see the
/// [`crate::span!`] macro), closed by drop. Duration lands in the global
/// registry's `span.<name>` histogram; the JSONL event is emitted only
/// when tracing is armed.
pub struct SpanGuard {
    name: &'static str,
    /// Pre-rendered JSON object body (`"k":v,...`), empty when fieldless.
    fields: String,
    start: Instant,
    id: u64,
    parent: u64,
    /// ts at entry (ns since arm); only meaningful when `emit` is set.
    ts_ns: u64,
    emit: bool,
}

impl SpanGuard {
    /// Open a span. `fields` is a pre-rendered JSON fragment (the macro
    /// builds it); pass an empty string for a fieldless span.
    pub fn enter(name: &'static str, fields: String) -> SpanGuard {
        let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
        let parent = SPAN_STACK.with(|st| {
            let mut st = st.borrow_mut();
            let parent = st.last().copied().unwrap_or(0);
            st.push(id);
            parent
        });
        let emit = armed();
        let ts_ns = if emit {
            sink().as_ref().map(|s| s.anchor.elapsed().as_nanos() as u64).unwrap_or(0)
        } else {
            0
        };
        SpanGuard { name, fields, start: Instant::now(), id, parent, ts_ns, emit }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let dur_ns = self.start.elapsed().as_nanos() as u64;
        SPAN_STACK.with(|st| {
            let mut st = st.borrow_mut();
            // Pop our own id; tolerate out-of-order drops (guards moved
            // across scopes) by removing wherever it sits.
            if st.last() == Some(&self.id) {
                st.pop();
            } else if let Some(i) = st.iter().rposition(|&x| x == self.id) {
                st.remove(i);
            }
        });
        // Always-on histogram (the passive registry side).
        Registry::global().histogram(&format!("span.{}", self.name)).observe_ns(dur_ns);
        if self.emit && armed() {
            let thread = THREAD_ID.with(|t| *t);
            let mut line = format!(
                "{{\"ts_ns\":{},\"dur_ns\":{},\"span\":\"{}\",\"id\":{},\"parent\":{},\
                 \"thread\":{}",
                self.ts_ns, dur_ns, self.name, self.id, self.parent, thread
            );
            if !self.fields.is_empty() {
                line.push_str(",\"fields\":{");
                line.push_str(&self.fields);
                line.push('}');
            }
            line.push_str("}\n");
            emit(&line);
        }
    }
}

/// Open a telemetry span scoped to the enclosing block.
///
/// ```ignore
/// let _sp = span!("pipeline.shard_build", shard = s);
/// ```
///
/// Field values must render as JSON numbers (integers/floats). Bind the
/// guard (`let _sp = ...`) — an unbound `span!` drops immediately.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::core::telemetry::trace::SpanGuard::enter($name, String::new())
    };
    ($name:expr, $($k:ident = $v:expr),+ $(,)?) => {{
        let mut __f = String::new();
        $(
            {
                use std::fmt::Write as _;
                let _ = write!(__f, "\"{}\":{},", stringify!($k), $v);
            }
        )+
        __f.pop();
        $crate::core::telemetry::trace::SpanGuard::enter($name, __f)
    }};
}

/// One parsed trace event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Nanoseconds since arming (monotonic).
    pub ts_ns: u64,
    /// Span duration in nanoseconds.
    pub dur_ns: u64,
    /// Span name.
    pub span: String,
    /// Span id (process-unique).
    pub id: u64,
    /// Parent span id on the same thread (0 = root).
    pub parent: u64,
    /// Process-unique thread id.
    pub thread: u64,
}

fn json_u64(s: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let i = s.find(&pat)? + pat.len();
    let rest = &s[i..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn json_str(s: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":\"");
    let i = s.find(&pat)? + pat.len();
    let rest = &s[i..];
    Some(rest[..rest.find('"')?].to_string())
}

/// Parse one JSONL trace line; `None` for blank or malformed lines (the
/// summarizer counts those instead of failing).
pub fn parse_line(line: &str) -> Option<TraceEvent> {
    let line = line.trim();
    if line.is_empty() || !line.starts_with('{') || !line.ends_with('}') {
        return None;
    }
    Some(TraceEvent {
        ts_ns: json_u64(line, "ts_ns")?,
        dur_ns: json_u64(line, "dur_ns")?,
        span: json_str(line, "span")?,
        id: json_u64(line, "id")?,
        parent: json_u64(line, "parent")?,
        thread: json_u64(line, "thread")?,
    })
}

/// Per-span aggregate of a parsed trace.
#[derive(Debug, Clone, Default)]
pub struct SpanSummary {
    /// Event count.
    pub count: u64,
    /// Total duration (ns).
    pub total_ns: u64,
    /// Max duration (ns).
    pub max_ns: u64,
    /// Distinct thread ids seen.
    pub threads: Vec<u64>,
    /// Events that had a root parent (parent == 0).
    pub roots: u64,
}

/// Aggregate parsed events per span name. Returns `(per-span, malformed)`.
pub fn summarize(lines: impl Iterator<Item = String>) -> (BTreeMap<String, SpanSummary>, u64) {
    let mut out: BTreeMap<String, SpanSummary> = BTreeMap::new();
    let mut bad = 0u64;
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line) {
            Some(ev) => {
                let s = out.entry(ev.span).or_default();
                s.count += 1;
                s.total_ns += ev.dur_ns;
                s.max_ns = s.max_ns.max(ev.dur_ns);
                if !s.threads.contains(&ev.thread) {
                    s.threads.push(ev.thread);
                }
                if ev.parent == 0 {
                    s.roots += 1;
                }
            }
            None => bad += 1,
        }
    }
    (out, bad)
}

/// Read a trace file (prepending its `.1` rotation generation when
/// present) and render the per-span summary table `lgd trace summarize`
/// prints. Errors only on an unreadable primary file.
pub fn summarize_file(path: &Path) -> std::io::Result<String> {
    let mut text = String::new();
    let mut rot = path.as_os_str().to_os_string();
    rot.push(".1");
    if let Ok(t) = std::fs::read_to_string(PathBuf::from(rot)) {
        text.push_str(&t);
    }
    text.push_str(&std::fs::read_to_string(path)?);
    let (spans, bad) = summarize(text.lines().map(|l| l.to_string()));
    let total: u64 = spans.values().map(|s| s.count).sum();
    let mut out = String::new();
    out.push_str(&format!("trace: {total} events, {bad} malformed\n"));
    out.push_str(&format!(
        "{:<28} {:>8} {:>12} {:>12} {:>12} {:>8}\n",
        "span", "count", "total_ms", "mean_ms", "max_ms", "threads"
    ));
    for (name, s) in &spans {
        out.push_str(&format!(
            "{:<28} {:>8} {:>12.3} {:>12.3} {:>12.3} {:>8}\n",
            name,
            s.count,
            s.total_ns as f64 / 1e6,
            s.total_ns as f64 / 1e6 / s.count as f64,
            s.max_ns as f64 / 1e6,
            s.threads.len()
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    // Unique temp paths without wall-clock calls.
    static TMP_SEQ: AtomicU32 = AtomicU32::new(0);

    fn tmp(tag: &str) -> PathBuf {
        let n = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "lgd-trace-{}-{tag}-{n}.jsonl",
            std::process::id()
        ))
    }

    #[test]
    fn event_roundtrip_parse() {
        let line = "{\"ts_ns\":12,\"dur_ns\":34,\"span\":\"a.b\",\"id\":5,\"parent\":2,\
                    \"thread\":7,\"fields\":{\"shard\":3}}";
        let ev = parse_line(line).unwrap();
        assert_eq!(ev.ts_ns, 12);
        assert_eq!(ev.dur_ns, 34);
        assert_eq!(ev.span, "a.b");
        assert_eq!(ev.id, 5);
        assert_eq!(ev.parent, 2);
        assert_eq!(ev.thread, 7);
        assert!(parse_line("not json").is_none());
        assert!(parse_line("{\"span\":\"x\"}").is_none());
    }

    #[test]
    fn summarize_groups_and_counts_malformed() {
        let lines = vec![
            "{\"ts_ns\":0,\"dur_ns\":10,\"span\":\"a\",\"id\":1,\"parent\":0,\"thread\":1}"
                .to_string(),
            "{\"ts_ns\":1,\"dur_ns\":30,\"span\":\"a\",\"id\":2,\"parent\":1,\"thread\":2}"
                .to_string(),
            "garbage".to_string(),
        ];
        let (spans, bad) = summarize(lines.into_iter());
        assert_eq!(bad, 1);
        let a = &spans["a"];
        assert_eq!(a.count, 2);
        assert_eq!(a.total_ns, 40);
        assert_eq!(a.max_ns, 30);
        assert_eq!(a.threads.len(), 2);
        assert_eq!(a.roots, 1);
    }

    // The arm/emit tests share the global sink, so they run as one test
    // (cargo test parallelism would otherwise interleave their arming).
    #[test]
    fn emit_parse_summarize_roundtrip_and_rotation() {
        let path = tmp("roundtrip");
        arm(&path, 1 << 20).unwrap();
        {
            let _root = crate::span!("test.outer", step = 1);
            let _child = crate::span!("test.inner");
        }
        disarm();
        let text = std::fs::read_to_string(&path).unwrap();
        let evs: Vec<TraceEvent> = text.lines().filter_map(parse_line).collect();
        assert_eq!(evs.len(), 2, "trace: {text}");
        // Drop order: inner closes first; its parent is the outer's id.
        let inner = evs.iter().find(|e| e.span == "test.inner").unwrap();
        let outer = evs.iter().find(|e| e.span == "test.outer").unwrap();
        assert_eq!(inner.parent, outer.id);
        assert_eq!(outer.parent, 0);
        assert_eq!(inner.thread, outer.thread);
        let rendered = summarize_file(&path).unwrap();
        assert!(rendered.contains("test.outer"));
        assert!(rendered.contains("test.inner"));
        assert!(rendered.contains("0 malformed"));

        // Rotation: re-arm with a tiny budget and overflow it.
        let path2 = tmp("rotate");
        arm(&path2, 4096).unwrap();
        for _ in 0..64 {
            let _sp = crate::span!("test.rotate");
        }
        disarm();
        let mut rot = path2.as_os_str().to_os_string();
        rot.push(".1");
        let rot = PathBuf::from(rot);
        assert!(rot.exists(), "trace rotation generation missing");
        // Both generations still parse; the summarizer folds them.
        let rendered = summarize_file(&path2).unwrap();
        assert!(rendered.contains("test.rotate"));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&path2);
        let _ = std::fs::remove_file(&rot);
    }

    #[test]
    fn disarmed_spans_emit_nothing_but_still_time() {
        // No sink armed by this test; spans must be safe and silent.
        let before = Registry::global()
            .snapshot()
            .iter()
            .filter(|s| s.name == "span.test.disarmed")
            .count();
        let _ = before;
        {
            let _sp = crate::span!("test.disarmed");
        }
        // The histogram exists in the global registry even when disarmed.
        let flat = Registry::global().flat();
        assert!(flat.iter().any(|(n, v)| n == "span.test.disarmed.count" && *v >= 1.0));
    }
}
