//! Sampling-quality probes: streaming health of the LSH draw distribution.
//!
//! The Theorem-1 guarantee only holds if the sampler's *claimed*
//! probabilities match the distribution it actually draws from. These
//! probes watch that contract live, without touching the draw path's RNG
//! or ordering (bitwise-invisibility contract):
//!
//! - **rates** — fallback / exhausted fractions, mean probes per draw,
//!   mean accepted-bucket size;
//! - **occupancy skew** — draws are folded into 64 occupancy buckets by a
//!   fixed integer mix of the example index; `max/mean` over bucket counts
//!   exposes a sampler collapsing onto a few hot buckets;
//! - **TV-distance sketch** — each accepted draw of example `i` with
//!   claimed probability `p` contributes importance weight `w = 1/(p·N)`
//!   to its occupancy bucket over a sliding window. If the claimed
//!   probabilities are correct, the normalized per-bucket mass converges
//!   to the *uniform* mass of that bucket (computed exactly at arm time),
//!   for **any** sampling distribution — so the total-variation distance
//!   between the two is a direct drift detector for the
//!   probability-accounting itself, not a uniformity test of the sampler.
//!
//! Disarmed cost is one relaxed atomic load per hook (the failpoint-
//! registry bar). Armed cost is a handful of relaxed `fetch_add`s plus a
//! `try_lock` on the sketch — contention skips the sketch update rather
//! than blocking a draw thread.
//!
//! [`publish`] snapshots everything into registry gauges/counters under
//! the `probe.` prefix; it is called from the `METRICS` wire op and the
//! trainer's per-epoch capture.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::core::telemetry::registry::Registry;

/// Occupancy buckets for the skew / TV sketches.
pub const PROBE_BUCKETS: usize = 64;

static ARMED: AtomicBool = AtomicBool::new(false);

const ZERO: AtomicU64 = AtomicU64::new(0);
static DRAWS: AtomicU64 = ZERO;
static FALLBACKS: AtomicU64 = ZERO;
static EXHAUSTED: AtomicU64 = ZERO;
static PROBE_SUM: AtomicU64 = ZERO;
static BUCKET_SIZE_SUM: AtomicU64 = ZERO;
static SHARD_HITS: [AtomicU64; PROBE_BUCKETS] = [ZERO; PROBE_BUCKETS];
static OCCUPANCY: [AtomicU64; PROBE_BUCKETS] = [ZERO; PROBE_BUCKETS];

static SKETCH: Mutex<Option<TvSketch>> = Mutex::new(None);

fn sketch() -> MutexGuard<'static, Option<TvSketch>> {
    SKETCH.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Fixed integer mix (splitmix64 finalizer) folding an example index into
/// an occupancy bucket. Deterministic across runs by construction.
#[inline]
fn mix(i: u64) -> usize {
    let mut z = i.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    (z ^ (z >> 31)) as usize % PROBE_BUCKETS
}

/// Sliding-window importance-weighted estimate of per-bucket *uniform*
/// mass, compared against the exact uniform reference. Pure struct —
/// unit-testable without the global arming machinery.
#[derive(Debug, Clone)]
pub struct TvSketch {
    window: usize,
    rows: usize,
    /// Exact uniform mass per bucket: `|{i < rows : mix(i) == b}| / rows`.
    reference: [f64; PROBE_BUCKETS],
    /// Ring of (bucket, importance weight) for the live window.
    ring: std::collections::VecDeque<(usize, f64)>,
    mass: [f64; PROBE_BUCKETS],
    total: f64,
}

impl TvSketch {
    /// Build a sketch for a dataset of `rows` examples with the given
    /// window. The uniform reference is computed exactly by enumeration.
    pub fn new(window: usize, rows: usize) -> TvSketch {
        let mut reference = [0.0; PROBE_BUCKETS];
        for i in 0..rows {
            reference[mix(i as u64)] += 1.0;
        }
        for r in &mut reference {
            *r /= rows.max(1) as f64;
        }
        TvSketch {
            window: window.max(1),
            rows: rows.max(1),
            reference,
            ring: std::collections::VecDeque::new(),
            mass: [0.0; PROBE_BUCKETS],
            total: 0.0,
        }
    }

    /// Record one accepted draw: example `index`, claimed probability `p`.
    pub fn record(&mut self, index: usize, p: f64) {
        if !(p > 0.0) || !p.is_finite() {
            return;
        }
        let b = mix(index as u64);
        let w = 1.0 / (p * self.rows as f64);
        self.ring.push_back((b, w));
        self.mass[b] += w;
        self.total += w;
        while self.ring.len() > self.window {
            let (ob, ow) = self.ring.pop_front().unwrap();
            self.mass[ob] -= ow;
            self.total -= ow;
        }
    }

    /// Draws currently in the window.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// Is the window empty?
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Total-variation distance between the windowed importance-weighted
    /// mass and the exact uniform reference. `None` until the window holds
    /// at least a quarter of its capacity (early readings are noise).
    pub fn tv_distance(&self) -> Option<f64> {
        if self.ring.len() < (self.window + 3) / 4 || self.total <= 0.0 {
            return None;
        }
        let mut tv = 0.0;
        for b in 0..PROBE_BUCKETS {
            tv += (self.mass[b] / self.total - self.reference[b]).abs();
        }
        Some(tv / 2.0)
    }
}

/// Arm the probes for a dataset of `rows` examples, with a TV-sketch
/// window of `window` draws. Resets all probe state.
pub fn arm(window: usize, rows: usize) {
    DRAWS.store(0, Ordering::Relaxed);
    FALLBACKS.store(0, Ordering::Relaxed);
    EXHAUSTED.store(0, Ordering::Relaxed);
    PROBE_SUM.store(0, Ordering::Relaxed);
    BUCKET_SIZE_SUM.store(0, Ordering::Relaxed);
    for a in SHARD_HITS.iter().chain(OCCUPANCY.iter()) {
        a.store(0, Ordering::Relaxed);
    }
    *sketch() = Some(TvSketch::new(window, rows));
    ARMED.store(true, Ordering::Release);
}

/// Disarm the probes. Idempotent; state is kept until the next [`arm`].
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
}

/// Are the probes armed? One relaxed load — the hook guard.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Record an accepted LSH draw: owning shard, global example index, the
/// sampler's claimed probability, tables probed, and accepted bucket size.
/// No-op (one atomic load) when disarmed; never touches RNG state.
#[inline]
pub fn observe_hit(shard: usize, index: usize, prob: f64, probes: usize, bucket_size: usize) {
    if !armed() {
        return;
    }
    DRAWS.fetch_add(1, Ordering::Relaxed);
    PROBE_SUM.fetch_add(probes as u64, Ordering::Relaxed);
    BUCKET_SIZE_SUM.fetch_add(bucket_size as u64, Ordering::Relaxed);
    SHARD_HITS[shard % PROBE_BUCKETS].fetch_add(1, Ordering::Relaxed);
    OCCUPANCY[mix(index as u64)].fetch_add(1, Ordering::Relaxed);
    // try_lock: a contended sketch drops the observation instead of
    // stalling a draw thread.
    if let Ok(mut guard) = SKETCH.try_lock() {
        if let Some(s) = guard.as_mut() {
            s.record(index, prob);
        }
    }
}

/// Record a uniform fallback (empty LSH candidate set → uniform draw).
#[inline]
pub fn observe_fallback() {
    if !armed() {
        return;
    }
    DRAWS.fetch_add(1, Ordering::Relaxed);
    FALLBACKS.fetch_add(1, Ordering::Relaxed);
}

/// Record `k` exhausted sampling attempts (all probed buckets empty).
#[inline]
pub fn observe_exhausted(k: usize) {
    if !armed() {
        return;
    }
    EXHAUSTED.fetch_add(k as u64, Ordering::Relaxed);
}

/// Snapshot the probe state into `probe.*` gauges/counters on `reg`.
/// Also safe to call while disarmed (publishes the last armed state).
pub fn publish(reg: &Registry) {
    let draws = DRAWS.load(Ordering::Relaxed);
    let fallbacks = FALLBACKS.load(Ordering::Relaxed);
    let exhausted = EXHAUSTED.load(Ordering::Relaxed);
    let probe_sum = PROBE_SUM.load(Ordering::Relaxed);
    let bucket_sum = BUCKET_SIZE_SUM.load(Ordering::Relaxed);
    let hits = draws.saturating_sub(fallbacks);

    reg.gauge("probe.draws").set(draws as f64);
    let rate = |num: u64| if draws > 0 { num as f64 / draws as f64 } else { 0.0 };
    reg.gauge("probe.fallback_rate").set(rate(fallbacks));
    reg.gauge("probe.exhausted_rate").set(rate(exhausted));
    let per_hit = |num: u64| if hits > 0 { num as f64 / hits as f64 } else { 0.0 };
    reg.gauge("probe.probes_per_draw").set(per_hit(probe_sum));
    reg.gauge("probe.bucket_size_mean").set(per_hit(bucket_sum));

    // Occupancy skew: max / mean over non-degenerate bucket counts.
    let occ: Vec<u64> = OCCUPANCY.iter().map(|a| a.load(Ordering::Relaxed)).collect();
    let occ_total: u64 = occ.iter().sum();
    let occ_max = occ.iter().copied().max().unwrap_or(0);
    let mean = occ_total as f64 / PROBE_BUCKETS as f64;
    reg.gauge("probe.occupancy_max").set(occ_max as f64);
    reg.gauge("probe.occupancy_skew").set(if mean > 0.0 { occ_max as f64 / mean } else { 0.0 });

    // Per-shard acceptance share (only shards that saw traffic).
    let shard_total: u64 = SHARD_HITS.iter().map(|a| a.load(Ordering::Relaxed)).sum();
    if shard_total > 0 {
        for (s, a) in SHARD_HITS.iter().enumerate() {
            let n = a.load(Ordering::Relaxed);
            if n > 0 {
                reg.gauge_labeled("probe.shard_accept", &[("shard", &s.to_string())])
                    .set(n as f64 / shard_total as f64);
            }
        }
    }

    if let Some(s) = sketch().as_ref() {
        reg.gauge("probe.tv_window").set(s.len() as f64);
        if let Some(tv) = s.tv_distance() {
            reg.gauge("probe.tv_distance").set(tv);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_deterministic_and_in_range() {
        for i in 0..1000u64 {
            let b = mix(i);
            assert!(b < PROBE_BUCKETS);
            assert_eq!(b, mix(i));
        }
    }

    #[test]
    fn uniform_reference_sums_to_one() {
        let s = TvSketch::new(128, 5000);
        let sum: f64 = s.reference.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn correct_probabilities_give_small_tv() {
        // Draw uniformly with the *correct* claimed probability 1/N:
        // every draw gets weight 1, the windowed mass is the empirical
        // bucket frequency, which converges to the exact reference.
        let rows = 4096usize;
        let mut s = TvSketch::new(rows, rows);
        // Deterministic LCG so the test needs no RNG plumbing.
        let mut x = 12345u64;
        for _ in 0..rows {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let idx = (x >> 33) as usize % rows;
            s.record(idx, 1.0 / rows as f64);
        }
        let tv = s.tv_distance().expect("window warm");
        assert!(tv < 0.15, "uniform-with-correct-probs TV too large: {tv}");
    }

    #[test]
    fn wrong_probabilities_give_large_tv() {
        // Same uniform draws, but the claimed probability is biased 100x
        // for half the index space — the importance weights are wrong, so
        // the estimated uniform mass drifts far from the reference.
        let rows = 4096usize;
        let mut s = TvSketch::new(rows, rows);
        let mut x = 987654321u64;
        for _ in 0..rows {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let idx = (x >> 33) as usize % rows;
            let p = if idx < rows / 2 { 100.0 / rows as f64 } else { 1.0 / rows as f64 };
            s.record(idx, p);
        }
        let tv = s.tv_distance().expect("window warm");
        assert!(tv > 0.3, "biased claimed probs should inflate TV: {tv}");
    }

    #[test]
    fn sketch_window_slides() {
        let mut s = TvSketch::new(8, 100);
        for i in 0..20 {
            s.record(i, 0.01);
        }
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn degenerate_probabilities_are_ignored() {
        let mut s = TvSketch::new(8, 100);
        s.record(1, 0.0);
        s.record(2, -1.0);
        s.record(3, f64::NAN);
        s.record(4, f64::INFINITY);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn publish_writes_probe_gauges() {
        // Private registry: publish() reads global probe state, which other
        // tests may also touch — assert presence, not exact values.
        let reg = Registry::new();
        publish(&reg);
        let flat = reg.flat();
        for want in ["probe.draws", "probe.fallback_rate", "probe.exhausted_rate"] {
            assert!(flat.iter().any(|(n, _)| n == want), "missing {want}");
        }
    }
}
