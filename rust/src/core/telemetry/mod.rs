//! Unified observability substrate: one registry, four views.
//!
//! - [`registry`] — register-once counters/gauges/histograms behind
//!   lock-free handles, with a process-global instance;
//! - [`prom`] — Prometheus text exposition + strict validator;
//! - [`trace`] — `span!` scopes recording into histograms and (when
//!   armed) a rotating JSONL trace file;
//! - [`probes`] — streaming sampling-quality health (fallback/exhausted
//!   rates, occupancy skew, importance-weighted TV-distance sketch).
//!
//! Design contract: everything here is passive. Recording telemetry never
//! touches RNG state, never reorders draws, and never changes θ — armed
//! telemetry is bitwise invisible to a seeded run (enforced by the
//! determinism gates in the trainer and serving tests).

pub mod probes;
pub mod prom;
pub mod registry;
pub mod trace;

pub use prom::{render as render_prometheus, validate as validate_prometheus, PromSummary};
pub use registry::{
    CounterHandle, GaugeHandle, HistogramCore, HistogramHandle, MetricSample, Registry,
    SampleValue, HIST_BUCKETS,
};
pub use trace::{SpanGuard, TraceEvent};
