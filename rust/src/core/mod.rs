//! Foundational substrates: errors, PRNG, dense linear algebra, statistics.

pub mod error;
pub mod matrix;
pub mod rng;
pub mod stats;

pub use error::{Error, Result};
pub use matrix::Matrix;
pub use rng::{Pcg64, Rng, SplitMix64};
