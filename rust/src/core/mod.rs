//! Foundational substrates: errors, PRNG, aligned-block numerics, dense
//! linear algebra, statistics, telemetry.

pub mod error;
pub mod matrix;
pub mod numerics;
pub mod rng;
pub mod stats;
pub mod telemetry;

pub use error::{Error, Result};
pub use matrix::Matrix;
pub use numerics::{AlignedBlock, AlignedRows, KernelMode, LANES};
pub use rng::{Pcg64, Rng, SplitMix64};
