//! Learning-rate schedules (§2.2 "LGD with Adaptive Learning Rate"): fixed,
//! step decay and exponential decay — the schedules the paper cites [34] as
//! empirically effective, all composable with any estimator.

/// A learning-rate schedule: maps iteration t to a step size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Constant step size.
    Const(f64),
    /// `base · drop^(t / every)` — step decay.
    Step { base: f64, drop: f64, every: u64 },
    /// `base · e^(−rate · t)` — exponential decay.
    Exp { base: f64, rate: f64 },
    /// `base / (1 + rate · t)` — inverse time decay (Robbins–Monro style).
    InvTime { base: f64, rate: f64 },
}

impl Schedule {
    /// Step size at iteration `t` (0-based).
    #[inline]
    pub fn at(&self, t: u64) -> f64 {
        match *self {
            Schedule::Const(lr) => lr,
            Schedule::Step { base, drop, every } => {
                base * drop.powi((t / every.max(1)) as i32)
            }
            Schedule::Exp { base, rate } => base * (-rate * t as f64).exp(),
            Schedule::InvTime { base, rate } => base / (1.0 + rate * t as f64),
        }
    }

    /// Initial step size.
    pub fn base(&self) -> f64 {
        match *self {
            Schedule::Const(lr) => lr,
            Schedule::Step { base, .. } => base,
            Schedule::Exp { base, .. } => base,
            Schedule::InvTime { base, .. } => base,
        }
    }

    /// Scale the schedule's base step size in place (the health
    /// supervisor's `rollback_lr_factor` hook). Decay shape is untouched:
    /// `at(t)` afterwards is exactly `factor * at(t)` before.
    pub fn scale(&mut self, factor: f64) {
        match self {
            Schedule::Const(lr) => *lr *= factor,
            Schedule::Step { base, .. } => *base *= factor,
            Schedule::Exp { base, .. } => *base *= factor,
            Schedule::InvTime { base, .. } => *base *= factor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_is_flat() {
        let s = Schedule::Const(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1_000_000), 0.1);
    }

    #[test]
    fn step_decays_in_plateaus() {
        let s = Schedule::Step { base: 1.0, drop: 0.5, every: 10 };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(9), 1.0);
        assert_eq!(s.at(10), 0.5);
        assert_eq!(s.at(25), 0.25);
    }

    #[test]
    fn scale_multiplies_base_and_keeps_decay_shape() {
        let mut s = Schedule::Step { base: 1.0, drop: 0.5, every: 10 };
        s.scale(0.5);
        assert_eq!(s.at(0), 0.5);
        assert_eq!(s.at(10), 0.25);
        let mut c = Schedule::Const(0.2);
        c.scale(1.0);
        assert_eq!(c.at(3), 0.2);
        let mut e = Schedule::Exp { base: 0.4, rate: 0.01 };
        e.scale(0.25);
        assert_eq!(e.base(), 0.1);
    }

    #[test]
    fn exp_and_invtime_monotone() {
        for s in [
            Schedule::Exp { base: 0.5, rate: 0.01 },
            Schedule::InvTime { base: 0.5, rate: 0.1 },
        ] {
            let mut last = f64::INFINITY;
            for t in 0..100 {
                let v = s.at(t);
                assert!(v <= last && v > 0.0);
                last = v;
            }
            assert_eq!(s.base(), 0.5);
        }
    }
}
