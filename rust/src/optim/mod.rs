//! First-order optimizers. LGD is "not an alternative but a complement" to
//! adaptive learning-rate methods (§2.2): every optimizer here consumes the
//! (already importance-weighted) gradient estimate from *any*
//! [`crate::estimator::GradientEstimator`].

pub mod adagrad;
pub mod adam;
pub mod schedule;
pub mod sgd;

/// A stateful first-order update rule.
pub trait Optimizer: Send {
    /// Apply one update: `theta ← theta − step(grad)`.
    fn step(&mut self, theta: &mut [f32], grad: &[f32]);

    /// Reset internal state (accumulators, iteration counter).
    fn reset(&mut self);

    /// Name for logs.
    fn name(&self) -> &'static str;
}

pub use adagrad::AdaGrad;
pub use adam::Adam;
pub use schedule::Schedule;
pub use sgd::Sgd;
