//! First-order optimizers. LGD is "not an alternative but a complement" to
//! adaptive learning-rate methods (§2.2): every optimizer here consumes the
//! (already importance-weighted) gradient estimate from *any*
//! [`crate::estimator::GradientEstimator`].

pub mod adagrad;
pub mod adam;
pub mod schedule;
pub mod sgd;

use crate::core::error::{Error, Result};

/// Serializable optimizer state — the persistence-layer view every update
/// rule exports into `store::snapshot` and re-imports on warm start: the
/// step counter plus zero or more per-dimension moment slots (SGD: none;
/// AdaGrad: the squared-gradient accumulator; Adam: first and second
/// moments, in that order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OptimState {
    /// Steps taken so far (drives schedules and Adam bias correction).
    pub t: u64,
    /// Moment vectors, optimizer-defined order. Empty slots are legal —
    /// they mean "not yet sized" (no step taken since construction).
    pub slots: Vec<Vec<f64>>,
}

/// A stateful first-order update rule.
pub trait Optimizer: Send {
    /// Apply one update: `theta ← theta − step(grad)`.
    fn step(&mut self, theta: &mut [f32], grad: &[f32]);

    /// Reset internal state (accumulators, iteration counter).
    fn reset(&mut self);

    /// Name for logs.
    fn name(&self) -> &'static str;

    /// Multiply the learning rate by `factor`, leaving moments and the step
    /// counter untouched — the health supervisor's rollback hook
    /// (`health.rollback_lr_factor`). Scaling accumulated moments instead
    /// would warp Adam/AdaGrad's effective step nonlinearly; the base rate
    /// is the one knob every rule shares. Repeated calls compound. A
    /// factor of exactly 1.0 is bitwise a no-op (`x * 1.0 == x`).
    fn scale_lr(&mut self, factor: f64);

    /// Export internal state for a snapshot (step counter + moment slots).
    fn export_state(&self) -> OptimState;

    /// Restore state previously exported by the *same optimizer kind*.
    /// Errors (`Error::Store`) on a slot-count mismatch so a snapshot saved
    /// with one optimizer cannot silently warp another's update rule.
    fn import_state(&mut self, st: &OptimState) -> Result<()>;
}

/// Shared slot-count check for [`Optimizer::import_state`] implementations.
pub(crate) fn expect_slots(name: &str, st: &OptimState, want: usize) -> Result<()> {
    if st.slots.len() != want {
        return Err(Error::Store(format!(
            "{name} optimizer state expects {want} moment slot(s), snapshot has {}",
            st.slots.len()
        )));
    }
    Ok(())
}

pub use adagrad::AdaGrad;
pub use adam::Adam;
pub use schedule::Schedule;
pub use sgd::Sgd;

#[cfg(test)]
mod tests {
    use super::*;

    /// Every optimizer kind round-trips its state exactly: a restored
    /// optimizer continues with the same updates as the original.
    #[test]
    fn optimizer_state_roundtrips_and_continues_identically() {
        let mk: [fn() -> Box<dyn Optimizer>; 3] = [
            || Box::new(Sgd::new(Schedule::Step { base: 0.1, drop: 0.5, every: 3 })),
            || Box::new(AdaGrad::new(0.1)),
            || Box::new(Adam::new(0.05)),
        ];
        for f in mk {
            let mut a = f();
            let mut theta_a = vec![0.5f32; 4];
            for t in 0..7 {
                let g: Vec<f32> = (0..4).map(|j| (t + j) as f32 * 0.3 - 0.8).collect();
                a.step(&mut theta_a, &g);
            }
            let st = a.export_state();
            let mut b = f();
            b.import_state(&st).unwrap();
            assert_eq!(b.export_state(), st, "{}: state not reproduced", a.name());
            let mut theta_b = theta_a.clone();
            for t in 0..7 {
                let g: Vec<f32> = (0..4).map(|j| (t * j) as f32 * 0.1 - 0.2).collect();
                a.step(&mut theta_a, &g);
                b.step(&mut theta_b, &g);
            }
            assert_eq!(theta_a, theta_b, "{}: restored optimizer diverged", a.name());
        }
    }

    /// `scale_lr` multiplies exactly the base rate: factor 1.0 is a bitwise
    /// no-op on every rule, and a halved rate halves the (fresh-state)
    /// first step of every rule.
    #[test]
    fn scale_lr_scales_rate_and_unit_factor_is_identity() {
        let mk: [fn() -> Box<dyn Optimizer>; 3] = [
            || Box::new(Sgd::new(Schedule::Step { base: 0.1, drop: 0.5, every: 3 })),
            || Box::new(AdaGrad::new(0.1)),
            || Box::new(Adam::new(0.05)),
        ];
        for f in mk {
            let mut a = f();
            let mut b = f();
            b.scale_lr(1.0);
            let mut ta = vec![0.5f32; 4];
            let mut tb = ta.clone();
            for t in 0..5 {
                let g: Vec<f32> = (0..4).map(|j| (t + j) as f32 * 0.2 - 0.3).collect();
                a.step(&mut ta, &g);
                b.step(&mut tb, &g);
            }
            assert_eq!(ta, tb, "{}: factor 1.0 must be an exact no-op", a.name());
        }
        // first steps are lr-sized for all three rules, so halving shows up
        // directly (AdaGrad/Adam first step ≈ lr·sign(g))
        let mut o = Sgd::constant(0.1);
        o.scale_lr(0.5);
        let mut th = [0.0f32];
        o.step(&mut th, &[1.0]);
        assert!((th[0] + 0.05).abs() < 1e-7);
        let mut o = AdaGrad::new(0.1);
        o.scale_lr(0.5);
        let mut th = [0.0f32];
        o.step(&mut th, &[4.0]);
        assert!((th[0] + 0.05).abs() < 1e-5);
        let mut o = Adam::new(0.01);
        o.scale_lr(0.5);
        o.scale_lr(0.5); // compounds
        let mut th = [0.0f32];
        o.step(&mut th, &[5.0]);
        assert!((th[0] + 0.0025).abs() < 1e-4);
    }

    /// Slot-count mismatches are a loud `Error::Store`, not silent drift.
    #[test]
    fn optimizer_state_slot_mismatch_rejected() {
        let bad = OptimState { t: 3, slots: vec![vec![1.0]] };
        let mut o = Sgd::constant(0.1);
        assert!(matches!(
            o.import_state(&bad),
            Err(crate::core::error::Error::Store(_))
        ));
        let mut o = Adam::new(0.1);
        assert!(o.import_state(&bad).is_err(), "adam wants two slots");
        let mut o = AdaGrad::new(0.1);
        assert!(o.import_state(&OptimState { t: 0, slots: vec![vec![0.5]] }).is_ok());
    }
}
