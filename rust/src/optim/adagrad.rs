//! AdaGrad (Duchi et al., [14] in the paper): dimension-specific adaptive
//! learning rates from accumulated squared gradients. Used in the paper's
//! Figure 6/12/13 comparisons (LGD+AdaGrad vs SGD+AdaGrad).

use crate::core::error::Result;
use crate::optim::{expect_slots, OptimState, Optimizer};

/// `θ_i ← θ_i − lr · g_i / (√(Σ g_i²) + ε)`.
#[derive(Debug, Clone)]
pub struct AdaGrad {
    lr: f64,
    eps: f64,
    accum: Vec<f64>,
}

impl AdaGrad {
    /// Standard constructor (`eps` = 1e-8).
    pub fn new(lr: f64) -> Self {
        AdaGrad { lr, eps: 1e-8, accum: Vec::new() }
    }
}

impl Optimizer for AdaGrad {
    #[inline]
    fn step(&mut self, theta: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(theta.len(), grad.len());
        if self.accum.len() != theta.len() {
            self.accum = vec![0.0; theta.len()];
        }
        for i in 0..theta.len() {
            let g = grad[i] as f64;
            self.accum[i] += g * g;
            theta[i] -= (self.lr * g / (self.accum[i].sqrt() + self.eps)) as f32;
        }
    }

    fn reset(&mut self) {
        self.accum.clear();
    }

    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn scale_lr(&mut self, factor: f64) {
        self.lr *= factor;
    }

    fn export_state(&self) -> OptimState {
        OptimState { t: 0, slots: vec![self.accum.clone()] }
    }

    fn import_state(&mut self, st: &OptimState) -> Result<()> {
        expect_slots("adagrad", st, 1)?;
        self.accum = st.slots[0].clone();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        let mut o = AdaGrad::new(0.1);
        let mut theta = [0.0f32, 0.0];
        o.step(&mut theta, &[4.0, 0.5]);
        // accum = g², so step = lr·g/|g| = lr·sign(g)
        assert!((theta[0] + 0.1).abs() < 1e-5);
        assert!((theta[1] + 0.1).abs() < 1e-5);
    }

    #[test]
    fn per_dimension_adaptivity() {
        let mut o = AdaGrad::new(0.1);
        let mut theta = [0.0f32, 0.0];
        // dimension 0 sees large gradients repeatedly -> its effective lr shrinks
        for _ in 0..50 {
            o.step(&mut theta, &[10.0, 0.1]);
        }
        let before = theta;
        o.step(&mut theta, &[10.0, 0.1]);
        let step0 = (theta[0] - before[0]).abs();
        let step1 = (theta[1] - before[1]).abs();
        assert!(step0 < step1 * 1.01, "dim 0 step {step0} should not exceed dim 1 {step1}");
    }

    #[test]
    fn reset_clears_accumulators() {
        let mut o = AdaGrad::new(0.1);
        let mut theta = [0.0f32];
        o.step(&mut theta, &[100.0]);
        o.reset();
        let mut theta2 = [0.0f32];
        o.step(&mut theta2, &[100.0]);
        assert!((theta2[0] + 0.1).abs() < 1e-5, "after reset first step is lr-sized");
    }

    #[test]
    fn converges_on_quadratic() {
        let mut o = AdaGrad::new(0.5);
        let mut theta = [3.0f32];
        for _ in 0..500 {
            let g = [2.0 * theta[0]];
            o.step(&mut theta, &g);
        }
        assert!(theta[0].abs() < 0.05, "theta {}", theta[0]);
    }
}
