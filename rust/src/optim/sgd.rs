//! Plain gradient-descent update with a learning-rate schedule (eq. 2).

use crate::core::error::Result;
use crate::optim::{expect_slots, OptimState, Optimizer, Schedule};

/// `θ ← θ − η_t · g`.
#[derive(Debug, Clone)]
pub struct Sgd {
    schedule: Schedule,
    t: u64,
}

impl Sgd {
    /// With an explicit schedule.
    pub fn new(schedule: Schedule) -> Self {
        Sgd { schedule, t: 0 }
    }

    /// Fixed learning rate.
    pub fn constant(lr: f64) -> Self {
        Self::new(Schedule::Const(lr))
    }
}

impl Optimizer for Sgd {
    #[inline]
    fn step(&mut self, theta: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(theta.len(), grad.len());
        let lr = self.schedule.at(self.t) as f32;
        for i in 0..theta.len() {
            theta[i] -= lr * grad[i];
        }
        self.t += 1;
    }

    fn reset(&mut self) {
        self.t = 0;
    }

    fn name(&self) -> &'static str {
        "sgd-update"
    }

    fn scale_lr(&mut self, factor: f64) {
        self.schedule.scale(factor);
    }

    fn export_state(&self) -> OptimState {
        OptimState { t: self.t, slots: Vec::new() }
    }

    fn import_state(&mut self, st: &OptimState) -> Result<()> {
        expect_slots("sgd", st, 0)?;
        self.t = st.t;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_math() {
        let mut o = Sgd::constant(0.5);
        let mut theta = [1.0f32, -2.0];
        o.step(&mut theta, &[2.0, 2.0]);
        assert_eq!(theta, [0.0, -3.0]);
    }

    #[test]
    fn schedule_advances() {
        let mut o = Sgd::new(Schedule::Step { base: 1.0, drop: 0.5, every: 1 });
        let mut theta = [0.0f32];
        o.step(&mut theta, &[1.0]); // lr 1.0
        o.step(&mut theta, &[1.0]); // lr 0.5
        assert!((theta[0] + 1.5).abs() < 1e-6);
        o.reset();
        let mut theta2 = [0.0f32];
        o.step(&mut theta2, &[1.0]);
        assert!((theta2[0] + 1.0).abs() < 1e-6);
    }

    /// Converges on a trivial quadratic.
    #[test]
    fn converges_on_quadratic() {
        let mut o = Sgd::constant(0.1);
        let mut theta = [5.0f32];
        for _ in 0..200 {
            let g = [2.0 * theta[0]]; // d/dθ θ²
            o.step(&mut theta, &g);
        }
        assert!(theta[0].abs() < 1e-3);
    }
}
