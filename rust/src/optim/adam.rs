//! Adam optimizer — used by the paper's BERT fine-tuning experiments
//! (§3.2, "Adam optimizer with initial learning rate 2e-5").

use crate::core::error::Result;
use crate::optim::{expect_slots, OptimState, Optimizer};

/// Adam with bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    lr: f64,
    b1: f64,
    b2: f64,
    eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    /// Standard hyperparameters (β₁ = 0.9, β₂ = 0.999, ε = 1e-8).
    pub fn new(lr: f64) -> Self {
        Adam { lr, b1: 0.9, b2: 0.999, eps: 1e-8, m: Vec::new(), v: Vec::new(), t: 0 }
    }

    /// Fully parameterised constructor.
    pub fn with_params(lr: f64, b1: f64, b2: f64, eps: f64) -> Self {
        Adam { lr, b1, b2, eps, m: Vec::new(), v: Vec::new(), t: 0 }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, theta: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(theta.len(), grad.len());
        if self.m.len() != theta.len() {
            self.m = vec![0.0; theta.len()];
            self.v = vec![0.0; theta.len()];
        }
        self.t += 1;
        let b1t = 1.0 - self.b1.powi(self.t as i32);
        let b2t = 1.0 - self.b2.powi(self.t as i32);
        for i in 0..theta.len() {
            let g = grad[i] as f64;
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * g;
            self.v[i] = self.b2 * self.v[i] + (1.0 - self.b2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            theta[i] -= (self.lr * mhat / (vhat.sqrt() + self.eps)) as f32;
        }
    }

    fn reset(&mut self) {
        self.m.clear();
        self.v.clear();
        self.t = 0;
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn scale_lr(&mut self, factor: f64) {
        self.lr *= factor;
    }

    fn export_state(&self) -> OptimState {
        OptimState { t: self.t, slots: vec![self.m.clone(), self.v.clone()] }
    }

    fn import_state(&mut self, st: &OptimState) -> Result<()> {
        expect_slots("adam", st, 2)?;
        self.t = st.t;
        self.m = st.slots[0].clone();
        self.v = st.slots[1].clone();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        let mut o = Adam::new(0.01);
        let mut theta = [0.0f32, 0.0];
        o.step(&mut theta, &[5.0, -0.01]);
        // bias-corrected first step ≈ lr·sign(g)
        assert!((theta[0] + 0.01).abs() < 1e-4);
        assert!((theta[1] - 0.01).abs() < 1e-4);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut o = Adam::new(0.05);
        let mut theta = [4.0f32];
        for _ in 0..2000 {
            let g = [2.0 * theta[0]];
            o.step(&mut theta, &g);
        }
        assert!(theta[0].abs() < 0.01, "theta {}", theta[0]);
    }

    #[test]
    fn reset_restores_initial_behaviour() {
        let mut o = Adam::new(0.01);
        let mut t1 = [0.0f32];
        o.step(&mut t1, &[1.0]);
        o.reset();
        let mut t2 = [0.0f32];
        o.step(&mut t2, &[1.0]);
        assert!((t1[0] - t2[0]).abs() < 1e-9);
    }

    #[test]
    fn momentum_smooths_oscillation() {
        // alternating gradients: Adam's step magnitude shrinks as momentum cancels
        let mut o = Adam::new(0.1);
        let mut theta = [0.0f32];
        let mut prev = theta[0];
        let mut first_step = 0.0;
        let mut last_step = 0.0;
        for t in 0..100 {
            let g = [if t % 2 == 0 { 1.0 } else { -1.0 }];
            o.step(&mut theta, &g);
            let s = (theta[0] - prev).abs();
            if t == 0 {
                first_step = s;
            }
            last_step = s;
            prev = theta[0];
        }
        assert!(last_step < first_step, "momentum should damp alternating steps");
    }
}
