//! Figure 2/9: sample quality. Freeze θ after ¼ epoch of SGD warm-up, then
//! compare (a) the average gradient L2 norm of LGD-sampled vs
//! SGD-sampled points and (b) the angular similarity between each
//! estimator's gradient estimate and the true full gradient, as a function
//! of the number of averaged samples.

use crate::config::spec::{EstimatorKind, RunConfig};
use crate::coordinator::trainer::build_estimator;
use crate::core::error::Result;
use crate::core::matrix::{angular_similarity, axpy, norm2};
use crate::data::csv::CsvWriter;
use crate::data::preprocess::{preprocess, PreprocessOptions};
use crate::experiments::ExpOptions;
use crate::model::{LinReg, Model};

/// Warm-up: ¼ epoch of plain SGD from zero (the paper's protocol — a cold
/// random θ makes all gradients look alike).
fn warmup(pre: &crate::data::Preprocessed, lr: f32, seed: u64) -> Vec<f32> {
    let model = LinReg;
    let d = pre.data.dim();
    let mut theta = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    let mut cfg = RunConfig::default();
    cfg.train.estimator = EstimatorKind::Sgd;
    cfg.train.seed = seed;
    let mut est = build_estimator(&cfg, pre).unwrap();
    for _ in 0..(pre.data.len() / 4).max(50) {
        let w = est.draw(&theta);
        let (x, y) = pre.data.example(w.index);
        model.grad(x, y, &theta, &mut g);
        axpy(-lr, &g, &mut theta);
    }
    theta
}

/// Emit `fig9.csv`: dataset, samples, lgd_norm, sgd_norm, lgd_cos, sgd_cos.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let path = opts.out_dir.join("fig9.csv");
    let mut w = CsvWriter::create(
        &path,
        &["dataset", "samples", "lgd_norm", "sgd_norm", "lgd_cos", "sgd_cos"],
    )?;
    let sample_counts: &[usize] = if opts.quick {
        &[1, 5, 20]
    } else {
        &[1, 2, 5, 10, 20, 50, 100, 200]
    };
    let repeats = if opts.quick { 40 } else { 200 };

    for spec in crate::experiments::regression_specs(opts) {
        let ds = spec.generate()?;
        let pre = preprocess(ds, &PreprocessOptions::default())?;
        let theta = warmup(&pre, 0.05, opts.seed);
        let model = LinReg;
        let d = pre.data.dim();

        let mut full = vec![0.0f32; d];
        model.full_grad(&pre.data, &theta, &mut full);

        let mut cfg = RunConfig::default();
        
        cfg.train.seed = opts.seed ^ 0xF19;
        cfg.train.estimator = EstimatorKind::Lgd;
        let mut lgd = build_estimator(&cfg, &pre)?;
        cfg.train.estimator = EstimatorKind::Sgd;
        let mut sgd = build_estimator(&cfg, &pre)?;

        for &s in sample_counts {
            let mut norm_acc = [0.0f64; 2];
            let mut cos_acc = [0.0f64; 2];
            let mut g = vec![0.0f32; d];
            for _ in 0..repeats {
                for (which, est) in [&mut lgd, &mut sgd].into_iter().enumerate() {
                    let mut est_dir = vec![0.0f32; d];
                    let mut norm_sum = 0.0f64;
                    for _ in 0..s {
                        let dr = est.draw(&theta);
                        let (x, y) = pre.data.example(dr.index);
                        norm_sum += model.grad_norm(x, y, &theta);
                        model.grad(x, y, &theta, &mut g);
                        axpy((dr.weight / s as f64) as f32, &g, &mut est_dir);
                    }
                    norm_acc[which] += norm_sum / s as f64;
                    if norm2(&est_dir) > 0.0 {
                        cos_acc[which] += angular_similarity(&est_dir, &full);
                    }
                }
            }
            w.row_str(&[
                pre.data.name.clone(),
                s.to_string(),
                format!("{}", norm_acc[0] / repeats as f64),
                format!("{}", norm_acc[1] / repeats as f64),
                format!("{}", cos_acc[0] / repeats as f64),
                format!("{}", cos_acc[1] / repeats as f64),
            ])?;
        }
        println!("[fig9] {} done", pre.data.name);
    }
    w.flush()?;
    println!("[fig9] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's claim, as a test: LGD's sampled gradient norm exceeds
    /// SGD's, and its estimate is better aligned with the true gradient.
    #[test]
    fn lgd_beats_sgd_on_sample_quality() {
        let dir = std::env::temp_dir().join("lgd-fig9-test");
        let opts = ExpOptions {
            out_dir: dir.clone(),
            scale: 0.004,
            quick: true,
            ..Default::default()
        };
        run(&opts).unwrap();
        let text = std::fs::read_to_string(dir.join("fig9.csv")).unwrap();
        let mut lgd_norm_wins = 0usize;
        let mut rows = 0usize;
        for line in text.lines().skip(1) {
            let c: Vec<&str> = line.split(',').collect();
            let (ln, sn): (f64, f64) = (c[2].parse().unwrap(), c[3].parse().unwrap());
            if ln > sn {
                lgd_norm_wins += 1;
            }
            rows += 1;
        }
        assert_eq!(rows, 9); // 3 datasets x 3 sample counts
        assert!(
            lgd_norm_wins >= 7,
            "LGD sampled-gradient norm should beat SGD on most rows ({lgd_norm_wins}/9)"
        );
    }
}
