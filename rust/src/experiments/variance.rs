//! Lemma 1 / §2.3 variance experiment: trace of the estimator covariance,
//! LGD vs SGD, on power-law data (LGD should win) and on the uniform
//! Gaussian control (parity predicted by the paper's "uniform data" case).

use crate::config::spec::{EstimatorKind, RunConfig};
use crate::coordinator::trainer::build_estimator;
use crate::core::error::Result;
use crate::core::matrix::axpy;
use crate::data::csv::CsvWriter;
use crate::data::preprocess::{preprocess, PreprocessOptions};
use crate::data::SynthSpec;
use crate::estimator::variance::{empirical_trace, lemma1_sides, sgd_trace_closed_form};
use crate::experiments::ExpOptions;
use crate::model::{LinReg, Model};

/// Emit `variance.csv`: dataset, regime, sgd_trace_closed, sgd_trace_mc,
/// lgd_trace_mc, lemma1_lhs, lemma1_rhs, lemma1_holds.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let path = opts.out_dir.join("variance.csv");
    let mut w = CsvWriter::create(
        &path,
        &[
            "dataset",
            "regime",
            "sgd_trace_closed",
            "sgd_trace_mc",
            "lgd_trace_mc",
            "lemma1_lhs",
            "lemma1_rhs",
            "lemma1_holds",
        ],
    )?;
    let n = if opts.quick { 400 } else { 2000 };
    let trials = if opts.quick { 30_000 } else { 150_000 };
    let d = 16;
    let cases = [
        ("pareto", SynthSpec::power_law("pareto", n, d, opts.seed ^ 1)),
        ("uniform", SynthSpec::uniform_control("uniform", n, d, opts.seed ^ 2)),
    ];
    for (regime, spec) in cases {
        let ds = spec.generate()?;
        let pre = preprocess(ds, &PreprocessOptions::default())?;
        let model = LinReg;
        // warm-up θ as in fig9
        let mut theta = vec![0.0f32; d];
        {
            let mut cfg = RunConfig::default();
            cfg.train.estimator = EstimatorKind::Sgd;
            cfg.train.seed = opts.seed;
            let mut est = build_estimator(&cfg, &pre)?;
            let mut g = vec![0.0f32; d];
            for _ in 0..(n / 4).max(50) {
                let dr = est.draw(&theta);
                let (x, y) = pre.data.example(dr.index);
                model.grad(x, y, &theta, &mut g);
                axpy(-0.05, &g, &mut theta);
            }
        }

        let mut cfg = RunConfig::default();
        cfg.train.seed = opts.seed ^ 0x7A;
        cfg.train.estimator = EstimatorKind::Sgd;
        let mut sgd = build_estimator(&cfg, &pre)?;
        cfg.train.estimator = EstimatorKind::Lgd;
        if opts.quick {
            cfg.lsh.l = 25;
        }
        let mut lgd = build_estimator(&cfg, &pre)?;

        let closed = sgd_trace_closed_form(&model, &pre.data, &theta);
        let sgd_rep = empirical_trace(sgd.as_mut(), &model, &pre.data, &theta, trials);
        let lgd_rep = empirical_trace(lgd.as_mut(), &model, &pre.data, &theta, trials);
        let (lhs, rhs) = lemma1_sides(lgd.as_mut(), &model, &pre.data, &theta, trials);

        w.row_str(&[
            pre.data.name.clone(),
            regime.to_string(),
            format!("{closed}"),
            format!("{}", sgd_rep.trace_cov),
            format!("{}", lgd_rep.trace_cov),
            format!("{lhs}"),
            format!("{rhs}"),
            (lhs < rhs).to_string(),
        ])?;
        println!(
            "[variance] {regime}: SGD trace {:.4} vs LGD trace {:.4} (lemma1 holds: {})",
            sgd_rep.trace_cov,
            lgd_rep.trace_cov,
            lhs < rhs
        );
    }
    w.flush()?;
    println!("[variance] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_regime_favours_lgd() {
        let dir = std::env::temp_dir().join("lgd-variance-test");
        let opts = ExpOptions {
            out_dir: dir.clone(),
            quick: true,
            seed: 5,
            ..Default::default()
        };
        run(&opts).unwrap();
        let text = std::fs::read_to_string(dir.join("variance.csv")).unwrap();
        let rows: Vec<Vec<String>> = text
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(String::from).collect())
            .collect();
        assert_eq!(rows.len(), 2);
        // pareto row: lemma1 holds and LGD trace < SGD trace
        let pareto = &rows[0];
        assert_eq!(pareto[1], "pareto");
        let sgd_mc: f64 = pareto[3].parse().unwrap();
        let lgd_mc: f64 = pareto[4].parse().unwrap();
        assert!(lgd_mc < sgd_mc, "pareto: LGD trace {lgd_mc} !< SGD {sgd_mc}");
        assert_eq!(pareto[7], "true");
        // uniform row: traces within ~35% of each other (parity regime)
        let uni = &rows[1];
        let sgd_u: f64 = uni[3].parse().unwrap();
        let lgd_u: f64 = uni[4].parse().unwrap();
        let ratio = lgd_u / sgd_u;
        assert!(
            (0.4..2.5).contains(&ratio),
            "uniform regime should be near parity, ratio {ratio}"
        );
    }
}
