//! §2.2 running-time accounting and the §2.2.1 near-neighbor comparison:
//! per-draw cost of SGD vs LGD sampling (time and multiplication-equivalent
//! work) and the candidate-evaluation count of a full NN query — the work
//! LGD's sampling view avoids.

use std::time::Instant;

use crate::config::spec::{EstimatorKind, RunConfig};
use crate::coordinator::trainer::build_estimator;
use crate::core::error::Result;
use crate::core::matrix::axpy;
use crate::data::csv::CsvWriter;
use crate::data::preprocess::{preprocess, PreprocessOptions};
use crate::estimator::GradientEstimator;
use crate::experiments::ExpOptions;
use crate::lsh::sampler::LshSampler;
use crate::lsh::srp::SparseSrp;
use crate::lsh::tables::LshTables;
use crate::model::{LinReg, Model};

fn time_draws(est: &mut dyn GradientEstimator, theta: &[f32], draws: usize) -> f64 {
    let t0 = Instant::now();
    for _ in 0..draws {
        std::hint::black_box(est.draw(theta));
    }
    t0.elapsed().as_secs_f64() / draws as f64 * 1e9
}

/// Emit `sampling_cost.csv`: per-dataset draw costs and ratios.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let path = opts.out_dir.join("sampling_cost.csv");
    let mut w = CsvWriter::create(
        &path,
        &[
            "dataset",
            "dim",
            "sgd_draw_ns",
            "lgd_draw_ns",
            "grad_step_ns",
            "lgd_iter_over_sgd_iter",
            "lgd_mults_per_draw",
            "grad_mults",
            "oracle_draw_ns",
            "nn_query_evals",
            "table_build_secs",
        ],
    )?;
    let draws = if opts.quick { 3_000 } else { 30_000 };
    for spec in crate::experiments::regression_specs(opts) {
        let ds = spec.generate()?;
        let d = ds.dim();
        let pre = preprocess(ds, &PreprocessOptions::default())?;
        let model = LinReg;
        let theta = vec![0.01f32; d];

        let mut cfg = RunConfig::default();
        cfg.train.seed = opts.seed;
        
        if opts.quick {
            cfg.lsh.l = 25;
        }
        cfg.train.estimator = EstimatorKind::Sgd;
        let mut sgd = build_estimator(&cfg, &pre)?;
        cfg.train.estimator = EstimatorKind::Lgd;
        let t_build = Instant::now();
        let mut lgd = build_estimator(&cfg, &pre)?;
        let build_secs = t_build.elapsed().as_secs_f64();

        let sgd_ns = time_draws(sgd.as_mut(), &theta, draws);
        let lgd_ns = time_draws(lgd.as_mut(), &theta, draws);
        // the O(N) chicken-and-egg baseline (§1.1): exact optimal sampling
        let mut oracle = crate::estimator::OracleEstimator::new(
            &pre.data,
            Box::new(LinReg),
            opts.seed ^ 5,
        );
        let oracle_ns = time_draws(&mut oracle, &theta, (draws / 100).max(10));

        // Gradient-step cost: the d-multiplication baseline of §2.2.
        let mut g = vec![0.0f32; d];
        let t0 = Instant::now();
        for i in 0..draws {
            let (x, y) = pre.data.example(i % pre.data.len());
            model.grad(x, y, &theta, &mut g);
            axpy(-0.01, &g, &mut std::hint::black_box(&mut vec![0.0f32; d]));
        }
        let grad_ns = t0.elapsed().as_secs_f64() / draws as f64 * 1e9;

        let stats = lgd.stats();
        let mults_per_draw = stats.cost.mults / stats.draws.max(1) as f64;

        // NN query cost (§2.2.1): candidate evaluations of a full query.
        let hasher = SparseSrp::new(pre.hashed.cols(), cfg.lsh.k, cfg.lsh.l, cfg.lsh.density, 99);
        let tables =
            LshTables::build(hasher, (0..pre.data.len()).map(|i| pre.hashed.row(i)))?;
        let sampler = LshSampler::new(&tables, &pre.hashed);
        let mut q = Vec::new();
        pre.query(&theta, &mut q);
        let (_, evals) = sampler.nn_query(&q);

        let ratio = (lgd_ns + grad_ns) / (sgd_ns + grad_ns);
        w.row_str(&[
            pre.data.name.clone(),
            d.to_string(),
            format!("{sgd_ns:.1}"),
            format!("{lgd_ns:.1}"),
            format!("{grad_ns:.1}"),
            format!("{ratio:.3}"),
            format!("{mults_per_draw:.1}"),
            format!("{d}"),
            format!("{oracle_ns:.1}"),
            evals.to_string(),
            format!("{build_secs:.4}"),
        ])?;
        println!(
            "[sampling] {}: sgd {sgd_ns:.0}ns lgd {lgd_ns:.0}ns oracle {oracle_ns:.0}ns \
             grad {grad_ns:.0}ns iter-ratio {ratio:.2} nn-evals {evals}",
            pre.data.name
        );
    }
    w.flush()?;
    println!("[sampling] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §2.2's accounting: LGD's hash work per draw is well under the d
    /// multiplications of the gradient step, and a full NN query touches
    /// far more candidates than one LGD draw.
    #[test]
    fn lgd_sampling_cost_is_sublinear_in_gradient_cost() {
        let dir = std::env::temp_dir().join("lgd-sampling-test");
        let opts = ExpOptions {
            out_dir: dir.clone(),
            scale: 0.003,
            quick: true,
            seed: 3,
            ..Default::default()
        };
        run(&opts).unwrap();
        let text = std::fs::read_to_string(dir.join("sampling_cost.csv")).unwrap();
        for line in text.lines().skip(1) {
            let c: Vec<&str> = line.split(',').collect();
            let dim: f64 = c[1].parse().unwrap();
            let mults: f64 = c[6].parse().unwrap();
            let nn_evals: f64 = c[8].parse().unwrap();
            // dense hashing amortised over query_refresh=8 draws: per-draw
            // hash work stays within ~K·d/8 ≈ 0.7·d of the gradient's d
            // multiplications (the sparse family's d/6 figure is measured
            // by bench_hashing)
            assert!(
                mults < 1.2 * dim,
                "LGD amortised hash mults {mults} should stay near gradient cost {dim}"
            );
            assert!(nn_evals >= 1.0);
        }
    }
}
