//! Design-choice ablation: trace of the LGD estimator covariance across
//! hasher family × mirroring × weight clipping × projection density,
//! against the SGD baseline. This is the experiment that justifies the
//! repo's default configuration (DESIGN.md §Perf) — the paper's formula
//! probability `cp^K(1−cp^K)^{l−1}/|S_b|` assumes the exact angular
//! collision law, which very sparse projections only approximate; the
//! ablation quantifies what that approximation costs in estimator
//! variance.

use crate::config::spec::{EstimatorKind, RunConfig};
use crate::coordinator::trainer::build_estimator;
use crate::core::error::Result;
use crate::core::matrix::axpy;
use crate::data::csv::CsvWriter;
use crate::data::preprocess::{preprocess, PreprocessOptions};
use crate::data::SynthSpec;
use crate::estimator::lgd::{LgdEstimator, LgdOptions};
use crate::estimator::variance::empirical_trace;
use crate::experiments::ExpOptions;
use crate::lsh::srp::{DenseSrp, SparseSrp};
use crate::model::{LinReg, Model};

/// Emit `variance_ablation.csv`.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let path = opts.out_dir.join("variance_ablation.csv");
    let mut w = CsvWriter::create(
        &path,
        &["hasher", "density", "mirror", "clip", "lgd_trace", "sgd_trace", "ratio"],
    )?;
    let n = if opts.quick { 500 } else { 1500 };
    let d = 24;
    let trials = if opts.quick { 20_000 } else { 80_000 };
    let ds = SynthSpec::power_law("ablate", n, d, opts.seed ^ 9).generate()?;
    let pre = preprocess(ds, &PreprocessOptions::default())?;
    let model = LinReg;

    // warm-up θ
    let mut theta = vec![0.0f32; d];
    {
        let mut cfg = RunConfig::default();
        cfg.train.estimator = EstimatorKind::Sgd;
        cfg.train.seed = opts.seed;
        let mut est = build_estimator(&cfg, &pre)?;
        let mut g = vec![0.0f32; d];
        for _ in 0..(n / 4).max(50) {
            let dr = est.draw(&theta);
            let (x, y) = pre.data.example(dr.index);
            model.grad(x, y, &theta, &mut g);
            axpy(-0.05, &g, &mut theta);
        }
    }

    // SGD baseline
    let sgd_trace = {
        let mut cfg = RunConfig::default();
        cfg.train.estimator = EstimatorKind::Sgd;
        cfg.train.seed = opts.seed ^ 2;
        let mut sgd = build_estimator(&cfg, &pre)?;
        empirical_trace(sgd.as_mut(), &model, &pre.data, &theta, trials).trace_cov
    };

    let hd = pre.hashed.cols();
    let (k, l) = (5usize, if opts.quick { 25 } else { 50 });
    let densities = [("dense", 1.0f64), ("sparse", 0.25), ("sparse", 1.0 / 30.0)];
    for (fam, density) in densities {
        for mirror in [true, false] {
            for clip in [None, Some(5.0)] {
                let o = LgdOptions {
                    weight_clip: clip,
                    query_refresh: 1,
                    mirror,
                    ..LgdOptions::default()
                };
                let trace = if fam == "dense" {
                    let h = DenseSrp::new(hd, k, l, opts.seed ^ 3);
                    let mut e = LgdEstimator::new(&pre, h, opts.seed ^ 4, o)?;
                    empirical_trace(&mut e, &model, &pre.data, &theta, trials).trace_cov
                } else {
                    let h = SparseSrp::new(hd, k, l, density, opts.seed ^ 3);
                    let mut e = LgdEstimator::new(&pre, h, opts.seed ^ 4, o)?;
                    empirical_trace(&mut e, &model, &pre.data, &theta, trials).trace_cov
                };
                w.row_str(&[
                    fam.into(),
                    format!("{density:.4}"),
                    mirror.to_string(),
                    clip.map(|c| c.to_string()).unwrap_or_else(|| "none".into()),
                    format!("{trace:.6}"),
                    format!("{sgd_trace:.6}"),
                    format!("{:.3}", trace / sgd_trace),
                ])?;
                println!(
                    "[ablation] {fam} density={density:.4} mirror={mirror} clip={clip:?}: \
                     LGD trace {trace:.4} vs SGD {sgd_trace:.4} (ratio {:.2})",
                    trace / sgd_trace
                );
            }
        }
    }
    w.flush()?;
    println!("[ablation] wrote {}", path.display());
    Ok(())
}
