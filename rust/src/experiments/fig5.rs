//! Figure 5: mini-BERT fine-tuning with LGD vs SGD batch sampling on the
//! MRPC/RTE stand-in tasks — the full three-layer path: Pallas/JAX
//! artifacts (L1/L2) executed through PJRT by the Rust coordinator (L3),
//! with the Appendix-E scheme: pooled [CLS] representations hashed into
//! LSH tables, label-signed (mirroring the logistic embedding y·x), the
//! classifier decision direction as the query, and periodic refresh as
//! fine-tuning drifts the representations.

use crate::core::error::{Error, Result};
use crate::core::rng::{Pcg64, Rng};
use crate::data::csv::CsvWriter;
use crate::data::seq::{SeqDataset, SeqSpec};
use crate::experiments::ExpOptions;
use crate::lsh::sampler::{LshSampler, SampleCost, Sampled};
use crate::lsh::srp::DenseSrp;
use crate::lsh::tables::LshTables;
use crate::runtime::{BertSession, Runtime};
use crate::core::matrix::Matrix;

/// Per-epoch evaluation record.
struct EpochEval {
    train_loss: f64,
    test_loss: f64,
    test_acc: f64,
}

/// Compute pooled representations for all examples (chunked through the
/// fixed-batch artifact).
fn pooled_all(
    rt: &mut Runtime,
    sess: &BertSession,
    ds: &SeqDataset,
    idx: &[usize],
) -> Result<Matrix> {
    let b = sess.eval_batch();
    let t = ds.max_t;
    let d = sess.abi().d_model;
    let mut out = Matrix::zeros(0, 0);
    let mut ids = vec![0i32; b * t];
    let mut i = 0usize;
    while i < idx.len() {
        let take = (idx.len() - i).min(b);
        for r in 0..take {
            ids[r * t..(r + 1) * t].copy_from_slice(ds.row(idx[i + r]));
        }
        for r in take..b {
            ids[r * t..(r + 1) * t].fill(0);
        }
        let pooled = sess.pooled(rt, &ids)?;
        for r in 0..take {
            out.push_row(&pooled[r * d..(r + 1) * d])
                .map_err(|e| Error::Runtime(e.to_string()))?;
        }
        i += take;
    }
    Ok(out)
}

/// Mean CE loss + accuracy over a subset, via the logits artifact.
fn eval_subset(
    rt: &mut Runtime,
    sess: &BertSession,
    ds: &SeqDataset,
    idx: &[usize],
) -> Result<(f64, f64)> {
    let b = sess.eval_batch();
    let t = ds.max_t;
    let nc = sess.abi().n_classes;
    let mut ids = vec![0i32; b * t];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    let mut i = 0usize;
    while i < idx.len() {
        let take = (idx.len() - i).min(b);
        for r in 0..take {
            ids[r * t..(r + 1) * t].copy_from_slice(ds.row(idx[i + r]));
        }
        let logits = sess.logits(rt, &ids)?;
        for r in 0..take {
            let row = &logits[r * nc..(r + 1) * nc];
            let label = ds.labels[idx[i + r]] as usize;
            // stable log-softmax
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let z: f64 = row.iter().map(|&v| ((v - m) as f64).exp()).sum();
            loss += -((row[label] - m) as f64 - z.ln());
            let pred = if row[1] > row[0] { 1 } else { 0 };
            if pred == ds.labels[idx[i + r]] as usize {
                correct += 1;
            }
        }
        i += take;
    }
    Ok((loss / idx.len() as f64, correct as f64 / idx.len() as f64))
}

/// Fine-tune one task with one sampling strategy.
#[allow(clippy::too_many_arguments)]
fn finetune(
    rt: &mut Runtime,
    ds: &SeqDataset,
    train_idx: &[usize],
    test_idx: &[usize],
    use_lgd: bool,
    epochs: usize,
    lr: f64,
    seed: u64,
) -> Result<Vec<EpochEval>> {
    let mut sess = BertSession::new(rt, lr)?;
    let b = sess.grad_batch();
    let t = ds.max_t;
    let d = sess.abi().d_model;
    let steps_per_epoch = (train_idx.len() / b).max(1);
    // Appendix E: refresh the hashed representations periodically — the
    // representations "do not change drastically in every iteration".
    let refresh_every = (steps_per_epoch / 2).max(1);
    let (k, l) = (7usize, 10usize); // §3.2: K=7, L=10
    let mut rng = Pcg64::new(seed, 0xF165);

    let mut ids = vec![0i32; b * t];
    let mut labels = vec![0i32; b];
    let mut weights = vec![1.0f32; b];
    let mut evals = Vec::new();

    // signed pooled representations + tables (LGD arm only)
    let mut hashed: Option<(Matrix, LshTables<DenseSrp>)> = None;
    let refresh = |rt: &mut Runtime, sess: &BertSession| -> Result<(Matrix, LshTables<DenseSrp>)> {
        let pooled = pooled_all(rt, sess, ds, train_idx)?;
        // label-signed embedding: v_i = (2y−1)·pooled_i (mirrors y·x of eq. 11)
        let mut m = Matrix::zeros(0, 0);
        for (r, &gi) in train_idx.iter().enumerate() {
            let sign = (2 * ds.labels[gi] - 1) as f32;
            let row: Vec<f32> = pooled.row(r).iter().map(|v| sign * v).collect();
            m.push_row(&row).map_err(|e| Error::Runtime(e.to_string()))?;
        }
        let hasher = DenseSrp::new(d, k, l, seed ^ 0xB417);
        let tables = LshTables::build(hasher, (0..m.rows()).map(|i| m.row(i)))
            .map_err(|e| Error::Runtime(e.to_string()))?;
        Ok((m, tables))
    };

    for epoch in 0..epochs {
        for step in 0..steps_per_epoch {
            if use_lgd && (step % refresh_every == 0 || hashed.is_none()) {
                hashed = Some(refresh(rt, &sess)?);
            }
            // --- select the batch ---
            if use_lgd {
                let (m, tables) = hashed.as_ref().unwrap();
                // query: −(decision direction) in pooled space — examples
                // whose signed rep aligns with it have small margins (large
                // gradients). Derived from the classifier weights, which is
                // Appendix E's "parameters in the classification layer are
                // used as queries".
                let q = classifier_query(&sess, rt)?;
                let sampler = LshSampler::new(tables, m);
                let mut cost = SampleCost::default();
                let mut got = 0usize;
                let mut wsum = 0.0f64;
                let mut draws = Vec::with_capacity(b);
                while got < b {
                    match sampler.sample(&q, &mut rng, &mut cost) {
                        Sampled::Hit(dr) => {
                            draws.push((dr.index, 1.0 / (dr.prob * train_idx.len() as f64)));
                            wsum += draws.last().unwrap().1;
                            got += 1;
                        }
                        Sampled::Exhausted { .. } => {
                            let i = rng.index(train_idx.len());
                            draws.push((i, 1.0));
                            wsum += 1.0;
                            got += 1;
                        }
                    }
                }
                // normalise weights to mean 1 (keeps the CE loss scale and
                // the Adam step size comparable with the SGD arm)
                let wmean = wsum / b as f64;
                for (r, (local, wt)) in draws.iter().enumerate() {
                    let gi = train_idx[*local];
                    ids[r * t..(r + 1) * t].copy_from_slice(ds.row(gi));
                    labels[r] = ds.labels[gi];
                    weights[r] = (*wt / wmean) as f32;
                }
            } else {
                for r in 0..b {
                    let gi = train_idx[rng.index(train_idx.len())];
                    ids[r * t..(r + 1) * t].copy_from_slice(ds.row(gi));
                    labels[r] = ds.labels[gi];
                    weights[r] = 1.0;
                }
            }
            sess.step(rt, &ids, &labels, &weights)?;
        }
        let (train_loss, _) = eval_subset(rt, &sess, ds, train_idx)?;
        let (test_loss, test_acc) = eval_subset(rt, &sess, ds, test_idx)?;
        println!(
            "[fig5] {} epoch {}: train_loss {train_loss:.4} test_loss {test_loss:.4} \
             acc {test_acc:.3} ({})",
            ds.name,
            epoch + 1,
            if use_lgd { "lgd" } else { "sgd" },
        );
        evals.push(EpochEval { train_loss, test_loss, test_acc });
    }
    Ok(evals)
}

/// Query vector from the classifier parameters (Appendix E).
fn classifier_query(sess: &BertSession, _rt: &mut Runtime) -> Result<Vec<f32>> {
    // cls_w is the second-to-last ABI parameter: (d_model, 2); decision
    // direction = w[:,1] − w[:,0]; query = −direction (targets small/negative
    // margins = large gradients under the signed embedding).
    let abi = sess.abi();
    let idx = abi
        .param_names
        .iter()
        .position(|n| n == "cls_w")
        .ok_or_else(|| Error::Runtime("no cls_w in ABI".into()))?;
    let w = sess.param(idx);
    let d = abi.d_model;
    let mut q = vec![0.0f32; d];
    for i in 0..d {
        q[i] = -(w[i * abi.n_classes + 1] - w[i * abi.n_classes]);
    }
    Ok(q)
}

/// Emit `fig5.csv`: task, estimator, epoch, train_loss, test_loss, test_acc.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let artifacts = opts
        .artifacts
        .clone()
        .unwrap_or_else(crate::runtime::default_artifacts_dir);
    let mut rt = Runtime::new(&artifacts)?;
    let path = opts.out_dir.join("fig5.csv");
    let mut w = CsvWriter::create(
        &path,
        &["task", "estimator", "epoch", "train_loss", "test_loss", "test_acc"],
    )?;
    let scale = if opts.quick { 0.05 } else { opts.scale.max(0.25) };
    let epochs = if opts.quick { 1 } else { 3 };
    let vocab = rt.manifest().bert.as_ref().map(|b| b.vocab).unwrap_or(1024);
    let max_t = rt.manifest().bert.as_ref().map(|b| b.max_t).unwrap_or(32);
    let tasks = [
        SeqSpec::mrpc_like(scale, vocab, max_t, opts.seed ^ 0x51),
        SeqSpec::rte_like(scale, vocab, max_t, opts.seed ^ 0x52),
    ];
    for spec in tasks {
        let ds = spec.generate();
        let (tr, te) = ds.split(0.9, opts.seed)?;
        for use_lgd in [true, false] {
            let evals = finetune(
                &mut rt,
                &ds,
                &tr,
                &te,
                use_lgd,
                epochs,
                2e-4, // Adam; scaled from the paper's 2e-5 for the mini model
                opts.seed ^ 0x53,
            )?;
            for (e, ev) in evals.iter().enumerate() {
                w.row_str(&[
                    ds.name.clone(),
                    if use_lgd { "lgd".into() } else { "sgd".into() },
                    (e + 1).to_string(),
                    format!("{}", ev.train_loss),
                    format!("{}", ev.test_loss),
                    format!("{}", ev.test_acc),
                ])?;
            }
        }
    }
    w.flush()?;
    println!("[fig5] wrote {}", path.display());
    Ok(())
}
