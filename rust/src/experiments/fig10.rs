//! Figures 3/10 & 11 (plain) and 6/12 & 13 (AdaGrad): training/testing loss
//! convergence of LGD vs SGD, both epoch-wise and wall-clock-wise, on the
//! three regression workloads. One CSV per family; figures 10 vs 11 (and
//! 12 vs 13) are the train vs test columns of the same runs.

use crate::config::spec::{EstimatorKind, OptimizerKind, RunConfig};
use crate::coordinator::trainer::{train, GradSource};
use crate::core::error::Result;
use crate::data::csv::CsvWriter;
use crate::data::preprocess::{preprocess, PreprocessOptions};
use crate::experiments::ExpOptions;
use crate::optim::Schedule;

/// Run the convergence family. `adagrad = false` → fig10/11 CSV,
/// `adagrad = true` → fig12/13 CSV.
pub fn run(opts: &ExpOptions, adagrad: bool) -> Result<()> {
    let fname = if adagrad { "fig12_13.csv" } else { "fig10_11.csv" };
    let path = opts.out_dir.join(fname);
    let mut w = CsvWriter::create(
        &path,
        &[
            "dataset",
            "estimator",
            "optimizer",
            "iter",
            "epoch",
            "wall_secs",
            "train_loss",
            "test_loss",
        ],
    )?;
    let epochs = if opts.quick { 3 } else { 8 };
    // The paper sweeps 1e-5..1e-1 and picks the convergent rate; on the
    // normalised synthetic workloads 0.05 (plain) / 0.1 (adagrad) converge
    // for both estimators across all three datasets.
    let lr = if adagrad { 0.1 } else { 0.05 };

    for spec in crate::experiments::regression_specs(opts) {
        let ds = spec.generate()?;
        let (tr, te) = ds.split(0.9, opts.seed)?;
        let pre = preprocess(tr, &PreprocessOptions::default())?;
        for est in [EstimatorKind::Lgd, EstimatorKind::Sgd] {
            let mut cfg = RunConfig::default();
            cfg.name = format!("{}-{:?}", spec.name, est);
            cfg.train.estimator = est;
            cfg.train.optimizer =
                if adagrad { OptimizerKind::AdaGrad } else { OptimizerKind::Sgd };
            cfg.train.schedule = Schedule::Const(lr);
            cfg.train.epochs = epochs;
            cfg.train.seed = opts.seed ^ 0x10;
            cfg.lsh.seed = opts.seed ^ 0x11;
            if opts.quick {
                cfg.lsh.l = 25;
            }
            let out = train(&cfg, &pre, &te, GradSource::Native)?;
            for p in &out.curve {
                w.row_str(&[
                    spec.name.clone(),
                    out.estimator.clone(),
                    if adagrad { "adagrad".into() } else { "sgd-update".into() },
                    p.iter.to_string(),
                    format!("{}", p.epoch),
                    format!("{}", p.wall),
                    format!("{}", p.train_loss),
                    format!("{}", p.test_loss),
                ])?;
            }
            println!(
                "[{}] {} {est:?}: loss {:.4} -> {:.4} in {:.2}s ({} iters, {} fallbacks)",
                if adagrad { "fig12" } else { "fig10" },
                spec.name,
                out.curve.first().unwrap().train_loss,
                out.curve.last().unwrap().train_loss,
                out.wall_secs,
                out.iterations,
                out.est_stats.fallbacks,
            );
        }
    }
    w.flush()?;
    println!("[{}] wrote {}", if adagrad { "fig12" } else { "fig10" }, path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke-scale reproduction check: at tiny scale both estimators must
    /// converge stably and land in the same loss regime (the strict
    /// LGD-faster claims are validated at full scale in EXPERIMENTS.md —
    /// at a few hundred examples the adaptive-sampling signal is within
    /// Monte-Carlo noise).
    #[test]
    fn lgd_converges_at_least_as_fast_epochwise() {
        let dir = std::env::temp_dir().join("lgd-fig10-test");
        let opts = ExpOptions {
            out_dir: dir.clone(),
            scale: 0.005,
            quick: true,
            seed: 7,
            ..Default::default()
        };
        run(&opts, false).unwrap();
        let text = std::fs::read_to_string(dir.join("fig10_11.csv")).unwrap();
        // final train loss per (dataset, estimator)
        let mut last: std::collections::BTreeMap<(String, String), f64> = Default::default();
        for line in text.lines().skip(1) {
            let c: Vec<&str> = line.split(',').collect();
            last.insert((c[0].into(), c[1].into()), c[6].parse().unwrap());
        }
        // first-curve-point losses per dataset for the stability check
        let mut first: std::collections::BTreeMap<(String, String), f64> = Default::default();
        for line in text.lines().skip(1) {
            let c: Vec<&str> = line.split(',').collect();
            first.entry((c[0].into(), c[1].into())).or_insert(c[6].parse().unwrap());
        }
        let mut same_regime = 0;
        for ds in ["yearmsd-like", "slice-like", "ujiindoor-like"] {
            let lgd = last[&(ds.to_string(), "lgd".to_string())];
            let sgd = last[&(ds.to_string(), "sgd".to_string())];
            let lgd0 = first[&(ds.to_string(), "lgd".to_string())];
            assert!(lgd < lgd0, "{ds}: LGD did not descend ({lgd0} -> {lgd})");
            if lgd <= sgd * 1.6 {
                same_regime += 1;
            }
        }
        assert!(same_regime >= 2, "LGD should land in SGD's loss regime on ≥2/3 datasets");
    }
}
