//! Table 4: dataset statistics — paper sizes vs the generated stand-ins.

use crate::core::error::Result;
use crate::data::csv::CsvWriter;
use crate::data::seq::SeqSpec;
use crate::experiments::ExpOptions;

/// Paper-reported (train, test, dim) per dataset.
const PAPER: &[(&str, usize, usize, usize)] = &[
    ("yearmsd-like", 463_715, 51_630, 90),
    ("slice-like", 53_500, 42_800, 385),
    ("ujiindoor-like", 10_534, 10_534, 529),
    ("mrpc-like", 3_669, 409, 0),
    ("rte-like", 2_491, 278, 0),
];

/// Emit `table4.csv`: dataset, paper_train, paper_test, paper_dim,
/// generated_n, generated_dim.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let path = opts.out_dir.join("table4.csv");
    let mut w = CsvWriter::create(
        &path,
        &["dataset", "paper_train", "paper_test", "paper_dim", "gen_n", "gen_dim"],
    )?;
    let specs = crate::data::paper_specs(opts.scale, opts.seed);
    for (i, (name, ptr, pte, pd)) in PAPER.iter().enumerate() {
        let (gen_n, gen_d) = if i < 3 {
            (specs[i].n, specs[i].d)
        } else if i == 3 {
            let s = SeqSpec::mrpc_like(opts.scale.max(0.05), 1024, 32, opts.seed);
            (s.n, 0)
        } else {
            let s = SeqSpec::rte_like(opts.scale.max(0.05), 1024, 32, opts.seed);
            (s.n, 0)
        };
        w.row_str(&[
            name.to_string(),
            ptr.to_string(),
            pte.to_string(),
            pd.to_string(),
            gen_n.to_string(),
            gen_d.to_string(),
        ])?;
    }
    w.flush()?;
    println!("[table4] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_five_rows() {
        let dir = std::env::temp_dir().join("lgd-table4-test");
        let opts = ExpOptions { out_dir: dir.clone(), scale: 0.01, ..Default::default() };
        run(&opts).unwrap();
        let text = std::fs::read_to_string(dir.join("table4.csv")).unwrap();
        assert_eq!(text.lines().count(), 6); // header + 5
        assert!(text.contains("yearmsd-like,463715,51630,90"));
    }
}
