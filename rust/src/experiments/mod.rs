//! Experiment drivers: one module per table/figure of the paper's
//! evaluation, each regenerating the corresponding series as CSV under
//! `results/` (see DESIGN.md §5 for the experiment index).

pub mod fig10;
pub mod fig5;
pub mod fig9;
pub mod sampling;
pub mod table4;
pub mod variance;
pub mod variance_ablation;

use std::path::PathBuf;

use crate::core::error::{Error, Result};

/// Options shared by all experiment drivers.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Scale factor on the paper's dataset sizes.
    pub scale: f64,
    /// Output directory for CSV series.
    pub out_dir: PathBuf,
    /// Master seed.
    pub seed: u64,
    /// Quick mode: smaller datasets / fewer repeats (CI smoke).
    pub quick: bool,
    /// Artifacts dir override for PJRT-backed experiments.
    pub artifacts: Option<PathBuf>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: 0.02,
            out_dir: PathBuf::from("results"),
            seed: 42,
            quick: false,
            artifacts: None,
        }
    }
}

/// Run an experiment by id. `all` runs everything except the PJRT-gated
/// fig5 unless artifacts are present.
pub fn run(id: &str, opts: &ExpOptions) -> Result<()> {
    match id {
        "table4" => table4::run(opts),
        "fig9" => fig9::run(opts),
        "fig10" | "fig11" => fig10::run(opts, false),
        "fig12" | "fig13" => fig10::run(opts, true),
        "variance" => variance::run(opts),
        "variance-ablation" => variance_ablation::run(opts),
        "sampling" => sampling::run(opts),
        "fig5" => fig5::run(opts),
        "all" => {
            table4::run(opts)?;
            fig9::run(opts)?;
            fig10::run(opts, false)?;
            fig10::run(opts, true)?;
            variance::run(opts)?;
            sampling::run(opts)?;
            let artifacts = opts
                .artifacts
                .clone()
                .unwrap_or_else(crate::runtime::default_artifacts_dir);
            if artifacts.join("manifest.json").exists() {
                fig5::run(opts)?;
            } else {
                println!("[all] skipping fig5: no artifacts at {}", artifacts.display());
            }
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown experiment '{other}' (have: table4, fig9, fig10, fig11, fig12, fig13, \
             variance, sampling, fig5, all)"
        ))),
    }
}

/// The three paper regression workloads at the configured scale.
pub(crate) fn regression_specs(opts: &ExpOptions) -> Vec<crate::data::SynthSpec> {
    let scale = if opts.quick { (opts.scale * 0.25).max(0.002) } else { opts.scale };
    crate::data::paper_specs(scale, opts.seed)
        .into_iter()
        .take(3)
        .collect()
}
