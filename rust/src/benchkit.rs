//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//! ```ignore
//! let mut b = Bench::new("sampling");
//! b.bench("lgd_draw_d90", || { ... });
//! b.report();
//! ```
//! Each benchmark is auto-calibrated (target ~0.4 s per measurement), runs
//! `reps` measured batches and reports median/p95 ns per iteration.

use std::collections::BTreeMap;
use std::hint::black_box;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::config::json::Json;

/// One benchmark result row.
#[derive(Debug, Clone)]
pub struct BenchRow {
    /// Benchmark name.
    pub name: String,
    /// Median ns/iteration.
    pub median_ns: f64,
    /// p95 ns/iteration.
    pub p95_ns: f64,
    /// Iterations per measured batch.
    pub iters: u64,
}

/// A named group of benchmarks with a common report.
pub struct Bench {
    group: String,
    rows: Vec<BenchRow>,
    /// Named numeric counters attached to the group (mults/draw, probe
    /// counts, hash invocations…) — the machine-readable side channel the
    /// `BENCH_*.json` perf-trajectory files carry alongside timings.
    notes: Vec<(String, f64)>,
    /// Measured batches per benchmark.
    pub reps: usize,
    /// Target seconds per measured batch during calibration.
    pub target_secs: f64,
}

/// Re-export of `std::hint::black_box` for benchmark bodies.
pub fn bb<T>(x: T) -> T {
    black_box(x)
}

impl Bench {
    /// New group.
    pub fn new(group: &str) -> Self {
        let mut b = Bench {
            group: group.to_string(),
            rows: Vec::new(),
            notes: Vec::new(),
            reps: 15,
            target_secs: 0.2,
        };
        // Quick mode for CI: LGD_BENCH_FAST=1 shrinks the measurement.
        if std::env::var("LGD_BENCH_FAST").is_ok() {
            b.reps = 5;
            b.target_secs = 0.02;
        }
        b
    }

    /// Run one benchmark; `f` is a single iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchRow {
        // Calibrate: how many iterations fit in target_secs?
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt >= self.target_secs / 4.0 || iters >= 1 << 30 {
                let scale = (self.target_secs / dt.max(1e-9)).clamp(1.0, 1e6);
                iters = ((iters as f64) * scale).ceil() as u64;
                break;
            }
            iters *= 8;
        }
        // Measure.
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64 * 1e9);
        }
        let median = crate::core::stats::median(&samples);
        let p95 = crate::core::stats::quantile(&samples, 0.95);
        self.rows.push(BenchRow { name: name.to_string(), median_ns: median, p95_ns: p95, iters });
        self.rows.last().unwrap()
    }

    /// Record an externally measured value (e.g. whole-run seconds).
    pub fn record(&mut self, name: &str, ns_per_iter: f64) {
        self.rows.push(BenchRow {
            name: name.to_string(),
            median_ns: ns_per_iter,
            p95_ns: ns_per_iter,
            iters: 1,
        });
    }

    /// Results so far.
    pub fn rows(&self) -> &[BenchRow] {
        &self.rows
    }

    /// Attach a named numeric counter (overwrites an earlier note of the
    /// same name, so loops can record their final value).
    pub fn note(&mut self, name: &str, value: f64) {
        if let Some(slot) = self.notes.iter_mut().find(|(n, _)| n == name) {
            slot.1 = value;
        } else {
            self.notes.push((name.to_string(), value));
        }
    }

    /// Notes so far.
    pub fn notes(&self) -> &[(String, f64)] {
        &self.notes
    }

    /// Print the group report (aligned table + counters).
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!("{:<44} {:>14} {:>14} {:>10}", "name", "median ns/it", "p95 ns/it", "iters");
        for r in &self.rows {
            println!(
                "{:<44} {:>14.1} {:>14.1} {:>10}",
                r.name, r.median_ns, r.p95_ns, r.iters
            );
        }
        if !self.notes.is_empty() {
            println!("{:<44} {:>14}", "counter", "value");
            for (n, v) in &self.notes {
                println!("{n:<44} {v:>14.3}");
            }
        }
    }

    /// Serialize the group (rows + counters) as JSON — the machine-readable
    /// perf-trajectory format future PRs regress against.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("group".to_string(), Json::Str(self.group.clone()));
        root.insert("source".to_string(), Json::Str("measured".to_string()));
        let rows = self
            .rows
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(r.name.clone()));
                m.insert("median_ns".to_string(), Json::Num(r.median_ns));
                m.insert("p95_ns".to_string(), Json::Num(r.p95_ns));
                m.insert("iters".to_string(), Json::Num(r.iters as f64));
                Json::Obj(m)
            })
            .collect();
        root.insert("rows".to_string(), Json::Arr(rows));
        let mut notes = BTreeMap::new();
        for (n, v) in &self.notes {
            notes.insert(n.clone(), Json::Num(*v));
        }
        root.insert("counters".to_string(), Json::Obj(notes));
        Json::Obj(root)
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
    }
}

/// Outcome of a counter-regression diff between a freshly emitted bench
/// JSON and a committed baseline (see [`gate_counters`]).
#[derive(Debug, Default)]
pub struct GateOutcome {
    /// Work counters present in both files and gated.
    pub compared: usize,
    /// Counters present in both but advisory (timing-/machine-dependent).
    pub advisory: usize,
    /// Baseline counters missing from the fresh file (renamed/retired).
    pub skipped: usize,
    /// Human-readable regression descriptions; empty = gate passes.
    pub failures: Vec<String>,
}

/// Timing-/machine-dependent counters: reported but never gating. Work
/// counters (mults/draw, probes/draw, fused invocations/batch, …) stay
/// deterministic under fixed seeds, so they gate. `bytes` rides the
/// advisory list: snapshot sizes shift with any legitimate format/state
/// change (I/O payload, not per-draw work), so a byte-count delta must
/// never fail the counter gate.
fn advisory_counter(name: &str) -> bool {
    ["per_sec", "rate", "secs", "_ns", "stall", "hit", "throughput", "bytes"]
        .iter()
        .any(|t| name.contains(t))
}

/// Diff `fresh` against `baseline`: every *work* counter present in both
/// `counters` maps must not regress — lower is better, within `tol`
/// relative tolerance, and an exactly-zero baseline (e.g. "per-row code()
/// calls on the draw path") must stay zero. Timing rows are ignored and
/// advisory counters never fail the gate; baseline counters absent from
/// the fresh file are skipped (reported), so analytic-seed baselines and
/// measured runs interoperate.
pub fn gate_counters(fresh: &Json, baseline: &Json, tol: f64) -> GateOutcome {
    let mut out = GateOutcome::default();
    let empty = BTreeMap::new();
    let base = baseline.get("counters").and_then(|c| c.as_obj()).unwrap_or(&empty);
    let new = fresh.get("counters").and_then(|c| c.as_obj()).unwrap_or(&empty);
    for (name, bv) in base {
        let Some(b) = bv.as_f64() else { continue };
        let Some(f) = new.get(name).and_then(|v| v.as_f64()) else {
            out.skipped += 1;
            continue;
        };
        if advisory_counter(name) {
            out.advisory += 1;
            continue;
        }
        out.compared += 1;
        let limit = if b == 0.0 { 1e-9 } else { b * (1.0 + tol) + 1e-9 };
        if f > limit {
            out.failures.push(format!("{name}: fresh {f} exceeds baseline {b} (tol {tol})"));
        }
    }
    out
}

/// Where a bench group's JSON report lands: `$LGD_BENCH_DIR` when set (CI
/// artifact staging), else the repository root — benches run with the
/// package directory as CWD, so this resolves the manifest dir's parent.
pub fn bench_json_path(file_name: &str) -> PathBuf {
    let dir = std::env::var("LGD_BENCH_DIR").map(PathBuf::from).unwrap_or_else(|_| {
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| PathBuf::from("."))
    });
    dir.join(file_name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("LGD_BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        let row = b.bench("add", || {
            acc = bb(acc.wrapping_add(1));
        });
        assert!(row.median_ns > 0.0);
        assert!(row.iters >= 1);
        let sleepy = b.bench("sleep", || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(sleepy.median_ns > 10_000.0, "sleep measured {}", sleepy.median_ns);
        assert_eq!(b.rows().len(), 2);
    }

    #[test]
    fn json_report_roundtrips() {
        std::env::set_var("LGD_BENCH_FAST", "1");
        let mut b = Bench::new("json");
        b.record("whole_run", 1234.5);
        b.note("mults_per_draw", 15.0);
        b.note("mults_per_draw", 16.0); // overwrite, not duplicate
        b.note("probes_per_draw", 1.25);
        let j = b.to_json();
        let back = crate::config::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("group").unwrap().as_str(), Some("json"));
        let rows = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("whole_run"));
        let counters = back.get("counters").unwrap();
        assert_eq!(counters.get("mults_per_draw").unwrap().as_f64(), Some(16.0));
        assert_eq!(counters.get("probes_per_draw").unwrap().as_f64(), Some(1.25));
        // write path: land in a temp dir via the env override
        let dir = std::env::temp_dir().join("lgd-benchkit-json");
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("LGD_BENCH_DIR", &dir);
        let path = bench_json_path("BENCH_test.json");
        assert_eq!(path, dir.join("BENCH_test.json"));
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::config::json::Json::parse(text.trim()).is_ok());
        std::env::remove_var("LGD_BENCH_DIR");
    }

    #[test]
    fn counter_gate_flags_only_real_regressions() {
        let baseline = Json::parse(
            r#"{"group":"g","counters":{"mults_per_draw":100.0,"probes_per_draw":1.25,
                "per_row_code_calls":0,"draws_per_sec_sync":5000.0,"queue_stalls_async":9,
                "snapshot_bytes_n20k":250000.0,"snapshot_save_ns":80000.0,
                "retired_counter":7}}"#,
        )
        .unwrap();
        // within tolerance + advisory blowups + retired counter: passes.
        // snapshot bytes/ns rows are I/O-sized and timing-noisy — advisory
        // by name-match, so churn there can never fail the gate.
        let ok = Json::parse(
            r#"{"group":"g","counters":{"mults_per_draw":105.0,"probes_per_draw":1.25,
                "per_row_code_calls":0,"draws_per_sec_sync":1.0,"queue_stalls_async":99999,
                "snapshot_bytes_n20k":990000.0,"snapshot_save_ns":999999.0}}"#,
        )
        .unwrap();
        let out = gate_counters(&ok, &baseline, 0.1);
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        assert_eq!(out.compared, 3, "three work counters gate");
        assert_eq!(out.advisory, 4, "per_sec/stall/bytes/_ns counters are advisory");
        assert_eq!(out.skipped, 1, "retired counter skipped");
        // a work-counter regression fails: more mults/draw and a formerly
        // zero counter going nonzero
        let bad = Json::parse(
            r#"{"group":"g","counters":{"mults_per_draw":150.0,"probes_per_draw":1.25,
                "per_row_code_calls":4}}"#,
        )
        .unwrap();
        let out = gate_counters(&bad, &baseline, 0.1);
        assert_eq!(out.failures.len(), 2, "{:?}", out.failures);
        assert!(out.failures.iter().any(|f| f.contains("mults_per_draw")));
        assert!(out.failures.iter().any(|f| f.contains("per_row_code_calls")));
    }

    #[test]
    fn relative_ordering_sane() {
        std::env::set_var("LGD_BENCH_FAST", "1");
        let mut b = Bench::new("order");
        let data: Vec<f64> = (0..4096).map(|i| i as f64).collect();
        let small = b.bench("sum16", || {
            bb(data[..16].iter().sum::<f64>());
        }).median_ns;
        let large = b.bench("sum4096", || {
            bb(data.iter().sum::<f64>());
        }).median_ns;
        assert!(large > small, "sum4096 {large} should exceed sum16 {small}");
    }
}
