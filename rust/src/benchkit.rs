//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//! ```ignore
//! let mut b = Bench::new("sampling");
//! b.bench("lgd_draw_d90", || { ... });
//! b.report();
//! ```
//! Each benchmark is auto-calibrated (target ~0.4 s per measurement), runs
//! `reps` measured batches and reports median/p95 ns per iteration.

use std::hint::black_box;
use std::time::Instant;

/// One benchmark result row.
#[derive(Debug, Clone)]
pub struct BenchRow {
    /// Benchmark name.
    pub name: String,
    /// Median ns/iteration.
    pub median_ns: f64,
    /// p95 ns/iteration.
    pub p95_ns: f64,
    /// Iterations per measured batch.
    pub iters: u64,
}

/// A named group of benchmarks with a common report.
pub struct Bench {
    group: String,
    rows: Vec<BenchRow>,
    /// Measured batches per benchmark.
    pub reps: usize,
    /// Target seconds per measured batch during calibration.
    pub target_secs: f64,
}

/// Re-export of `std::hint::black_box` for benchmark bodies.
pub fn bb<T>(x: T) -> T {
    black_box(x)
}

impl Bench {
    /// New group.
    pub fn new(group: &str) -> Self {
        let mut b =
            Bench { group: group.to_string(), rows: Vec::new(), reps: 15, target_secs: 0.2 };
        // Quick mode for CI: LGD_BENCH_FAST=1 shrinks the measurement.
        if std::env::var("LGD_BENCH_FAST").is_ok() {
            b.reps = 5;
            b.target_secs = 0.02;
        }
        b
    }

    /// Run one benchmark; `f` is a single iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchRow {
        // Calibrate: how many iterations fit in target_secs?
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt >= self.target_secs / 4.0 || iters >= 1 << 30 {
                let scale = (self.target_secs / dt.max(1e-9)).clamp(1.0, 1e6);
                iters = ((iters as f64) * scale).ceil() as u64;
                break;
            }
            iters *= 8;
        }
        // Measure.
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64 * 1e9);
        }
        let median = crate::core::stats::median(&samples);
        let p95 = crate::core::stats::quantile(&samples, 0.95);
        self.rows.push(BenchRow { name: name.to_string(), median_ns: median, p95_ns: p95, iters });
        self.rows.last().unwrap()
    }

    /// Record an externally measured value (e.g. whole-run seconds).
    pub fn record(&mut self, name: &str, ns_per_iter: f64) {
        self.rows.push(BenchRow {
            name: name.to_string(),
            median_ns: ns_per_iter,
            p95_ns: ns_per_iter,
            iters: 1,
        });
    }

    /// Results so far.
    pub fn rows(&self) -> &[BenchRow] {
        &self.rows
    }

    /// Print the group report (aligned table).
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!("{:<44} {:>14} {:>14} {:>10}", "name", "median ns/it", "p95 ns/it", "iters");
        for r in &self.rows {
            println!(
                "{:<44} {:>14.1} {:>14.1} {:>10}",
                r.name, r.median_ns, r.p95_ns, r.iters
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("LGD_BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        let row = b.bench("add", || {
            acc = bb(acc.wrapping_add(1));
        });
        assert!(row.median_ns > 0.0);
        assert!(row.iters >= 1);
        let sleepy = b.bench("sleep", || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(sleepy.median_ns > 10_000.0, "sleep measured {}", sleepy.median_ns);
        assert_eq!(b.rows().len(), 2);
    }

    #[test]
    fn relative_ordering_sane() {
        std::env::set_var("LGD_BENCH_FAST", "1");
        let mut b = Bench::new("order");
        let data: Vec<f64> = (0..4096).map(|i| i as f64).collect();
        let small = b.bench("sum16", || {
            bb(data[..16].iter().sum::<f64>());
        }).median_ns;
        let large = b.bench("sum4096", || {
            bb(data.iter().sum::<f64>());
        }).median_ns;
        assert!(large > small, "sum4096 {large} should exceed sum16 {small}");
    }
}
