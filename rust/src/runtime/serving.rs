//! Epoch-based shared serving: many concurrent sessions draw from one
//! immutable published generation of the sharded LGD index.
//!
//! [`ShardedLgdEstimator`](crate::estimator::ShardedLgdEstimator) owns its
//! shard set exclusively — one borrow, one RNG, one draw stream. Serving
//! wants the opposite shape: *N* clients sampling the same index at once.
//! The split here is the classic read-copy-update arrangement:
//!
//! * [`ServingCore`] — the shared, read-only side: the preprocessed
//!   dataset, the sampler options, and an `Arc`-published [`ShardSet`]
//!   (stored rows, norms, sealed CSR arenas, hasher — all immutable after
//!   publication). Readers never lock anything on the draw path.
//! * [`ServingSession`] — the per-client side: its own fused query codes
//!   (one `codes_all` sweep per batch), its own RNG stream, its own
//!   [`SampleCost`](crate::lsh::sampler::SampleCost) counters, and — when
//!   pipelined — its own [`DrawQueue`] and sampler thread. Sessions share
//!   **no** mutable state, so N concurrent sessions are draw-for-draw
//!   identical to the same N sessions run sequentially (tested here and in
//!   the integration suite).
//!
//! **Generation flips.** Mutations (insert/remove/rebalance) never touch
//! the published set. [`ServingCore::mutate`] takes the writer lock, deep-
//! clones the current generation `g`, applies the mutation (the `ShardSet`
//! mutators bump the PR-4 generation counter), and atomically publishes
//! `g+1`. Sessions pinned to `g` keep draining their own `Arc` — every row
//! in it is live *for g*, so no session can ever serve a row that was dead
//! in its pinned generation. A session picks up `g+1` only at an explicit
//! [`ServingSession::refresh`], and the pipelined consumer drops (and
//! counts) any queued batch whose generation tag does not match the pinned
//! generation — the same "observed, not assumed" staleness contract as the
//! async draw engine's `stale_drops`.
//!
//! **Determinism.** A session's RNG uses the estimator's stream constant,
//! so `ServingSession::open(core, seed)` replays the batch stream of
//! `ShardedLgdEstimator` built with the same hasher/options/`seed` — the
//! contract the serving determinism tests pin for {Vec, sealed} layouts
//! across shard counts.
//!
//! A supervised wire front rides along: a length-prefixed (u32 LE)
//! request/response protocol over `std::net` TCP
//! ([`serve_supervised`]/[`ServeClient`]) with a bounded connection pool,
//! per-connection idle/write deadlines and per-connection error isolation
//! (a broken client becomes a counter, never the server's exit status),
//! plus the in-process N-client harness ([`run_harness`]) the CLI's
//! `lgd serve`, the `async_serving` example and `bench_runtime` all share.
//!
//! **Failure model** (see `docs/robustness.md`): a pipelined session whose
//! sampler thread dies *degrades* — it replays what the consumer already
//! saw from its own untouched RNG and finishes synchronously, so the
//! delivered stream is identical to an undegraded run and the incident is
//! a [`ServingCounters::degraded_sessions`] tick, not a lost session. On
//! the client side, [`RetryClient`] reconnects with deterministic
//! exponential backoff and fast-forwards the fresh seed-pinned server
//! session past every already-consumed draw, keeping the resumed stream
//! draw-for-draw identical.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::draw_engine::DrawQueue;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::{build_shard_tables, ShardSet, ShardTables};
use crate::core::error::{Error, Result};
use crate::core::rng::Pcg64;
use crate::core::telemetry::registry::Registry;
use crate::core::telemetry::{probes, prom};
use crate::data::preprocess::Preprocessed;
use crate::data::shard::ShardPlan;
use crate::estimator::lgd::LgdOptions;
use crate::estimator::sharded::mixture_draw_batch;
use crate::estimator::{EstimatorStats, WeightedDraw};
use crate::lsh::sampler::Draw;
use crate::lsh::srp::SrpHasher;
use crate::lsh::tables::BucketRead;
use crate::testkit::faults;

/// Lock `m`, treating a poisoned mutex as live — the protected state (an
/// `Arc` pointer or the writer token) is always structurally valid, same
/// policy as the draw queues.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn io_err(e: std::io::Error) -> Error {
    Error::Pipeline(format!("serving wire: {e}"))
}

/// Monotonic counters of a [`ServingCore`] (all sessions aggregated).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServingCounters {
    /// Generation publications (one per successful [`ServingCore::mutate`]).
    pub flips: u64,
    /// Sessions opened against this core.
    pub sessions: u64,
    /// Draws delivered to consumers across all sessions.
    pub draws_served: u64,
    /// Queued batches discarded because their generation tag did not match
    /// the session's pinned generation. Structurally 0 — a session's
    /// producer samples from the very `Arc` the consumer checks against —
    /// but counted so the "zero stale-generation serves" invariant is
    /// observed, not assumed (CI smoke-checks it stays 0).
    pub stale_rejected: u64,
    /// Pipelined sessions whose sampler thread died and which fell back to
    /// synchronous draws (the delivered stream stays identical — see
    /// [`ServingSession::run_pipelined`]). 0 in healthy operation.
    pub degraded_sessions: u64,
}

/// The shared read-only core of the serving engine: dataset + options +
/// the currently published shard-set generation. Cheap to share
/// (`Arc<ServingCore<_>>`); all draw-path state lives in sessions.
pub struct ServingCore<H: SrpHasher> {
    pre: Arc<Preprocessed>,
    opts: LgdOptions,
    /// The published generation. Readers clone the `Arc` out ([`Self::pin`])
    /// and never hold the lock across a draw.
    published: Mutex<Arc<ShardSet<H>>>,
    /// Lock-free mirror of the published set's generation counter, so
    /// sessions can poll staleness without touching the mutex.
    gen: AtomicU64,
    /// Serializes writers; readers never take it.
    writer: Mutex<()>,
    flips: AtomicU64,
    sessions_opened: AtomicU64,
    draws_served: AtomicU64,
    stale_rejected: AtomicU64,
    degraded_sessions: AtomicU64,
}

impl<H: SrpHasher> ServingCore<H> {
    /// Build the index (concurrent per-shard table builds, sealed into the
    /// CSR arena when `opts.sealed`) and wrap it as generation 0.
    pub fn build(
        pre: Arc<Preprocessed>,
        hasher: H,
        opts: LgdOptions,
        shards: usize,
    ) -> Result<Arc<Self>>
    where
        H: Clone,
    {
        let n = pre.data.len();
        let plan = ShardPlan::round_robin(n, shards)?;
        let built = build_shard_tables(&pre.hashed, &plan, opts.mirror, &hasher, &Metrics::new())?;
        let built: Vec<ShardTables<H>> = if opts.sealed {
            built.into_iter().map(ShardTables::seal).collect()
        } else {
            built
        };
        let set = ShardSet::from_shards(built, n, opts.mirror, 0.0);
        Ok(Arc::new(Self::from_set(pre, set, opts)))
    }

    /// Wrap an existing shard set (e.g. restored from a snapshot) as the
    /// published generation.
    pub fn from_set(pre: Arc<Preprocessed>, set: ShardSet<H>, opts: LgdOptions) -> Self {
        let gen = set.generation();
        ServingCore {
            pre,
            opts,
            published: Mutex::new(Arc::new(set)),
            gen: AtomicU64::new(gen),
            writer: Mutex::new(()),
            flips: AtomicU64::new(0),
            sessions_opened: AtomicU64::new(0),
            draws_served: AtomicU64::new(0),
            stale_rejected: AtomicU64::new(0),
            degraded_sessions: AtomicU64::new(0),
        }
    }

    /// The preprocessed dataset every generation serves.
    pub fn preprocessed(&self) -> &Preprocessed {
        &self.pre
    }

    /// The sampler options sessions run with.
    pub fn options(&self) -> &LgdOptions {
        &self.opts
    }

    /// Pin the currently published generation: an `Arc` the caller can
    /// read from for as long as it likes, regardless of later flips.
    pub fn pin(&self) -> Arc<ShardSet<H>> {
        lock(&self.published).clone()
    }

    /// Generation counter of the published set (lock-free).
    pub fn generation(&self) -> u64 {
        self.gen.load(Ordering::Acquire)
    }

    /// Apply a mutation as a generation flip: clone the published set
    /// (copy-on-write — readers keep their pins), run `f` on the clone,
    /// and atomically publish the result. Writers are serialized by the
    /// writer lock; an `Err` from `f` publishes nothing. Returns `f`'s
    /// value.
    pub fn mutate<T, F>(&self, f: F) -> Result<T>
    where
        H: Clone,
        F: FnOnce(&mut ShardSet<H>, &Preprocessed) -> Result<T>,
    {
        let _w = lock(&self.writer);
        let _sp = crate::span!("serve.generation_flip");
        if faults::should_fail(faults::GENERATION_FLIP) {
            // Before the clone: a failed flip publishes nothing and the
            // previous generation keeps serving untouched.
            return Err(Error::Pipeline("generation flip failed (failpoint)".into()));
        }
        let mut next = ShardSet::clone(&self.pin());
        let out = f(&mut next, &self.pre)?;
        let gen = next.generation();
        *lock(&self.published) = Arc::new(next);
        self.gen.store(gen, Ordering::Release);
        self.flips.fetch_add(1, Ordering::Relaxed);
        Registry::global().gauge("serve.generation").set(gen as f64);
        Ok(out)
    }

    /// Flip that inserts example `id`; returns the shard chosen.
    pub fn insert(&self, id: usize) -> Result<usize>
    where
        H: Clone,
    {
        self.mutate(|set, pre| set.insert(id, &pre.hashed))
    }

    /// Flip that removes example `id`; returns whether it was present.
    pub fn remove(&self, id: usize) -> Result<bool>
    where
        H: Clone,
    {
        self.mutate(|set, pre| set.remove(id, &pre.hashed))
    }

    /// Flip that rebalances shards to imbalance ≤ `target`; returns the
    /// number of examples migrated.
    pub fn rebalance_to(&self, target: f64) -> Result<usize>
    where
        H: Clone,
    {
        self.mutate(|set, pre| set.rebalance_to(target, &pre.hashed))
    }

    /// Snapshot of the aggregate counters.
    pub fn counters(&self) -> ServingCounters {
        ServingCounters {
            flips: self.flips.load(Ordering::Relaxed),
            sessions: self.sessions_opened.load(Ordering::Relaxed),
            draws_served: self.draws_served.load(Ordering::Relaxed),
            stale_rejected: self.stale_rejected.load(Ordering::Relaxed),
            degraded_sessions: self.degraded_sessions.load(Ordering::Relaxed),
        }
    }
}

/// What one [`ServingSession::run_pipelined`] call did.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServeReport {
    /// Batches delivered to the consumer callback.
    pub batches: usize,
    /// Draws assembled by the sampler side (≥ `batches · m` on early stop).
    pub draws: u64,
    /// Batches that were ready the moment the consumer asked.
    pub prefetch_hits: u64,
    /// Batch requests that had to wait on an empty queue.
    pub queue_stalls: u64,
    /// Queued batches dropped for a stale generation tag (see
    /// [`ServingCounters::stale_rejected`]).
    pub stale_rejected: u64,
    /// Pinned generation the session served.
    pub generation: u64,
    /// True when the sampler thread died and the session fell back to
    /// synchronous draws (the delivered stream is still identical to an
    /// undegraded run).
    pub degraded: bool,
}

/// One assembled batch, tagged with the generation it was drawn under.
struct GenBatch {
    gen: u64,
    draws: Vec<WeightedDraw>,
}

/// Closes a queue when dropped — shutdown stays correct on every exit
/// path, including a panicking consumer callback.
struct Closer<'q>(&'q DrawQueue<GenBatch>);

impl Drop for Closer<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Pop batches off `q` and hand live-generation ones to the consumer,
/// dropping (and counting) stale-tagged batches, until `steps` batches
/// were delivered, the callback stops, or the producer died. Closes `q`
/// on every exit path. Returns `(delivered, stopped)` — `stopped` is true
/// only when the *callback* ended the run, which is what lets the degraded
/// fallback tell "the consumer is done" apart from "the producer died".
fn deliver_batches<F>(
    q: &DrawQueue<GenBatch>,
    gen: u64,
    steps: usize,
    stale: &mut u64,
    on_batch: &mut F,
) -> (usize, bool)
where
    F: FnMut(usize, &[WeightedDraw]) -> bool,
{
    let guard = Closer(q);
    let mut delivered = 0usize;
    let mut stopped = false;
    while delivered < steps {
        match q.pop() {
            Some(b) if b.gen == gen => {
                let go = on_batch(delivered, &b.draws);
                delivered += 1;
                if !go {
                    stopped = true;
                    break;
                }
            }
            Some(_) => *stale += 1,
            None => break,
        }
    }
    drop(guard);
    (delivered, stopped)
}

/// One client's view of a [`ServingCore`]: a pinned generation plus all
/// the mutable draw-path state (RNG stream, fused query codes, counters,
/// scratch buffers) that the shared core deliberately does not hold.
pub struct ServingSession<H: SrpHasher> {
    core: Arc<ServingCore<H>>,
    set: Arc<ShardSet<H>>,
    opts: LgdOptions,
    rng: Pcg64,
    stats: EstimatorStats,
    query: Vec<f32>,
    codes: Vec<u32>,
    scratch: Vec<Draw>,
}

impl<H: SrpHasher> ServingSession<H> {
    /// Open a session pinned to the currently published generation. The
    /// RNG uses the estimator's stream constant, so a session with seed
    /// `s` replays `ShardedLgdEstimator`'s batch stream under the same
    /// hasher/options/seed.
    pub fn open(core: &Arc<ServingCore<H>>, seed: u64) -> Self {
        core.sessions_opened.fetch_add(1, Ordering::Relaxed);
        ServingSession {
            set: core.pin(),
            opts: core.opts.clone(),
            core: Arc::clone(core),
            rng: Pcg64::new(seed, 0x4c474400),
            stats: EstimatorStats::default(),
            query: Vec::new(),
            codes: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Generation this session is pinned to.
    pub fn generation(&self) -> u64 {
        self.set.generation()
    }

    /// The pinned shard set (immutable for the session's lifetime).
    pub fn shard_set(&self) -> &ShardSet<H> {
        &self.set
    }

    /// The session's own draw-path counters.
    pub fn stats(&self) -> EstimatorStats {
        self.stats
    }

    /// True when the core has published a newer generation than the one
    /// this session is pinned to (lock-free poll).
    pub fn is_stale(&self) -> bool {
        self.core.generation() != self.set.generation()
    }

    /// Re-pin to the currently published generation. Returns true when
    /// the pin actually moved. Draws before and after a refresh belong to
    /// different generations; the session's RNG stream continues either
    /// way.
    pub fn refresh(&mut self) -> bool {
        if !self.is_stale() {
            return false;
        }
        self.set = self.core.pin();
        true
    }

    /// Hash the query once (fused `codes_all` sweep) into the session's
    /// own code buffer. Skipped on a drained set — the batch core serves
    /// membership-aware uniform fallbacks without codes.
    fn hash_query(&mut self, theta: &[f32]) {
        if self.set.total_rows() == 0 {
            return;
        }
        self.core.pre.query(theta, &mut self.query);
        let hasher = self.set.shard(0).tables.hasher();
        hasher.codes_all(&self.query, &mut self.codes);
        self.stats.cost.codes += hasher.l();
        self.stats.cost.mults += hasher.mults_all();
    }

    /// Draw one exact shard-mixture batch of `m` weighted draws against
    /// the query built from `theta` — the synchronous per-session path,
    /// identical draw-for-draw to `ShardedLgdEstimator::draw_batch` under
    /// the same seed.
    pub fn draw_batch(&mut self, theta: &[f32], m: usize, out: &mut Vec<WeightedDraw>) {
        self.hash_query(theta);
        let n = self.set.base_len();
        mixture_draw_batch(
            &self.set,
            n,
            &self.opts,
            &self.codes,
            &self.query,
            m,
            &mut self.rng,
            &mut self.stats,
            &mut self.scratch,
            out,
        );
        self.core.draws_served.fetch_add(m as u64, Ordering::Relaxed);
    }

    /// Run one pipelined serving session: `steps` batches of `m` draws,
    /// assembled ahead of the consumer by the session's own sampler thread
    /// through its own bounded [`DrawQueue`] (capacity ≈ `queue_depth / m`
    /// batches). The query is hashed once for the whole run; the RNG is
    /// handed back, so synchronous [`Self::draw_batch`] calls continue the
    /// same stream afterwards — a fully consumed pipelined run delivers
    /// exactly the batches `steps` synchronous calls would have (the
    /// early-stop caveat of the async draw engine applies here too).
    ///
    /// Every queued batch carries its generation tag; the consumer side
    /// refuses to deliver a batch tagged with anything but the pinned
    /// generation, counting rejects into [`ServeReport::stale_rejected`]
    /// and the core's aggregate counter.
    pub fn run_pipelined<F>(
        &mut self,
        theta: &[f32],
        m: usize,
        steps: usize,
        queue_depth: usize,
        mut on_batch: F,
    ) -> Result<ServeReport>
    where
        F: FnMut(usize, &[WeightedDraw]) -> bool,
    {
        let gen = self.set.generation();
        if m == 0 || steps == 0 {
            return Ok(ServeReport { generation: gen, ..Default::default() });
        }
        self.hash_query(theta);
        let set = &*self.set;
        let n = set.base_len();
        let opts = &self.opts;
        let codes = &self.codes;
        let query = &self.query;
        let prod_rng = self.rng.clone();
        let q: DrawQueue<GenBatch> = DrawQueue::new((queue_depth / m).max(1));
        let mut stale = 0u64;
        let (prod_res, (mut delivered, stopped)) = thread::scope(|scope| {
            let qr = &q;
            let producer = scope.spawn(move || {
                let _close = Closer(qr);
                if faults::should_fail_at(faults::WORKER_START, 0) {
                    panic!("failpoint: {}", faults::WORKER_START);
                }
                let mut rng = prod_rng;
                let mut st = EstimatorStats::default();
                let mut scratch = Vec::new();
                for _ in 0..steps {
                    let mut out = Vec::with_capacity(m);
                    mixture_draw_batch(
                        set,
                        n,
                        opts,
                        codes,
                        query,
                        m,
                        &mut rng,
                        &mut st,
                        &mut scratch,
                        &mut out,
                    );
                    if !qr.push(GenBatch { gen, draws: out }) {
                        break;
                    }
                }
                (rng, st)
            });
            let delivered = deliver_batches(&q, gen, steps, &mut stale, &mut on_batch);
            (producer.join(), delivered)
        });
        let mut degraded = false;
        let draws;
        match prod_res {
            Ok((rng_back, prod_stats)) => {
                self.rng = rng_back;
                draws = prod_stats.draws;
                self.stats.merge_draws(&prod_stats);
            }
            Err(_) => {
                // Degraded mode: the sampler thread died, taking its RNG
                // clone and counters with it. The session's own RNG is
                // untouched, so replay the `delivered` batches from it —
                // regenerating exactly the stream (and the stats) the
                // consumer already saw; the producer's discarded partial
                // work never reached anyone — then finish the remaining
                // steps synchronously. The delivered stream is identical
                // to an undegraded run, draw-for-draw.
                degraded = true;
                self.core.degraded_sessions.fetch_add(1, Ordering::Relaxed);
                let mut buf = Vec::with_capacity(m);
                for _ in 0..delivered {
                    mixture_draw_batch(
                        &self.set,
                        n,
                        &self.opts,
                        &self.codes,
                        &self.query,
                        m,
                        &mut self.rng,
                        &mut self.stats,
                        &mut self.scratch,
                        &mut buf,
                    );
                }
                let mut assembled = (delivered * m) as u64;
                if !stopped {
                    while delivered < steps {
                        mixture_draw_batch(
                            &self.set,
                            n,
                            &self.opts,
                            &self.codes,
                            &self.query,
                            m,
                            &mut self.rng,
                            &mut self.stats,
                            &mut self.scratch,
                            &mut buf,
                        );
                        assembled += m as u64;
                        let go = on_batch(delivered, &buf);
                        delivered += 1;
                        if !go {
                            break;
                        }
                    }
                }
                draws = assembled;
            }
        }
        let (hits, stalls) = q.counters();
        self.stats.prefetch_hits += hits;
        self.stats.queue_stalls += stalls;
        self.core.draws_served.fetch_add((delivered * m) as u64, Ordering::Relaxed);
        if stale > 0 {
            self.core.stale_rejected.fetch_add(stale, Ordering::Relaxed);
        }
        Ok(ServeReport {
            batches: delivered,
            draws,
            prefetch_hits: hits,
            queue_stalls: stalls,
            stale_rejected: stale,
            generation: gen,
            degraded,
        })
    }
}

/// Aggregate result of one in-process multi-client run.
#[derive(Debug, Clone, Copy)]
pub struct HarnessReport {
    /// Concurrent client sessions.
    pub clients: usize,
    /// Pipelined batches each client consumed.
    pub batches_per_client: usize,
    /// Draws per batch.
    pub batch: usize,
    /// Total draws delivered across clients.
    pub draws: u64,
    /// Wall seconds for the whole fan-out.
    pub wall_secs: f64,
    /// `draws / wall_secs`.
    pub draws_per_sec: f64,
    /// Stale-generation batch rejects across clients (expected 0).
    pub stale_rejected: u64,
    /// Client sessions that fell back to synchronous draws (expected 0).
    pub degraded: u64,
    /// Generation the clients served.
    pub generation: u64,
}

/// The in-process N-client harness: `clients` concurrent pipelined
/// sessions (seeds `seed`, `seed+1`, …) each consuming `batches` batches
/// of `m` draws against the same query. Returns the aggregate throughput —
/// the serving scaling number `lgd serve`, the `async_serving` example and
/// `bench_runtime` report.
pub fn run_harness<H: SrpHasher>(
    core: &Arc<ServingCore<H>>,
    clients: usize,
    batches: usize,
    m: usize,
    theta: &[f32],
    seed: u64,
) -> Result<HarnessReport> {
    if clients == 0 {
        return Err(Error::Config("serving harness needs clients >= 1".into()));
    }
    let t0 = Instant::now();
    let results: Vec<thread::Result<Result<ServeReport>>> = thread::scope(|scope| {
        let mut hs = Vec::with_capacity(clients);
        for c in 0..clients {
            let core = Arc::clone(core);
            hs.push(scope.spawn(move || -> Result<ServeReport> {
                let mut sess = ServingSession::open(&core, seed.wrapping_add(c as u64));
                sess.run_pipelined(theta, m, batches, 4 * m, |_, draws| {
                    debug_assert_eq!(draws.len(), m);
                    true
                })
            }));
        }
        hs.into_iter().map(|h| h.join()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut draws = 0u64;
    let mut stale = 0u64;
    let mut degraded = 0u64;
    let mut gen = 0u64;
    for r in results {
        let rep = r.map_err(|_| Error::Pipeline("serving client thread panicked".into()))??;
        draws += (rep.batches * m) as u64;
        stale += rep.stale_rejected;
        degraded += rep.degraded as u64;
        gen = rep.generation;
    }
    Ok(HarnessReport {
        clients,
        batches_per_client: batches,
        batch: m,
        draws,
        wall_secs: wall,
        draws_per_sec: draws as f64 / wall.max(1e-12),
        stale_rejected: stale,
        degraded,
        generation: gen,
    })
}

// ---------------------------------------------------------------------------
// Wire protocol: u32 LE length-prefixed frames over std::net TCP.
//
//   request  = HELLO  (op=1, magic u32, version u32, seed u64)
//            | DRAW   (op=2, m u32, dim u32, theta f32×dim)
//            | BYE    (op=3)
//            | STATS  (op=4) — allowed before HELLO
//            | METRICS(op=5) — allowed before HELLO
//   response = ok:  status=0 + HELLO → generation u64
//                              DRAW  → generation u64, count u32,
//                                      (index u32, weight f64, prob f64)×count
//                              STATS → 8×u64 (see WireStats), then the
//                                      registry appendix: count u32 +
//                                      (len u16, name utf-8, value f64)×count
//                                      — old clients read the 8 u64s and
//                                      ignore the rest
//                              METRICS → Prometheus text exposition (utf-8)
//              err: status=1 + utf-8 message
// ---------------------------------------------------------------------------

/// Frame magic in HELLO ("LGDS").
pub const WIRE_MAGIC: u32 = 0x4C47_4453;
/// Wire protocol version.
pub const WIRE_VERSION: u32 = 1;

const OP_HELLO: u8 = 1;
const OP_DRAW: u8 = 2;
const OP_BYE: u8 = 3;
const OP_STATS: u8 = 4;
const OP_METRICS: u8 = 5;
const ST_OK: u8 = 0;
const ST_ERR: u8 = 1;
/// Frame size ceiling (16 MiB) — refuse anything larger before allocating.
const MAX_FRAME: u32 = 1 << 24;
/// Per-request draw-count ceiling.
const MAX_DRAWS_PER_REQUEST: u32 = 1 << 20;

/// Bounds-checked little-endian reader over one frame payload.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, k: usize) -> Result<&'a [u8]> {
        if k > self.buf.len() - self.pos {
            return Err(Error::Pipeline("serving wire: truncated frame".into()));
        }
        let s = &self.buf[self.pos..self.pos + k];
        self.pos += k;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, k: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * k)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn rest_str(&self) -> String {
        String::from_utf8_lossy(&self.buf[self.pos..]).into_owned()
    }
}

fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if faults::should_fail(faults::TCP_WRITE) {
        return Err(Error::Pipeline("serving wire: write failpoint".into()));
    }
    if payload.len() as u64 > MAX_FRAME as u64 {
        return Err(Error::Pipeline(format!(
            "serving wire: frame of {} bytes exceeds the {MAX_FRAME}-byte ceiling",
            payload.len()
        )));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes()).map_err(io_err)?;
    w.write_all(payload).map_err(io_err)?;
    w.flush().map_err(io_err)
}

/// Blocking frame read (client side). `Ok(None)` on clean EOF before the
/// header.
fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    if faults::should_fail_at(faults::TCP_READ, faults::SIDE_CLIENT) {
        return Err(Error::Pipeline("serving wire: read failpoint".into()));
    }
    let mut lb = [0u8; 4];
    match r.read_exact(&mut lb) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(io_err(e)),
    }
    let len = u32::from_le_bytes(lb);
    if len > MAX_FRAME {
        return Err(Error::Pipeline(format!("serving wire: oversized frame ({len} bytes)")));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf).map_err(io_err)?;
    Ok(Some(buf))
}

/// Fill `buf` from the stream, tolerating read-timeout polls (the server
/// sets a short timeout so handlers can notice the stop flag). `Ok(None)`
/// = clean end: EOF before any byte (between frames), the stop flag going
/// up, or the `deadline` expiring, all while nothing was in flight; a
/// deadline that expires *mid-frame* is an error.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    deadline: Option<Duration>,
) -> Result<Option<()>> {
    if faults::should_fail_at(faults::TCP_READ, faults::SIDE_SERVER) {
        return Err(Error::Pipeline("serving wire: read failpoint".into()));
    }
    let start = Instant::now();
    let mut got = 0usize;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(Error::Pipeline("serving wire: connection truncated mid-frame".into()));
            }
            Ok(k) => got += k,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) && got == 0 {
                    return Ok(None);
                }
                if let Some(d) = deadline {
                    if start.elapsed() >= d {
                        if got == 0 {
                            return Ok(None);
                        }
                        return Err(Error::Pipeline(
                            "serving wire: read deadline exceeded mid-frame".into(),
                        ));
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(io_err(e)),
        }
    }
    Ok(Some(()))
}

fn err_payload(msg: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(1 + msg.len());
    p.push(ST_ERR);
    p.extend_from_slice(msg.as_bytes());
    p
}

/// Knobs of the supervised TCP front ([`serve_supervised`]).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Connection-pool bound: accepts beyond this many live connections
    /// answer an error frame and close (counted in
    /// [`ServeTotals::rejected_at_capacity`]); they never spawn a handler.
    pub max_clients: usize,
    /// Idle deadline: a connection that sends nothing for this long
    /// between frames is closed cleanly.
    pub idle_timeout: Duration,
    /// Per-frame I/O deadline: a request that stalls mid-frame or a
    /// response write that cannot make progress for this long fails the
    /// connection (counted, isolated).
    pub io_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_clients: 64,
            idle_timeout: Duration::from_secs(30),
            io_timeout: Duration::from_secs(5),
        }
    }
}

/// Per-listener counters shared between the accept loop and the handlers.
#[derive(Default)]
struct ServeState {
    draws: AtomicU64,
    connections: AtomicU64,
    conn_errors: AtomicU64,
    rejected_at_capacity: AtomicU64,
}

/// What one [`serve_supervised`] run did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeTotals {
    /// Draws served across all connections.
    pub draws: u64,
    /// Connections accepted into the pool.
    pub connections: u64,
    /// Connections that ended in an I/O error or handler panic (isolated —
    /// the server kept running).
    pub conn_errors: u64,
    /// Connections turned away because the pool was full.
    pub rejected_at_capacity: u64,
}

/// Bring a monotone counter in the global registry up to `total` (totals
/// come from per-core atomics; the registry cell only ever moves forward).
fn set_counter_total(reg: &Registry, name: &str, total: u64) {
    let h = reg.counter(name);
    let cur = h.get();
    if total > cur {
        h.add(total - cur);
    }
}

/// Publish the serving core + listener state into the global registry —
/// the single producer the `STATS` appendix and the `METRICS` exposition
/// read from. Also pre-registers the PR-7/8/9 gated counters
/// (`serve.stale_candidates_rejected`, `serve.degraded_sessions`,
/// `health.rollbacks`) so they are visible at 0 before anything trips.
fn publish_wire_metrics<H: SrpHasher>(core: &ServingCore<H>, state: &ServeState) {
    let reg = Registry::global();
    let c = core.counters();
    set_counter_total(reg, "serve.flips", c.flips);
    set_counter_total(reg, "serve.sessions", c.sessions);
    set_counter_total(reg, "serve.draws_served", c.draws_served);
    set_counter_total(reg, "serve.stale_candidates_rejected", c.stale_rejected);
    set_counter_total(reg, "serve.degraded_sessions", c.degraded_sessions);
    set_counter_total(reg, "serve.connections", state.connections.load(Ordering::Relaxed));
    set_counter_total(reg, "serve.conn_errors", state.conn_errors.load(Ordering::Relaxed));
    set_counter_total(
        reg,
        "serve.rejected_at_capacity",
        state.rejected_at_capacity.load(Ordering::Relaxed),
    );
    // Registered-for-exposure: the trainer increments it on rollback.
    reg.counter("health.rollbacks");
    reg.gauge("serve.generation").set(core.generation() as f64);
    let pin = core.pin();
    for s in 0..pin.shard_count() {
        reg.gauge_labeled("serve.shard_rows", &[("shard", &s.to_string())])
            .set(pin.shard(s).stored.rows() as f64);
    }
    probes::publish(reg);
}

/// Handle one client connection: HELLO opens the session, DRAWs stream
/// batches, STATS reads the server counters (plus the registry appendix),
/// METRICS dumps the Prometheus exposition, BYE (or EOF, or the idle
/// deadline) ends it. Returns draws served on this connection. Protocol
/// violations get an error frame, then the connection closes — they never
/// take the server down.
fn handle_conn<H: SrpHasher>(
    core: &Arc<ServingCore<H>>,
    mut stream: TcpStream,
    stop: &AtomicBool,
    opts: &ServeOptions,
    state: &ServeState,
) -> Result<u64> {
    stream.set_read_timeout(Some(Duration::from_millis(100))).map_err(io_err)?;
    stream.set_write_timeout(Some(opts.io_timeout)).map_err(io_err)?;
    stream.set_nodelay(true).ok();
    let mut session: Option<ServingSession<H>> = None;
    let mut served = 0u64;
    let mut draws: Vec<WeightedDraw> = Vec::new();
    // Pre-registered once per connection; each observe is lock-free.
    let req_hist = Registry::global().histogram("serve.request_secs");
    loop {
        let mut lb = [0u8; 4];
        if read_full(&mut stream, &mut lb, stop, Some(opts.idle_timeout))?.is_none() {
            return Ok(served);
        }
        let len = u32::from_le_bytes(lb);
        if len > MAX_FRAME {
            let _ = write_frame(&mut stream, &err_payload("oversized frame"));
            return Ok(served);
        }
        let mut payload = vec![0u8; len as usize];
        if read_full(&mut stream, &mut payload, stop, Some(opts.io_timeout))?.is_none() {
            return Ok(served);
        }
        // Decode + dispatch; a malformed frame answers with an error
        // payload and closes this connection only.
        let req_t0 = Instant::now();
        let flow = (|| -> Result<bool> {
            let mut r = Reader::new(&payload);
            match r.u8()? {
                OP_HELLO => {
                    let magic = r.u32()?;
                    let version = r.u32()?;
                    let seed = r.u64()?;
                    if magic != WIRE_MAGIC {
                        return Err(Error::Pipeline("serving wire: bad HELLO magic".into()));
                    }
                    if version != WIRE_VERSION {
                        return Err(Error::Pipeline(format!(
                            "serving wire: unsupported version {version} (server speaks \
                             {WIRE_VERSION})"
                        )));
                    }
                    let sess = ServingSession::open(core, seed);
                    let mut p = Vec::with_capacity(9);
                    p.push(ST_OK);
                    p.extend_from_slice(&sess.generation().to_le_bytes());
                    session = Some(sess);
                    write_frame(&mut stream, &p)?;
                    Ok(true)
                }
                OP_DRAW => {
                    let m = r.u32()?;
                    let dim = r.u32()? as usize;
                    if m == 0 || m > MAX_DRAWS_PER_REQUEST {
                        return Err(Error::Pipeline(format!("serving wire: bad draw count {m}")));
                    }
                    let want = core.pre.data.dim();
                    if dim != want {
                        return Err(Error::Pipeline(format!(
                            "serving wire: DRAW dim {dim} does not match the dataset dim {want}"
                        )));
                    }
                    let theta = r.f32s(dim)?;
                    let sess = session
                        .as_mut()
                        .ok_or_else(|| Error::Pipeline("serving wire: DRAW before HELLO".into()))?;
                    sess.draw_batch(&theta, m as usize, &mut draws);
                    let mut p = Vec::with_capacity(13 + draws.len() * 20);
                    p.push(ST_OK);
                    p.extend_from_slice(&sess.generation().to_le_bytes());
                    p.extend_from_slice(&(draws.len() as u32).to_le_bytes());
                    for d in &draws {
                        p.extend_from_slice(&(d.index as u32).to_le_bytes());
                        p.extend_from_slice(&d.weight.to_le_bytes());
                        p.extend_from_slice(&d.prob.to_le_bytes());
                    }
                    served += m as u64;
                    write_frame(&mut stream, &p)?;
                    Ok(true)
                }
                OP_STATS => {
                    // Allowed before HELLO: health checks don't need a
                    // session.
                    let c = core.counters();
                    let mut p = Vec::with_capacity(1 + 8 * 8);
                    p.push(ST_OK);
                    for v in [
                        c.flips,
                        c.sessions,
                        c.draws_served,
                        c.stale_rejected,
                        c.degraded_sessions,
                        state.connections.load(Ordering::Relaxed),
                        state.conn_errors.load(Ordering::Relaxed),
                        state.rejected_at_capacity.load(Ordering::Relaxed),
                    ] {
                        p.extend_from_slice(&v.to_le_bytes());
                    }
                    // Registry appendix (protocol-compatible: old clients
                    // stop after the 8 u64s above).
                    publish_wire_metrics(core, state);
                    let flat = Registry::global().flat();
                    p.extend_from_slice(&(flat.len() as u32).to_le_bytes());
                    for (name, value) in &flat {
                        let bytes = name.as_bytes();
                        p.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
                        p.extend_from_slice(bytes);
                        p.extend_from_slice(&value.to_le_bytes());
                    }
                    write_frame(&mut stream, &p)?;
                    Ok(true)
                }
                OP_METRICS => {
                    // Allowed before HELLO, like STATS: scrapers don't
                    // open sessions.
                    publish_wire_metrics(core, state);
                    let text = prom::render(Registry::global());
                    let mut p = Vec::with_capacity(1 + text.len());
                    p.push(ST_OK);
                    p.extend_from_slice(text.as_bytes());
                    write_frame(&mut stream, &p)?;
                    Ok(true)
                }
                OP_BYE => Ok(false),
                op => Err(Error::Pipeline(format!("serving wire: unknown op {op}"))),
            }
        })();
        req_hist.observe_secs(req_t0.elapsed().as_secs_f64());
        match flow {
            Ok(true) => {}
            Ok(false) => return Ok(served),
            Err(e) => {
                let _ = write_frame(&mut stream, &err_payload(&e.to_string()));
                return Ok(served);
            }
        }
    }
}

/// Serve the core over TCP under supervision: accept connections until
/// `stop` goes up, one handler thread per connection (each with its own
/// [`ServingSession`]), with the pool bounded at `opts.max_clients` live
/// connections — excess accepts answer an error frame and close. A
/// connection that errors (broken pipe, stalled frame, handler panic)
/// becomes a [`ServeTotals::conn_errors`] tick, never the server's exit
/// status: `Err` is reserved for listener/accept failures. On stop the
/// accept loop drains gracefully — every live handler is joined (each
/// notices the flag within its read-timeout tick once its client goes
/// quiet).
pub fn serve_supervised<H: SrpHasher>(
    core: &Arc<ServingCore<H>>,
    listener: TcpListener,
    stop: &AtomicBool,
    opts: &ServeOptions,
) -> Result<ServeTotals> {
    listener.set_nonblocking(true).map_err(io_err)?;
    let state = ServeState::default();
    let mut listen_err: Option<Error> = None;
    let live_gauge = Registry::global().gauge("serve.live_connections");
    thread::scope(|scope| {
        let st = &state;
        let mut handlers: Vec<thread::ScopedJoinHandle<'_, ()>> = Vec::new();
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((mut stream, _addr)) => {
                    // Reap finished handlers first so the pool bound
                    // tracks *live* connections, not historical ones.
                    let mut i = 0;
                    while i < handlers.len() {
                        if handlers[i].is_finished() {
                            if handlers.swap_remove(i).join().is_err() {
                                st.conn_errors.fetch_add(1, Ordering::Relaxed);
                            }
                        } else {
                            i += 1;
                        }
                    }
                    if handlers.len() >= opts.max_clients {
                        st.rejected_at_capacity.fetch_add(1, Ordering::Relaxed);
                        let _ = write_frame(&mut stream, &err_payload("server at capacity"));
                        continue;
                    }
                    st.connections.fetch_add(1, Ordering::Relaxed);
                    handlers.push(scope.spawn(move || {
                        match handle_conn(core, stream, stop, opts, st) {
                            Ok(served) => {
                                st.draws.fetch_add(served, Ordering::Relaxed);
                            }
                            Err(_) => {
                                st.conn_errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }));
                    live_gauge.set(handlers.len() as f64);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    listen_err = Some(io_err(e));
                    break;
                }
            }
        }
        for h in handlers {
            if h.join().is_err() {
                st.conn_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        live_gauge.set(0.0);
    });
    match listen_err {
        Some(e) => Err(e),
        None => Ok(ServeTotals {
            draws: state.draws.load(Ordering::Relaxed),
            connections: state.connections.load(Ordering::Relaxed),
            conn_errors: state.conn_errors.load(Ordering::Relaxed),
            rejected_at_capacity: state.rejected_at_capacity.load(Ordering::Relaxed),
        }),
    }
}

/// [`serve_supervised`] with default [`ServeOptions`], returning just the
/// draws served — the original front's signature, kept for callers that
/// don't need the totals.
pub fn serve_tcp<H: SrpHasher>(
    core: &Arc<ServingCore<H>>,
    listener: TcpListener,
    stop: &AtomicBool,
) -> Result<u64> {
    serve_supervised(core, listener, stop, &ServeOptions::default()).map(|t| t.draws)
}

/// Client-side socket deadlines — the knobs that keep a [`ServeClient`]
/// from hanging forever on a stalled or dead server.
#[derive(Debug, Clone)]
pub struct ClientOptions {
    /// TCP connect deadline (`None` = the OS default blocking connect).
    pub connect_timeout: Option<Duration>,
    /// Read/write deadline per frame (`None` = block forever).
    pub io_timeout: Option<Duration>,
}

impl Default for ClientOptions {
    fn default() -> Self {
        ClientOptions {
            connect_timeout: Some(Duration::from_secs(5)),
            io_timeout: Some(Duration::from_secs(5)),
        }
    }
}

/// Aggregate server-side counters returned by the wire `STATS` op.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Generation publications.
    pub flips: u64,
    /// Sessions opened against the core.
    pub sessions: u64,
    /// Draws delivered across all sessions.
    pub draws_served: u64,
    /// Stale-generation batch rejects (expected 0).
    pub stale_rejected: u64,
    /// Sessions that fell back to synchronous draws (expected 0).
    pub degraded_sessions: u64,
    /// Connections accepted into the pool.
    pub connections: u64,
    /// Connections that ended in an isolated error.
    pub conn_errors: u64,
    /// Connections turned away at the pool bound.
    pub rejected_at_capacity: u64,
}

/// Client half of the wire protocol.
pub struct ServeClient {
    stream: TcpStream,
    /// Generation the server reported at HELLO.
    pub generation: u64,
}

impl ServeClient {
    /// [`Self::connect_with`] under the default [`ClientOptions`] (5 s
    /// connect and per-frame deadlines).
    pub fn connect(addr: impl ToSocketAddrs, seed: u64) -> Result<Self> {
        Self::connect_with(addr, seed, &ClientOptions::default())
    }

    /// Connect and HELLO with `seed` (the server opens a session whose
    /// draw stream is pinned by that seed), under explicit deadlines.
    pub fn connect_with(
        addr: impl ToSocketAddrs,
        seed: u64,
        opts: &ClientOptions,
    ) -> Result<Self> {
        let mut stream = match opts.connect_timeout {
            Some(d) => {
                let mut last: Option<std::io::Error> = None;
                let mut found: Option<TcpStream> = None;
                for a in addr.to_socket_addrs().map_err(io_err)? {
                    match TcpStream::connect_timeout(&a, d) {
                        Ok(s) => {
                            found = Some(s);
                            break;
                        }
                        Err(e) => last = Some(e),
                    }
                }
                match found {
                    Some(s) => s,
                    None => {
                        return Err(match last {
                            Some(e) => io_err(e),
                            None => Error::Pipeline(
                                "serving wire: address resolved to nothing".into(),
                            ),
                        })
                    }
                }
            }
            None => TcpStream::connect(&addr).map_err(io_err)?,
        };
        stream.set_read_timeout(opts.io_timeout).map_err(io_err)?;
        stream.set_write_timeout(opts.io_timeout).map_err(io_err)?;
        stream.set_nodelay(true).ok();
        let mut p = Vec::with_capacity(17);
        p.push(OP_HELLO);
        p.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
        p.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        p.extend_from_slice(&seed.to_le_bytes());
        write_frame(&mut stream, &p)?;
        let resp = read_frame(&mut stream)?
            .ok_or_else(|| Error::Pipeline("serving wire: server closed during HELLO".into()))?;
        let mut r = Reader::new(&resp);
        if r.u8()? != ST_OK {
            return Err(Error::Pipeline(format!("serving server rejected HELLO: {}", r.rest_str())));
        }
        let generation = r.u64()?;
        Ok(ServeClient { stream, generation })
    }

    /// Request one batch of `m` weighted draws for the query built from
    /// `theta`; returns the server session's generation and the draws.
    pub fn draw(&mut self, theta: &[f32], m: usize) -> Result<(u64, Vec<WeightedDraw>)> {
        let mut p = Vec::with_capacity(9 + 4 * theta.len());
        p.push(OP_DRAW);
        p.extend_from_slice(&(m as u32).to_le_bytes());
        p.extend_from_slice(&(theta.len() as u32).to_le_bytes());
        for v in theta {
            p.extend_from_slice(&v.to_le_bytes());
        }
        write_frame(&mut self.stream, &p)?;
        let resp = read_frame(&mut self.stream)?
            .ok_or_else(|| Error::Pipeline("serving wire: server closed during DRAW".into()))?;
        let mut r = Reader::new(&resp);
        if r.u8()? != ST_OK {
            return Err(Error::Pipeline(format!("serving server error: {}", r.rest_str())));
        }
        let generation = r.u64()?;
        let count = r.u32()? as usize;
        let mut draws = Vec::with_capacity(count);
        for _ in 0..count {
            let index = r.u32()? as usize;
            let weight = r.f64()?;
            let prob = r.f64()?;
            draws.push(WeightedDraw { index, weight, prob });
        }
        Ok((generation, draws))
    }

    /// Fetch the server's aggregate counters (allowed before HELLO).
    pub fn stats(&mut self) -> Result<WireStats> {
        write_frame(&mut self.stream, &[OP_STATS])?;
        let resp = read_frame(&mut self.stream)?
            .ok_or_else(|| Error::Pipeline("serving wire: server closed during STATS".into()))?;
        let mut r = Reader::new(&resp);
        if r.u8()? != ST_OK {
            return Err(Error::Pipeline(format!("serving server error: {}", r.rest_str())));
        }
        Ok(WireStats {
            flips: r.u64()?,
            sessions: r.u64()?,
            draws_served: r.u64()?,
            stale_rejected: r.u64()?,
            degraded_sessions: r.u64()?,
            connections: r.u64()?,
            conn_errors: r.u64()?,
            rejected_at_capacity: r.u64()?,
        })
    }

    /// Fetch the server's counters *and* the full registry appendix
    /// (name → value pairs) the `STATS` response carries after the 8 u64s.
    pub fn stats_full(&mut self) -> Result<(WireStats, Vec<(String, f64)>)> {
        write_frame(&mut self.stream, &[OP_STATS])?;
        let resp = read_frame(&mut self.stream)?
            .ok_or_else(|| Error::Pipeline("serving wire: server closed during STATS".into()))?;
        let mut r = Reader::new(&resp);
        if r.u8()? != ST_OK {
            return Err(Error::Pipeline(format!("serving server error: {}", r.rest_str())));
        }
        let stats = WireStats {
            flips: r.u64()?,
            sessions: r.u64()?,
            draws_served: r.u64()?,
            stale_rejected: r.u64()?,
            degraded_sessions: r.u64()?,
            connections: r.u64()?,
            conn_errors: r.u64()?,
            rejected_at_capacity: r.u64()?,
        };
        let count = r.u32()? as usize;
        let mut registry = Vec::with_capacity(count);
        for _ in 0..count {
            let len = u16::from_le_bytes(r.take(2)?.try_into().unwrap()) as usize;
            let name = String::from_utf8_lossy(r.take(len)?).into_owned();
            registry.push((name, r.f64()?));
        }
        Ok((stats, registry))
    }

    /// Fetch the Prometheus text exposition (the `METRICS` op; allowed
    /// before HELLO).
    pub fn metrics(&mut self) -> Result<String> {
        write_frame(&mut self.stream, &[OP_METRICS])?;
        let resp = read_frame(&mut self.stream)?
            .ok_or_else(|| Error::Pipeline("serving wire: server closed during METRICS".into()))?;
        let mut r = Reader::new(&resp);
        if r.u8()? != ST_OK {
            return Err(Error::Pipeline(format!("serving server error: {}", r.rest_str())));
        }
        Ok(r.rest_str())
    }

    /// Polite goodbye (the server also handles a plain disconnect).
    pub fn bye(mut self) -> Result<()> {
        write_frame(&mut self.stream, &[OP_BYE])
    }
}

/// Deterministic exponential-backoff schedule for [`RetryClient`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Reconnect attempts per draw beyond the first try.
    pub max_retries: u32,
    /// Backoff before retry `k` (0-based) is `min(base · 2^k, max)`.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// The deterministic backoff before retry `attempt` (0-based) — no
    /// jitter, so retry schedules are reproducible in tests.
    pub fn backoff(&self, attempt: u32) -> Duration {
        self.base_backoff
            .checked_mul(1u32 << attempt.min(16))
            .map_or(self.max_backoff, |d| d.min(self.max_backoff))
    }
}

/// A [`ServeClient`] that survives connection failures: on an I/O error
/// it backs off (per the deterministic policy schedule), reconnects with
/// the **same seed**, and fast-forwards — re-issuing every previously
/// consumed draw against the fresh server session and discarding the
/// responses. Server sessions are seed-pinned and die with their
/// connection, so the replayed session walks the identical RNG stream and
/// the resumed stream is draw-for-draw what an uninterrupted client would
/// have seen.
pub struct RetryClient {
    addr: String,
    seed: u64,
    opts: ClientOptions,
    policy: RetryPolicy,
    inner: ServeClient,
    /// Every consumed request `(theta, m)`, in order — the fast-forward
    /// script a reconnect replays.
    history: Vec<(Vec<f32>, usize)>,
    retries: u64,
    /// Generation the live connection reported at HELLO.
    pub generation: u64,
}

impl RetryClient {
    /// Connect and HELLO with `seed`, remembering `addr` for reconnects.
    pub fn connect(
        addr: &str,
        seed: u64,
        opts: ClientOptions,
        policy: RetryPolicy,
    ) -> Result<Self> {
        let inner = ServeClient::connect_with(addr, seed, &opts)?;
        let generation = inner.generation;
        Ok(RetryClient {
            addr: addr.to_string(),
            seed,
            opts,
            policy,
            inner,
            history: Vec::new(),
            retries: 0,
            generation,
        })
    }

    /// Reconnects performed so far.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    fn reconnect(&mut self) -> Result<()> {
        let mut fresh = ServeClient::connect_with(self.addr.as_str(), self.seed, &self.opts)?;
        // Fast-forward: the fresh seed-pinned session replays the stream
        // from the top; burn through everything already consumed.
        for (theta, m) in &self.history {
            fresh.draw(theta, *m)?;
        }
        self.generation = fresh.generation;
        self.inner = fresh;
        Ok(())
    }

    /// Like [`ServeClient::draw`], with reconnect-and-fast-forward on
    /// failure. Gives up (returning the last error) after the policy's
    /// retry budget.
    pub fn draw(&mut self, theta: &[f32], m: usize) -> Result<(u64, Vec<WeightedDraw>)> {
        let mut last: Option<Error> = None;
        for attempt in 0..=self.policy.max_retries {
            if attempt > 0 {
                thread::sleep(self.policy.backoff(attempt - 1));
                self.retries += 1;
                if let Err(e) = self.reconnect() {
                    last = Some(e);
                    continue;
                }
            }
            match self.inner.draw(theta, m) {
                Ok(out) => {
                    self.history.push((theta.to_vec(), m));
                    return Ok(out);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| Error::Pipeline("serving wire: retries exhausted".into())))
    }

    /// Polite goodbye on the live connection.
    pub fn bye(self) -> Result<()> {
        self.inner.bye()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::preprocess::{preprocess, PreprocessOptions};
    use crate::data::synth::SynthSpec;
    use crate::estimator::{GradientEstimator, ShardedLgdEstimator};
    use crate::lsh::srp::DenseSrp;

    fn setup(n: usize, d: usize, seed: u64) -> Arc<Preprocessed> {
        let ds = SynthSpec::power_law("serve", n, d, seed).generate().unwrap();
        Arc::new(preprocess(ds, &PreprocessOptions::default()).unwrap())
    }

    fn mk_core(pre: &Arc<Preprocessed>, shards: usize, sealed: bool) -> Arc<ServingCore<DenseSrp>> {
        let hd = pre.hashed.cols();
        let opts = LgdOptions { sealed, ..LgdOptions::default() };
        ServingCore::build(Arc::clone(pre), DenseSrp::new(hd, 3, 12, 101), opts, shards).unwrap()
    }

    /// The determinism contract: a session replays the estimator's batch
    /// stream under the same hasher/options/seed, for both table layouts.
    #[test]
    fn session_replays_estimator_batch_stream() {
        let pre = setup(200, 8, 21);
        let hd = pre.hashed.cols();
        let theta = vec![0.04f32; 8];
        for sealed in [true, false] {
            let core = mk_core(&pre, 3, sealed);
            let opts = LgdOptions { sealed, ..LgdOptions::default() };
            let mut est =
                ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 3, 12, 101), 7, opts, 3).unwrap();
            let mut sess = ServingSession::open(&core, 7);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            for round in 0..5 {
                est.draw_batch(&theta, 32, &mut a);
                sess.draw_batch(&theta, 32, &mut b);
                assert_eq!(a, b, "sealed={sealed} round {round}: session diverged");
            }
        }
    }

    /// Sessions share no mutable state: N concurrent sessions produce
    /// exactly the draws the same N sessions produce sequentially.
    #[test]
    fn concurrent_sessions_equal_sequential() {
        let pre = setup(180, 8, 33);
        let core = mk_core(&pre, 4, true);
        let theta = vec![0.05f32; 8];
        let run_one = |seed: u64| {
            let mut sess = ServingSession::open(&core, seed);
            let mut got = Vec::new();
            sess.run_pipelined(&theta, 16, 4, 64, |_, draws| {
                got.extend(draws.iter().copied());
                true
            })
            .unwrap();
            got
        };
        let sequential: Vec<Vec<WeightedDraw>> = (0..4).map(|c| run_one(900 + c)).collect();
        let concurrent: Vec<Vec<WeightedDraw>> = thread::scope(|scope| {
            let hs: Vec<_> = (0..4).map(|c| scope.spawn(move || run_one(900 + c))).collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(sequential, concurrent, "concurrency changed a draw stream");
    }

    /// Pipelined runs replay the synchronous session stream and hand the
    /// RNG back so sync draws continue it.
    #[test]
    fn pipelined_matches_sync_session_stream() {
        let pre = setup(160, 8, 41);
        let core = mk_core(&pre, 3, true);
        let theta = vec![0.03f32; 8];
        let (m, steps) = (24usize, 6usize);
        let mut sync = ServingSession::open(&core, 11);
        let mut piped = ServingSession::open(&core, 11);
        let mut want = Vec::new();
        let mut out = Vec::new();
        for _ in 0..steps {
            sync.draw_batch(&theta, m, &mut out);
            want.extend(out.iter().copied());
        }
        let mut got = Vec::new();
        let rep = piped
            .run_pipelined(&theta, m, steps, 64, |_, draws| {
                got.extend(draws.iter().copied());
                true
            })
            .unwrap();
        assert_eq!(rep.batches, steps);
        assert_eq!(rep.draws, (m * steps) as u64);
        assert_eq!(rep.stale_rejected, 0);
        assert_eq!(want, got, "pipelined session diverged from sync");
        // RNG hand-back: both continue identically
        let mut out2 = Vec::new();
        sync.draw_batch(&theta, m, &mut out);
        piped.draw_batch(&theta, m, &mut out2);
        assert_eq!(out, out2, "post-pipeline sync draws diverged");
    }

    /// Generation flips are copy-on-write: pinned sessions keep serving
    /// their generation untouched, refreshed sessions see the mutation and
    /// never serve rows dead in the new generation.
    #[test]
    fn flips_are_cow_and_refresh_respects_membership() {
        let pre = setup(150, 8, 51);
        let core = mk_core(&pre, 3, true);
        let theta = vec![0.04f32; 8];
        let mut pinned = ServingSession::open(&core, 5);
        let g0 = pinned.generation();
        for id in 0..50 {
            assert!(core.remove(id).unwrap());
        }
        assert!(core.generation() > g0, "flips must bump the published generation");
        assert_eq!(core.counters().flips, 50);
        // the pinned session still serves g0: all 150 ids remain valid there
        assert!(pinned.is_stale());
        assert_eq!(pinned.generation(), g0);
        let mut out = Vec::new();
        pinned.draw_batch(&theta, 64, &mut out);
        assert!(out.iter().all(|d| d.index < 150));
        // refreshed: the evicted block must never appear again
        assert!(pinned.refresh());
        assert!(!pinned.is_stale());
        for _ in 0..20 {
            pinned.draw_batch(&theta, 32, &mut out);
            assert!(
                out.iter().all(|d| d.index >= 50 && d.index < 150),
                "refreshed session served a dead row"
            );
        }
        // a freshly opened session starts on the new generation
        let mut fresh = ServingSession::open(&core, 6);
        assert_eq!(fresh.generation(), core.generation());
        fresh.draw_batch(&theta, 64, &mut out);
        assert!(out.iter().all(|d| d.index >= 50 && d.index < 150));
    }

    /// The consumer-side staleness filter: batches tagged with a foreign
    /// generation are dropped and counted, never delivered.
    #[test]
    fn deliver_batches_drops_stale_generations() {
        let q: DrawQueue<GenBatch> = DrawQueue::new(8);
        let d = WeightedDraw { index: 0, weight: 1.0, prob: 1.0 };
        for gen in [3u64, 7, 3, 2, 3] {
            assert!(q.push(GenBatch { gen, draws: vec![d; 4] }));
        }
        q.close();
        let mut stale = 0u64;
        let mut delivered_draws = 0usize;
        let (delivered, stopped) = deliver_batches(&q, 3, 10, &mut stale, &mut |_, draws| {
            delivered_draws += draws.len();
            true
        });
        assert_eq!(delivered, 3, "three live-generation batches");
        assert_eq!(stale, 2, "two foreign-generation batches rejected");
        assert_eq!(delivered_draws, 12);
        assert!(!stopped, "the queue drained; the callback never said stop");
        // a callback stop is reported as such
        let q2: DrawQueue<GenBatch> = DrawQueue::new(4);
        assert!(q2.push(GenBatch { gen: 1, draws: vec![d; 2] }));
        assert!(q2.push(GenBatch { gen: 1, draws: vec![d; 2] }));
        q2.close();
        let (delivered, stopped) = deliver_batches(&q2, 1, 10, &mut stale, &mut |_, _| false);
        assert_eq!(delivered, 1);
        assert!(stopped, "the callback ended the run");
    }

    /// The harness aggregates across clients and observes zero stale
    /// rejects on a quiescent core.
    #[test]
    fn harness_aggregates_across_clients() {
        let pre = setup(120, 6, 61);
        let core = mk_core(&pre, 2, true);
        let theta = vec![0.05f32; 6];
        let rep = run_harness(&core, 4, 5, 16, &theta, 77).unwrap();
        assert_eq!(rep.clients, 4);
        assert_eq!(rep.draws, 4 * 5 * 16);
        assert_eq!(rep.stale_rejected, 0);
        assert!(rep.draws_per_sec > 0.0);
        let c = core.counters();
        assert_eq!(c.sessions, 4);
        assert_eq!(c.draws_served, rep.draws);
        assert_eq!(c.stale_rejected, 0);
        assert!(run_harness(&core, 0, 1, 1, &theta, 1).is_err());
    }

    /// TCP round trip: a served client's draws equal an in-process session
    /// with the same seed, concurrent clients each get their own stream,
    /// and protocol errors answer cleanly.
    #[test]
    fn tcp_serving_round_trip() {
        let pre = setup(140, 8, 71);
        let core = mk_core(&pre, 3, true);
        let theta = vec![0.04f32; 8];
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        thread::scope(|scope| {
            let corer = &core;
            let stopr = &stop;
            let server = scope.spawn(move || serve_tcp(corer, listener, stopr));
            // reference stream: in-process session, same seed
            let mut reference = ServingSession::open(&core, 1234);
            let mut want = Vec::new();
            reference.draw_batch(&theta, 20, &mut want);
            let mut client = ServeClient::connect(addr, 1234).unwrap();
            assert_eq!(client.generation, core.generation());
            let (gen, got) = client.draw(&theta, 20).unwrap();
            assert_eq!(gen, core.generation());
            assert_eq!(want, got, "wire round trip changed the draw stream");
            // a second concurrent client gets its own independent stream
            let mut other = ServeClient::connect(addr, 4321).unwrap();
            let (_, draws2) = other.draw(&theta, 20).unwrap();
            assert!(draws2.iter().all(|d| d.index < 140 && d.prob > 0.0));
            other.bye().unwrap();
            // DRAW before HELLO answers an error frame, not a hang
            let mut raw = TcpStream::connect(addr).unwrap();
            let mut p = vec![OP_DRAW];
            p.extend_from_slice(&20u32.to_le_bytes());
            p.extend_from_slice(&0u32.to_le_bytes());
            write_frame(&mut raw, &p).unwrap();
            let resp = read_frame(&mut raw).unwrap().unwrap();
            assert_eq!(resp[0], ST_ERR);
            drop(raw);
            client.bye().unwrap();
            stop.store(true, Ordering::Relaxed);
            let served = server.join().unwrap().unwrap();
            assert_eq!(served, 40, "two 20-draw requests served");
        });
        assert!(core.counters().draws_served >= 60, "reference + wire draws counted");
    }

    /// A drained published generation serves uniform fallbacks (weight 1)
    /// instead of hanging — through sessions and the harness alike.
    #[test]
    fn drained_generation_serves_uniform_fallbacks() {
        let pre = setup(40, 6, 81);
        let core = mk_core(&pre, 2, true);
        for id in 0..40 {
            assert!(core.remove(id).unwrap());
        }
        assert_eq!(core.pin().total_rows(), 0);
        let mut sess = ServingSession::open(&core, 9);
        let mut out = Vec::new();
        sess.draw_batch(&[0.1; 6], 16, &mut out);
        assert_eq!(out.len(), 16);
        assert!(out.iter().all(|d| d.index < 40 && d.weight == 1.0));
        assert_eq!(sess.stats().fallbacks, 16);
        let rep = run_harness(&core, 2, 2, 8, &[0.1; 6], 3).unwrap();
        assert_eq!(rep.draws, 2 * 2 * 8);
    }

    /// Wire-protocol torture under the supervised front: mid-frame
    /// disconnects, oversized length headers, truncated DRAW payloads,
    /// DRAW before HELLO, and out-of-range dims all answer (or close)
    /// cleanly — and a healthy client still gets served afterwards. None
    /// of it surfaces as a server error: `Err` is reserved for the
    /// listener.
    #[test]
    fn wire_torture_cases_never_take_the_server_down() {
        let d = 6usize;
        let pre = setup(100, d, 91);
        let core = mk_core(&pre, 2, true);
        let theta = vec![0.05f32; d];
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        let opts = ServeOptions { idle_timeout: Duration::from_millis(600), ..Default::default() };
        thread::scope(|scope| {
            let corer = &core;
            let stopr = &stop;
            let optsr = &opts;
            let server = scope.spawn(move || serve_supervised(corer, listener, stopr, optsr));

            // mid-frame disconnect: a header promising 100 bytes, then 3
            // bytes, then gone
            let mut raw = TcpStream::connect(addr).unwrap();
            raw.write_all(&100u32.to_le_bytes()).unwrap();
            raw.write_all(&[1, 2, 3]).unwrap();
            drop(raw);

            // oversized length header: answered with an error frame
            let mut raw = TcpStream::connect(addr).unwrap();
            raw.write_all(&(MAX_FRAME + 1).to_le_bytes()).unwrap();
            let resp = read_frame(&mut raw).unwrap().unwrap();
            assert_eq!(resp[0], ST_ERR, "oversized header must answer an error frame");
            drop(raw);

            // truncated DRAW payload: dim claims the full width, the
            // frame carries only 2 floats
            let client = ServeClient::connect(addr, 5).unwrap();
            let mut stream = client.stream;
            let mut p = vec![OP_DRAW];
            p.extend_from_slice(&4u32.to_le_bytes());
            p.extend_from_slice(&(d as u32).to_le_bytes());
            p.extend_from_slice(&0.5f32.to_le_bytes());
            p.extend_from_slice(&0.5f32.to_le_bytes());
            write_frame(&mut stream, &p).unwrap();
            let resp = read_frame(&mut stream).unwrap().unwrap();
            assert_eq!(resp[0], ST_ERR, "truncated payload must answer an error frame");
            drop(stream);

            // DRAW before HELLO
            let mut raw = TcpStream::connect(addr).unwrap();
            let mut p = vec![OP_DRAW];
            p.extend_from_slice(&8u32.to_le_bytes());
            p.extend_from_slice(&(d as u32).to_le_bytes());
            p.extend_from_slice(&vec![0u8; 4 * d]);
            write_frame(&mut raw, &p).unwrap();
            let resp = read_frame(&mut raw).unwrap().unwrap();
            assert_eq!(resp[0], ST_ERR, "DRAW before HELLO must answer an error frame");
            drop(raw);

            // dim boundary sweep: only the dataset dim is accepted
            for (dim, ok) in [(0usize, false), (d - 1, false), (d, true), (d + 1, false)] {
                let mut c = ServeClient::connect(addr, 9).unwrap();
                let th = vec![0.1f32; dim];
                assert_eq!(c.draw(&th, 8).is_ok(), ok, "dim={dim}");
            }

            // after all the abuse, a healthy client is served normally
            let mut healthy = ServeClient::connect(addr, 1234).unwrap();
            let (_, got) = healthy.draw(&theta, 16).unwrap();
            assert_eq!(got.len(), 16);
            healthy.bye().unwrap();

            stop.store(true, Ordering::Relaxed);
            let totals = server.join().unwrap().unwrap();
            assert!(totals.draws >= 16 + 8, "dim=d probe + healthy client draws");
            assert!(totals.connections >= 8);
            assert!(totals.conn_errors >= 1, "the mid-frame disconnect is an isolated error");
            assert_eq!(totals.rejected_at_capacity, 0);
        });
    }

    /// The pool bound: with `max_clients = 2`, a third live connection is
    /// turned away with an error frame and counted — and gets in once a
    /// slot frees up.
    #[test]
    fn capacity_bound_rejects_excess_clients() {
        let pre = setup(80, 6, 93);
        let core = mk_core(&pre, 2, true);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        let opts = ServeOptions { max_clients: 2, ..Default::default() };
        thread::scope(|scope| {
            let corer = &core;
            let stopr = &stop;
            let optsr = &opts;
            let server = scope.spawn(move || serve_supervised(corer, listener, stopr, optsr));
            let a = ServeClient::connect(addr, 1).unwrap();
            let b = ServeClient::connect(addr, 2).unwrap();
            // third connection: rejected at HELLO with the capacity error
            match ServeClient::connect(addr, 3) {
                Err(Error::Pipeline(msg)) => {
                    assert!(msg.contains("capacity"), "unexpected rejection: {msg}")
                }
                other => panic!("expected a capacity rejection, got {:?}", other.is_ok()),
            }
            // free a slot; the pool admits a new client again
            a.bye().unwrap();
            thread::sleep(Duration::from_millis(200));
            let c = ServeClient::connect(addr, 4).unwrap();
            c.bye().unwrap();
            b.bye().unwrap();
            stop.store(true, Ordering::Relaxed);
            let totals = server.join().unwrap().unwrap();
            assert_eq!(totals.rejected_at_capacity, 1);
            assert_eq!(totals.connections, 3, "rejected connections never enter the pool");
        });
    }

    /// The wire STATS op round-trips the server counters (and works
    /// before HELLO).
    #[test]
    fn stats_op_reports_server_counters() {
        let pre = setup(90, 6, 95);
        let core = mk_core(&pre, 2, true);
        let theta = vec![0.05f32; 6];
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        thread::scope(|scope| {
            let corer = &core;
            let stopr = &stop;
            let server = scope.spawn(move || serve_tcp(corer, listener, stopr));
            let mut client = ServeClient::connect(addr, 7).unwrap();
            client.draw(&theta, 20).unwrap();
            client.draw(&theta, 12).unwrap();
            let s = client.stats().unwrap();
            assert!(s.sessions >= 1);
            assert_eq!(s.draws_served, 32);
            assert_eq!(s.stale_rejected, 0);
            assert_eq!(s.degraded_sessions, 0);
            assert_eq!(s.connections, 1);
            assert_eq!(s.conn_errors, 0);
            assert_eq!(s.rejected_at_capacity, 0);
            client.bye().unwrap();
            stop.store(true, Ordering::Relaxed);
            assert_eq!(server.join().unwrap().unwrap(), 32);
        });
    }

    /// The METRICS op answers a strictly-valid Prometheus exposition
    /// covering counters, gauges and histogram buckets, with the gated
    /// counters visible at 0; the STATS appendix dumps the same registry
    /// as name → value pairs (old clients read the 8 u64s and stop).
    #[test]
    fn metrics_op_returns_valid_prometheus_and_stats_appendix() {
        let pre = setup(90, 6, 99);
        let core = mk_core(&pre, 2, true);
        let theta = vec![0.05f32; 6];
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        thread::scope(|scope| {
            let corer = &core;
            let stopr = &stop;
            let server = scope.spawn(move || serve_tcp(corer, listener, stopr));
            let mut client = ServeClient::connect(addr, 7).unwrap();
            client.draw(&theta, 24).unwrap();

            let text = client.metrics().unwrap();
            let sum = prom::validate(&text).expect("METRICS must be valid Prometheus text");
            assert!(sum.counters >= 1 && sum.gauges >= 1 && sum.histograms >= 1);
            assert!(text.contains("lgd_serve_draws_served"));
            assert!(text.contains("lgd_serve_request_secs_seconds_bucket{le=\"+Inf\"}"));
            assert!(text.contains("lgd_serve_generation"));
            // PR-7/8/9 gated counters: visible, and (structurally) zero.
            assert!(text.contains("lgd_serve_stale_candidates_rejected 0"));
            assert!(text.contains("lgd_serve_degraded_sessions 0"));
            // Registered for exposure even before any rollback happens
            // (value asserted 0 in the CI smoke against a fresh process;
            // here trainer tests in the same binary may have bumped it).
            assert!(text.contains("lgd_health_rollbacks"));

            let (stats, registry) = client.stats_full().unwrap();
            assert_eq!(stats.draws_served, 24);
            assert_eq!(stats.stale_rejected, 0);
            let get = |k: &str| registry.iter().find(|(n, _)| n == k).map(|(_, v)| *v);
            assert!(get("serve.draws_served").unwrap() >= 24.0);
            assert_eq!(get("serve.stale_candidates_rejected"), Some(0.0));
            assert_eq!(get("serve.degraded_sessions"), Some(0.0));
            assert!(get("serve.request_secs.count").unwrap() >= 1.0);
            // The compact client still parses the extended response.
            let s2 = client.stats().unwrap();
            assert_eq!(s2.draws_served, 24);
            client.bye().unwrap();
            stop.store(true, Ordering::Relaxed);
            server.join().unwrap().unwrap();
        });
    }

    /// Bitwise-invisibility gate (serving side): arming the sampling
    /// probes changes nothing about a session's draw stream — same seed,
    /// same draws, armed or not. Probes observe; they never touch the RNG.
    #[test]
    fn armed_probes_leave_serve_draw_stream_identical() {
        let pre = setup(120, 6, 103);
        let core = mk_core(&pre, 3, true);
        let theta = vec![0.03f32; 6];
        probes::disarm();
        let mut plain = Vec::new();
        let mut sess = ServingSession::open(&core, 4242);
        for _ in 0..4 {
            let mut b = Vec::new();
            sess.draw_batch(&theta, 32, &mut b);
            plain.extend(b);
        }
        probes::arm(256, 120);
        let mut armed = Vec::new();
        let mut sess = ServingSession::open(&core, 4242);
        for _ in 0..4 {
            let mut b = Vec::new();
            sess.draw_batch(&theta, 32, &mut b);
            armed.extend(b);
        }
        probes::disarm();
        assert_eq!(plain, armed, "armed probes perturbed the draw stream");
    }

    /// The retry client's deterministic backoff schedule and its plain
    /// (failure-free) operation: same stream as a ServeClient, zero
    /// retries. The reconnect-and-fast-forward path itself is exercised in
    /// `tests/chaos.rs` with the TCP_READ failpoint armed.
    #[test]
    fn retry_client_matches_plain_client_without_failures() {
        let policy = RetryPolicy::default();
        assert_eq!(policy.backoff(0), Duration::from_millis(10));
        assert_eq!(policy.backoff(1), Duration::from_millis(20));
        assert_eq!(policy.backoff(2), Duration::from_millis(40));
        assert_eq!(policy.backoff(12), Duration::from_millis(500), "capped at max_backoff");
        let pre = setup(110, 6, 97);
        let core = mk_core(&pre, 2, true);
        let theta = vec![0.04f32; 6];
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        thread::scope(|scope| {
            let corer = &core;
            let stopr = &stop;
            let server = scope.spawn(move || serve_tcp(corer, listener, stopr));
            let mut reference = ServingSession::open(&core, 55);
            let mut want = Vec::new();
            let mut batch = Vec::new();
            for _ in 0..3 {
                reference.draw_batch(&theta, 16, &mut batch);
                want.extend(batch.iter().copied());
            }
            let mut rc = RetryClient::connect(
                &addr.to_string(),
                55,
                ClientOptions::default(),
                RetryPolicy { base_backoff: Duration::from_millis(1), ..Default::default() },
            )
            .unwrap();
            let mut got = Vec::new();
            for _ in 0..3 {
                let (_, draws) = rc.draw(&theta, 16).unwrap();
                got.extend(draws);
            }
            assert_eq!(want, got, "retry client diverged from the session stream");
            assert_eq!(rc.retries(), 0, "no failures, no retries");
            rc.bye().unwrap();
            stop.store(true, Ordering::Relaxed);
            server.join().unwrap().unwrap();
        });
    }
}
