//! PJRT-backed linear-model gradients: wraps the `linreg_*`/`logreg_*`
//! artifacts so the trainer can execute the L1 Pallas kernels (lowered into
//! the HLO) from the Rust hot path.

use crate::core::error::{Error, Result};
use crate::data::dataset::{Dataset, Task};
use crate::runtime::executor::{lit_f32, to_f32, to_vec_f32, Runtime};

/// A PJRT gradient/loss evaluator bound to one (batch, dim) entry pair.
pub struct PjrtLinear {
    grad_entry: String,
    loss_entry: String,
    batch: usize,
    loss_batch: usize,
    dim: usize,
    // preallocated staging buffers
    xb: Vec<f32>,
    yb: Vec<f32>,
    wb: Vec<f32>,
}

impl PjrtLinear {
    /// Resolve entries for a task/batch/dim combination, e.g.
    /// (`Regression`, 1, 90) → `linreg_grad_b1_d90` + `linreg_loss_b1024_d90`.
    pub fn new(rt: &mut Runtime, task: Task, batch: usize, dim: usize) -> Result<Self> {
        let prefix = match task {
            Task::Regression => "linreg",
            Task::Classification => "logreg",
        };
        let grad_entry = format!("{prefix}_grad_b{batch}_d{dim}");
        let loss_batch = 1024;
        let loss_entry = format!("{prefix}_loss_b{loss_batch}_d{dim}");
        rt.load(&grad_entry)?;
        rt.load(&loss_entry)?;
        Ok(PjrtLinear {
            grad_entry,
            loss_entry,
            batch,
            loss_batch,
            dim,
            xb: vec![0.0; batch * dim],
            yb: vec![0.0; batch],
            wb: vec![0.0; batch],
        })
    }

    /// Gradient estimate from a weighted batch of examples.
    /// `idx.len()` must equal the compiled batch size.
    pub fn grad(
        &mut self,
        rt: &mut Runtime,
        ds: &Dataset,
        idx: &[usize],
        weights: &[f64],
        theta: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        if idx.len() != self.batch || weights.len() != self.batch {
            return Err(Error::Runtime(format!(
                "batch {} vs compiled {}",
                idx.len(),
                self.batch
            )));
        }
        if theta.len() != self.dim || out.len() != self.dim {
            return Err(Error::Runtime("theta/out dim mismatch".into()));
        }
        for (r, &i) in idx.iter().enumerate() {
            let (x, y) = ds.example(i);
            self.xb[r * self.dim..(r + 1) * self.dim].copy_from_slice(x);
            self.yb[r] = y;
            self.wb[r] = weights[r] as f32;
        }
        let args = [
            lit_f32(&self.xb, &[self.batch, self.dim])?,
            lit_f32(&self.yb, &[self.batch])?,
            lit_f32(theta, &[self.dim])?,
            lit_f32(&self.wb, &[self.batch])?,
        ];
        let outs = rt.execute(&self.grad_entry, &args)?;
        let g = to_vec_f32(&outs[0])?;
        out.copy_from_slice(&g);
        Ok(())
    }

    /// Mean loss over a dataset, chunked through the fixed-batch loss entry
    /// (padding rows contribute zero residual for linreg; for logreg they
    /// are corrected exactly via the ln(2) offset of zero-padded rows).
    pub fn mean_loss(&mut self, rt: &mut Runtime, ds: &Dataset, theta: &[f32]) -> Result<f64> {
        let n = ds.len();
        let lb = self.loss_batch;
        let mut total = 0.0f64;
        let mut xbuf = vec![0.0f32; lb * self.dim];
        let mut ybuf = vec![0.0f32; lb];
        let mut i = 0usize;
        while i < n {
            let take = (n - i).min(lb);
            for r in 0..take {
                let (x, y) = ds.example(i + r);
                xbuf[r * self.dim..(r + 1) * self.dim].copy_from_slice(x);
                ybuf[r] = y;
            }
            // zero padding
            for r in take..lb {
                xbuf[r * self.dim..(r + 1) * self.dim].fill(0.0);
                ybuf[r] = 0.0;
            }
            let args = [
                lit_f32(&xbuf, &[lb, self.dim])?,
                lit_f32(&ybuf, &[lb])?,
                lit_f32(theta, &[self.dim])?,
            ];
            let outs = rt.execute(&self.loss_entry, &args)?;
            let mean_chunk = to_f32(&outs[0])? as f64;
            let mut sum_chunk = mean_chunk * lb as f64;
            if ds.task == Task::Classification {
                // zero-padded logreg rows contribute ln(1 + e^0) = ln 2 each
                sum_chunk -= (lb - take) as f64 * (2.0f64).ln();
            }
            total += sum_chunk;
            i += take;
        }
        Ok(total / n as f64)
    }

    /// Compiled batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }
}
