//! Mini-BERT fine-tuning session (Appendix E): parameters live as host
//! vectors, gradients come from the `bert_grad_b32` artifact, the Adam
//! update runs in Rust (L3 owns optimisation), and `pooled()` exposes the
//! [CLS] representations the LSH tables index.

use std::path::Path;

use crate::core::error::{Error, Result};
use crate::optim::{Adam, Optimizer};
use crate::runtime::artifact::BertAbi;
use crate::runtime::executor::{lit_f32, lit_i32, to_f32, to_vec_f32, Runtime};

/// A fine-tuning session over the mini-BERT artifacts.
pub struct BertSession {
    abi: BertAbi,
    /// Flat parameter buffers, ABI order.
    params: Vec<Vec<f32>>,
    /// One Adam state per parameter tensor.
    opt: Vec<Adam>,
    grad_batch: usize,
    eval_batch: usize,
}

impl BertSession {
    /// Load ABI + initial parameters from the artifacts directory.
    pub fn new(rt: &mut Runtime, lr: f64) -> Result<Self> {
        let abi = rt
            .manifest()
            .bert
            .clone()
            .ok_or_else(|| Error::Runtime("manifest has no bert block".into()))?;
        let init_file = abi
            .init_file
            .clone()
            .ok_or_else(|| Error::Runtime("manifest bert block has no init_file".into()))?;
        let npz_path = rt.manifest().dir.join(&init_file);
        let params = load_params_npz(&npz_path, &abi)?;
        rt.load("bert_grad_b32")?;
        rt.load("bert_logits_b64")?;
        rt.load("bert_pooled_b64")?;
        let opt = (0..params.len()).map(|_| Adam::new(lr)).collect();
        Ok(BertSession { abi, params, opt, grad_batch: 32, eval_batch: 64 })
    }

    /// The parameter ABI.
    pub fn abi(&self) -> &BertAbi {
        &self.abi
    }

    /// Gradient batch size the artifact was compiled for.
    pub fn grad_batch(&self) -> usize {
        self.grad_batch
    }

    /// Eval/pooled batch size the artifacts were compiled for.
    pub fn eval_batch(&self) -> usize {
        self.eval_batch
    }

    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        self.params
            .iter()
            .zip(&self.abi.param_shapes)
            .map(|(p, s)| lit_f32(p, s))
            .collect()
    }

    /// One importance-weighted Adam step on a batch of `grad_batch`
    /// sequences. Returns the (weighted) batch loss.
    pub fn step(
        &mut self,
        rt: &mut Runtime,
        ids: &[i32],
        labels: &[i32],
        weights: &[f32],
    ) -> Result<f64> {
        let b = self.grad_batch;
        let t = self.abi.max_t;
        if ids.len() != b * t || labels.len() != b || weights.len() != b {
            return Err(Error::Runtime(format!(
                "bert step shapes: ids {} labels {} weights {} for b={b} t={t}",
                ids.len(),
                labels.len(),
                weights.len()
            )));
        }
        let mut args = self.param_literals()?;
        args.push(lit_i32(ids, &[b, t])?);
        args.push(lit_i32(labels, &[b])?);
        args.push(lit_f32(weights, &[b])?);
        let outs = rt.execute("bert_grad_b32", &args)?;
        let loss = to_f32(&outs[0])? as f64;
        // outs[1..] are gradients in ABI order; Adam-update each tensor.
        for (i, g) in outs[1..].iter().enumerate() {
            let gv = to_vec_f32(g)?;
            self.opt[i].step(&mut self.params[i], &gv);
        }
        Ok(loss)
    }

    /// Classifier logits for an eval batch (`eval_batch` sequences).
    pub fn logits(&self, rt: &mut Runtime, ids: &[i32]) -> Result<Vec<f32>> {
        let b = self.eval_batch;
        let t = self.abi.max_t;
        if ids.len() != b * t {
            return Err(Error::Runtime(format!("bert logits: ids {} for b={b}", ids.len())));
        }
        let mut args = self.param_literals()?;
        args.push(lit_i32(ids, &[b, t])?);
        let outs = rt.execute("bert_logits_b64", &args)?;
        to_vec_f32(&outs[0])
    }

    /// Pooled [CLS] representations for an eval batch — the hash-space
    /// vectors of Appendix E.
    pub fn pooled(&self, rt: &mut Runtime, ids: &[i32]) -> Result<Vec<f32>> {
        let b = self.eval_batch;
        let t = self.abi.max_t;
        if ids.len() != b * t {
            return Err(Error::Runtime(format!("bert pooled: ids {} for b={b}", ids.len())));
        }
        let mut args = self.param_literals()?;
        args.push(lit_i32(ids, &[b, t])?);
        let outs = rt.execute("bert_pooled_b64", &args)?;
        to_vec_f32(&outs[0])
    }

    /// Total parameter count (diagnostics).
    pub fn n_params(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Borrow one parameter tensor (flat) by ABI index.
    pub fn param(&self, i: usize) -> &[f32] {
        &self.params[i]
    }
}

/// Load ABI-ordered parameters from the `bert_init.npz` written by aot.py
/// (keys are `p{idx:03}_{name}`, so lexicographic order is ABI order).
fn load_params_npz(path: &Path, abi: &BertAbi) -> Result<Vec<Vec<f32>>> {
    use xla::FromRawBytes;
    let mut named: Vec<(String, xla::Literal)> = xla::Literal::read_npz(path, &())
        .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
    named.sort_by(|a, b| a.0.cmp(&b.0));
    if named.len() != abi.param_shapes.len() {
        return Err(Error::Runtime(format!(
            "{} params in npz, ABI wants {}",
            named.len(),
            abi.param_shapes.len()
        )));
    }
    let mut out = Vec::with_capacity(named.len());
    for (i, (name, lit)) in named.iter().enumerate() {
        let want: usize = abi.param_shapes[i].iter().product();
        let v = to_vec_f32(lit)?;
        if v.len() != want {
            return Err(Error::Runtime(format!(
                "param {i} ({name}): {} elements, ABI wants {want}",
                v.len()
            )));
        }
        out.push(v);
    }
    Ok(out)
}
