//! PJRT executor: loads AOT HLO-text artifacts, compiles them once on the
//! CPU client, and executes them from the L3 hot path with shape-checked
//! literal arguments. Adapted from `/opt/xla-example/load_hlo/`.

use std::collections::HashMap;
use std::path::Path;

use crate::core::error::{Error, Result};
use crate::runtime::artifact::{Dtype, Manifest, TensorSpec};

fn xerr(ctx: &str, e: xla::Error) -> Error {
    Error::Runtime(format!("{ctx}: {e}"))
}

/// The PJRT runtime: one CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create from an artifacts directory (must contain `manifest.json`).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| xerr("PjRtClient::cpu", e))?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure `entry` is compiled and cached. Returns compile time cost only
    /// on first call.
    pub fn load(&mut self, entry: &str) -> Result<()> {
        if self.cache.contains_key(entry) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(entry)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| xerr(&format!("parse {}", path.display()), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| xerr(&format!("compile {entry}"), e))?;
        self.cache.insert(entry.to_string(), exe);
        Ok(())
    }

    /// Execute `entry` with positional literal arguments; returns the output
    /// tuple as a vector of literals. Arguments are validated against the
    /// manifest specs (count + element counts + dtype).
    pub fn execute(&mut self, entry: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(entry)?;
        let spec = self.manifest.entry(entry)?.clone();
        if args.len() != spec.args.len() {
            return Err(Error::Runtime(format!(
                "{entry}: {} args given, manifest wants {}",
                args.len(),
                spec.args.len()
            )));
        }
        for (i, (lit, want)) in args.iter().zip(&spec.args).enumerate() {
            let n = lit.element_count();
            if n != want.elements() {
                return Err(Error::Runtime(format!(
                    "{entry} arg {i}: {n} elements, manifest wants {} (shape {:?})",
                    want.elements(),
                    want.shape
                )));
            }
        }
        let exe = self.cache.get(entry).expect("loaded above");
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| xerr(&format!("execute {entry}"), e))?[0][0]
            .to_literal_sync()
            .map_err(|e| xerr("to_literal_sync", e))?;
        let outs = result.to_tuple().map_err(|e| xerr("to_tuple", e))?;
        if outs.len() != spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{entry}: {} outputs, manifest wants {}",
                outs.len(),
                spec.outputs.len()
            )));
        }
        Ok(outs)
    }

    /// Number of compiled executables held.
    pub fn loaded(&self) -> usize {
        self.cache.len()
    }
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Runtime(format!("lit_f32: {} values for shape {shape:?}", data.len())));
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| xerr("reshape", e))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Runtime(format!("lit_i32: {} values for shape {shape:?}", data.len())));
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| xerr("reshape", e))
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| xerr("to_vec<f32>", e))
}

/// Extract a u32 vector from a literal.
pub fn to_vec_u32(lit: &xla::Literal) -> Result<Vec<u32>> {
    lit.to_vec::<u32>().map_err(|e| xerr("to_vec<u32>", e))
}

/// Extract the first f32 (scalar outputs).
pub fn to_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| xerr("get_first_element", e))
}

/// Validate a spec/dtype pair (used by integration tests).
pub fn dtype_matches(spec: &TensorSpec, dt: Dtype) -> bool {
    spec.dtype == dt
}
