//! Runtime layer: the PJRT bridge (manifest parsing, executable cache, and
//! the model-specific sessions that execute the AOT HLO artifacts from the
//! Rust hot path) plus the epoch-based concurrent serving engine.

pub mod artifact;
pub mod bert;
pub mod executor;
pub mod linear;
pub mod serving;

pub use artifact::{BertAbi, Dtype, EntrySpec, Manifest, TensorSpec};
pub use bert::BertSession;
pub use executor::{lit_f32, lit_i32, to_f32, to_vec_f32, to_vec_u32, Runtime};
pub use linear::PjrtLinear;
pub use serving::{
    run_harness, serve_supervised, serve_tcp, ClientOptions, HarnessReport, RetryClient,
    RetryPolicy, ServeClient, ServeOptions, ServeReport, ServeTotals, ServingCore,
    ServingCounters, ServingSession, WireStats,
};

use std::path::PathBuf;

/// Default artifacts directory: `$LGD_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("LGD_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
