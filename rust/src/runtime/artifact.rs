//! Artifact manifest: the ABI contract between the Python AOT pipeline and
//! the Rust runtime. `python -m compile.aot` writes
//! `artifacts/manifest.json`; this module parses and validates it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::json::Json;
use crate::core::error::{Error, Result};

/// Element dtype of an argument/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// float32
    F32,
    /// int32
    S32,
    /// uint32
    U32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" => Ok(Dtype::S32),
            "u32" => Ok(Dtype::U32),
            other => Err(Error::Runtime(format!("unknown dtype '{other}'"))),
        }
    }
}

/// Shape + dtype of one argument or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| Error::Runtime("spec missing shape".into()))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| Error::Runtime("bad dim".into())))
            .collect::<Result<Vec<usize>>>()?;
        let dtype = Dtype::parse(
            j.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32"),
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One compiled entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    /// HLO text file (relative to the artifacts dir).
    pub file: String,
    /// Argument specs, positional.
    pub args: Vec<TensorSpec>,
    /// Output specs (the HLO returns a tuple of these).
    pub outputs: Vec<TensorSpec>,
}

/// The mini-BERT parameter ABI.
#[derive(Debug, Clone)]
pub struct BertAbi {
    /// Parameter names, ABI order.
    pub param_names: Vec<String>,
    /// Parameter shapes, ABI order.
    pub param_shapes: Vec<Vec<usize>>,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length.
    pub max_t: usize,
    /// Hidden width (pooled-representation dimension fed to LSH).
    pub d_model: usize,
    /// Number of classes.
    pub n_classes: usize,
    /// Initial-parameter npz file, when present.
    pub init_file: Option<String>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Entry points by name.
    pub entries: BTreeMap<String, EntrySpec>,
    /// BERT ABI block.
    pub bert: Option<BertAbi>,
    /// SimHash (K, L) the simhash artifacts were compiled with.
    pub simhash_kl: Option<(usize, usize)>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!("{}: {e} (run `make artifacts`)", path.display()))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text)?;
        if j.get("format").and_then(|f| f.as_str()) != Some("hlo-text") {
            return Err(Error::Runtime("manifest format must be 'hlo-text'".into()));
        }
        let mut entries = BTreeMap::new();
        let eobj = j
            .get("entries")
            .and_then(|e| e.as_obj())
            .ok_or_else(|| Error::Runtime("manifest missing entries".into()))?;
        for (name, spec) in eobj {
            let file = spec
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| Error::Runtime(format!("entry {name} missing file")))?
                .to_string();
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                spec.get(key)
                    .and_then(|a| a.as_arr())
                    .ok_or_else(|| Error::Runtime(format!("entry {name} missing {key}")))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec { file, args: parse_list("args")?, outputs: parse_list("outputs")? },
            );
        }
        let bert = j.get("bert").and_then(|b| {
            let names: Vec<String> = b
                .get("param_names")?
                .as_arr()?
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect();
            let shapes: Vec<Vec<usize>> = b
                .get("param_shapes")?
                .as_arr()?
                .iter()
                .filter_map(|s| {
                    s.as_arr()
                        .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                })
                .collect();
            Some(BertAbi {
                param_names: names,
                param_shapes: shapes,
                vocab: b.get("vocab")?.as_usize()?,
                max_t: b.get("max_t")?.as_usize()?,
                d_model: b.get("d_model")?.as_usize()?,
                n_classes: b.get("n_classes")?.as_usize()?,
                init_file: b.get("init_file").and_then(|f| f.as_str()).map(String::from),
            })
        });
        let simhash_kl = j.get("simhash").and_then(|s| {
            Some((s.get("k")?.as_usize()?, s.get("l")?.as_usize()?))
        });
        Ok(Manifest { dir: dir.to_path_buf(), entries, bert, simhash_kl })
    }

    /// Entry lookup with a helpful error.
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries.get(name).ok_or_else(|| {
            Error::Runtime(format!(
                "entry '{name}' not in manifest (have: {})",
                self.entries.keys().cloned().collect::<Vec<_>>().join(", ")
            ))
        })
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.entry(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "entries": {
        "linreg_grad_b1_d90": {
          "file": "linreg_grad_b1_d90.hlo.txt",
          "args": [{"shape": [1, 90], "dtype": "f32"}, {"shape": [1], "dtype": "f32"},
                   {"shape": [90], "dtype": "f32"}, {"shape": [1], "dtype": "f32"}],
          "outputs": [{"shape": [90], "dtype": "f32"}]
        }
      },
      "bert": {
        "param_names": ["tok_emb"], "param_shapes": [[1024, 64]],
        "vocab": 1024, "max_t": 32, "d_model": 64, "n_classes": 2,
        "init_file": "bert_init.npz"
      },
      "simhash": {"k": 5, "l": 100}
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let e = m.entry("linreg_grad_b1_d90").unwrap();
        assert_eq!(e.args.len(), 4);
        assert_eq!(e.args[0].shape, vec![1, 90]);
        assert_eq!(e.args[0].dtype, Dtype::F32);
        assert_eq!(e.outputs[0].elements(), 90);
        let b = m.bert.as_ref().unwrap();
        assert_eq!(b.vocab, 1024);
        assert_eq!(b.init_file.as_deref(), Some("bert_init.npz"));
        assert_eq!(m.simhash_kl, Some((5, 100)));
        assert!(m.entry("nope").is_err());
        assert_eq!(
            m.hlo_path("linreg_grad_b1_d90").unwrap(),
            PathBuf::from("/tmp/a/linreg_grad_b1_d90.hlo.txt")
        );
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn parses_real_manifest_when_built() {
        // Integration hook: if `make artifacts` has run, parse the result.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entries.contains_key("linreg_grad_b1_d90"));
            assert!(m.bert.is_some());
        }
    }
}
