//! The (K, L) hash-table structure of Appendix A.1 / Figure 7.
//!
//! `L` independent tables, each keyed by a K-bit meta-hash code, each bucket
//! holding the *ids* of the stored points (never the vectors themselves —
//! the paper stores pointers for memory efficiency; we store `u32` ids into
//! the caller's dataset).
//!
//! Building the tables is the one-time preprocessing cost of LGD; queries
//! and incremental inserts/removes are O(K·density·d) per table.

use std::collections::HashMap;

use crate::core::error::{Error, Result};
use crate::lsh::srp::SrpHasher;

/// Bucket storage for one table: direct-indexed array for small key spaces
/// (K ≤ 12 — the paper's K=5 gives 32 buckets), HashMap beyond. The dense
/// variant turns the per-probe bucket lookup into one array index — a
/// measurable win on the Algorithm-1 hot path (§Perf).
enum Buckets {
    Dense(Vec<Vec<u32>>),
    Map(HashMap<u32, Vec<u32>>),
}

impl Buckets {
    fn new(k: usize) -> Self {
        if k <= 12 {
            Buckets::Dense((0..(1usize << k)).map(|_| Vec::new()).collect())
        } else {
            Buckets::Map(HashMap::new())
        }
    }

    #[inline]
    fn get(&self, code: u32) -> &[u32] {
        match self {
            Buckets::Dense(v) => v.get(code as usize).map(|b| b.as_slice()).unwrap_or(&[]),
            Buckets::Map(m) => m.get(&code).map(|b| b.as_slice()).unwrap_or(&[]),
        }
    }

    #[inline]
    fn push(&mut self, code: u32, id: u32) {
        match self {
            Buckets::Dense(v) => v[code as usize].push(id),
            Buckets::Map(m) => m.entry(code).or_default().push(id),
        }
    }

    fn remove_id(&mut self, code: u32, id: u32) -> bool {
        let b = match self {
            Buckets::Dense(v) => &mut v[code as usize],
            Buckets::Map(m) => match m.get_mut(&code) {
                Some(b) => b,
                None => return false,
            },
        };
        if let Some(pos) = b.iter().position(|&v| v == id) {
            b.swap_remove(pos);
            if b.is_empty() {
                if let Buckets::Map(m) = self {
                    m.remove(&code);
                }
            }
            true
        } else {
            false
        }
    }

    fn clear(&mut self) {
        match self {
            Buckets::Dense(v) => v.iter_mut().for_each(|b| b.clear()),
            Buckets::Map(m) => m.clear(),
        }
    }

    fn non_empty(&self) -> usize {
        match self {
            Buckets::Dense(v) => v.iter().filter(|b| !b.is_empty()).count(),
            Buckets::Map(m) => m.len(),
        }
    }

    fn for_each_bucket(&self, mut f: impl FnMut(&[u32])) {
        match self {
            Buckets::Dense(v) => v.iter().filter(|b| !b.is_empty()).for_each(|b| f(b)),
            Buckets::Map(m) => m.values().for_each(|b| f(b)),
        }
    }
}

/// L hash tables over point ids.
pub struct LshTables<H: SrpHasher> {
    hasher: H,
    /// tables[t] : code -> point ids
    tables: Vec<Buckets>,
    /// number of points inserted
    len: usize,
}

/// Bucket-occupancy statistics (diagnostics + table-tuning experiments).
#[derive(Debug, Clone, PartialEq)]
pub struct TableStats {
    /// Total number of (non-empty) buckets across all tables.
    pub buckets: usize,
    /// Mean bucket size over non-empty buckets.
    pub mean_bucket: f64,
    /// Largest bucket size.
    pub max_bucket: usize,
    /// Fraction of the 2^K key space occupied, averaged over tables.
    pub occupancy: f64,
}

impl<H: SrpHasher> LshTables<H> {
    /// Empty tables wrapping `hasher`.
    pub fn new(hasher: H) -> Self {
        let l = hasher.l();
        let k = hasher.k();
        LshTables { hasher, tables: (0..l).map(|_| Buckets::new(k)).collect(), len: 0 }
    }

    /// Build from a set of row vectors (`rows[i]` inserted with id `i`).
    pub fn build<'a, I>(hasher: H, rows: I) -> Result<Self>
    where
        I: IntoIterator<Item = &'a [f32]>,
    {
        let mut t = Self::new(hasher);
        for (i, r) in rows.into_iter().enumerate() {
            t.insert(i as u32, r)?;
        }
        Ok(t)
    }

    /// Insert a point id with its vector into every table.
    pub fn insert(&mut self, id: u32, x: &[f32]) -> Result<()> {
        if x.len() != self.hasher.dim() {
            return Err(Error::Lsh(format!(
                "insert dim {} into hasher dim {}",
                x.len(),
                self.hasher.dim()
            )));
        }
        for t in 0..self.tables.len() {
            let code = self.hasher.code(t, x);
            self.tables[t].push(code, id);
        }
        self.len += 1;
        Ok(())
    }

    /// Insert a pre-computed (table, code) pair for `id`. Pipeline building
    /// block: hash workers compute codes in parallel and a single owner
    /// thread applies them. The caller is responsible for covering every
    /// table exactly once per id; `finish_coded_inserts` sets the length.
    #[inline]
    pub fn insert_coded(&mut self, table: usize, code: u32, id: u32) {
        self.tables[table].push(code, id);
    }

    /// Declare how many distinct ids were inserted via `insert_coded`.
    pub fn finish_coded_inserts(&mut self, n: usize) {
        self.len = n;
    }

    /// Remove a point id (requires the same vector it was inserted with).
    /// Returns true if found in all tables.
    pub fn remove(&mut self, id: u32, x: &[f32]) -> bool {
        let mut found_everywhere = true;
        for t in 0..self.tables.len() {
            let code = self.hasher.code(t, x);
            if !self.tables[t].remove_id(code, id) {
                found_everywhere = false;
            }
        }
        if found_everywhere && self.len > 0 {
            self.len -= 1;
        }
        found_everywhere
    }

    /// Number of inserted points.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no points inserted.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The wrapped hasher.
    pub fn hasher(&self) -> &H {
        &self.hasher
    }

    /// The bucket in table `t` matching the query (computes the query's
    /// meta-hash for that table only — the Algorithm 1 cost model).
    #[inline]
    pub fn query_bucket(&self, t: usize, query: &[f32]) -> &[u32] {
        let code = self.hasher.code(t, query);
        self.bucket(t, code)
    }

    /// The bucket in table `t` under an explicit code.
    #[inline]
    pub fn bucket(&self, t: usize, code: u32) -> &[u32] {
        self.tables[t].get(code)
    }

    /// Union of the query's buckets over all L tables, deduplicated — the
    /// *near-neighbor candidate set* of Appendix A.1, used by the §2.2.1
    /// cost comparison (this is exactly the work LGD avoids).
    pub fn candidate_union(&self, query: &[f32]) -> Vec<u32> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for t in 0..self.tables.len() {
            for &id in self.query_bucket(t, query) {
                if seen.insert(id) {
                    out.push(id);
                }
            }
        }
        out
    }

    /// Occupancy statistics.
    pub fn stats(&self) -> TableStats {
        let mut buckets = 0usize;
        let mut total = 0usize;
        let mut max_bucket = 0usize;
        for t in &self.tables {
            buckets += t.non_empty();
            t.for_each_bucket(|b| {
                total += b.len();
                max_bucket = max_bucket.max(b.len());
            });
        }
        let key_space = (1u64 << self.hasher.k()) as f64;
        let occupancy = if self.tables.is_empty() {
            0.0
        } else {
            self.tables.iter().map(|t| t.non_empty() as f64 / key_space).sum::<f64>()
                / self.tables.len() as f64
        };
        TableStats {
            buckets,
            mean_bucket: if buckets == 0 { 0.0 } else { total as f64 / buckets as f64 },
            max_bucket,
            occupancy,
        }
    }

    /// Rebuild all tables from scratch with new vectors (Appendix E: BERT
    /// pooled representations drift during fine-tuning and are re-hashed
    /// periodically). Ids are assigned 0..rows.len().
    pub fn rebuild<'a, I>(&mut self, rows: I) -> Result<()>
    where
        I: IntoIterator<Item = &'a [f32]>,
    {
        for t in self.tables.iter_mut() {
            t.clear();
        }
        self.len = 0;
        for (i, r) in rows.into_iter().enumerate() {
            self.insert(i as u32, r)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::{Pcg64, Rng};
    use crate::lsh::srp::DenseSrp;

    fn unit_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(seed);
        (0..n)
            .map(|_| {
                let mut v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                crate::core::matrix::normalize(&mut v);
                v
            })
            .collect()
    }

    #[test]
    fn every_point_lands_in_every_table() {
        let rows = unit_rows(50, 8, 1);
        let h = DenseSrp::new(8, 4, 6, 2);
        let t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        assert_eq!(t.len(), 50);
        let s = t.stats();
        // all 50 ids per table
        let total: usize = (0..6)
            .map(|ti| {
                (0..(1u32 << 4)).map(|c| t.bucket(ti, c).len()).sum::<usize>()
            })
            .sum();
        assert_eq!(total, 50 * 6);
        assert!(s.max_bucket >= 1);
        assert!(s.occupancy > 0.0 && s.occupancy <= 1.0);
    }

    #[test]
    fn query_self_always_finds_self() {
        let rows = unit_rows(30, 12, 3);
        let h = DenseSrp::new(12, 5, 8, 4);
        let t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        for (i, r) in rows.iter().enumerate() {
            for ti in 0..8 {
                let b = t.query_bucket(ti, r);
                assert!(b.contains(&(i as u32)), "point {i} missing from its own bucket");
            }
        }
    }

    #[test]
    fn insert_remove_roundtrip() {
        let rows = unit_rows(20, 6, 5);
        let h = DenseSrp::new(6, 3, 4, 6);
        let mut t = LshTables::new(h);
        for (i, r) in rows.iter().enumerate() {
            t.insert(i as u32, r).unwrap();
        }
        assert_eq!(t.len(), 20);
        assert!(t.remove(7, &rows[7]));
        assert_eq!(t.len(), 19);
        for ti in 0..4 {
            assert!(!t.query_bucket(ti, &rows[7]).contains(&7));
        }
        // removing again fails cleanly
        assert!(!t.remove(7, &rows[7]));
    }

    #[test]
    fn dim_mismatch_rejected() {
        let h = DenseSrp::new(6, 3, 2, 1);
        let mut t = LshTables::new(h);
        assert!(t.insert(0, &[1.0; 5]).is_err());
    }

    /// `remove` + re-`insert` round-trip: bucket membership, `len()` and
    /// `stats()` all identical to a fresh build of the same rows. (Bucket
    /// *order* may differ — removal swap-removes and re-insertion appends —
    /// so membership is compared as sorted sets.)
    #[test]
    fn remove_reinsert_roundtrip_matches_fresh_build() {
        let rows = unit_rows(40, 8, 21);
        let h = DenseSrp::new(8, 4, 6, 22);
        let fresh = LshTables::build(h.clone(), rows.iter().map(|r| r.as_slice())).unwrap();
        let mut t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        for &id in &[3u32, 17, 39, 0] {
            assert!(t.remove(id, &rows[id as usize]));
        }
        assert_eq!(t.len(), 36);
        for &id in &[0u32, 39, 17, 3] {
            t.insert(id, &rows[id as usize]).unwrap();
        }
        assert_eq!(t.len(), fresh.len());
        assert_eq!(t.stats(), fresh.stats());
        for ti in 0..6 {
            for code in 0..(1u32 << 4) {
                let mut a = fresh.bucket(ti, code).to_vec();
                let mut b = t.bucket(ti, code).to_vec();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "table {ti} code {code}");
            }
        }
    }

    /// Property form of the round-trip over random shapes and removal
    /// sets, including the empty-removal and remove-everything cases.
    #[test]
    fn prop_remove_reinsert_roundtrip() {
        use crate::testkit::{gen, prop};
        prop(25, |rng| {
            let n = gen::size(rng, 1, 60);
            let d = gen::size(rng, 3, 10);
            let k = gen::size(rng, 2, 5);
            let l = gen::size(rng, 2, 8);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| gen::unit_vec(rng, d)).collect();
            let h = DenseSrp::new(d, k, l, rng.next_u64());
            let fresh = LshTables::build(h.clone(), rows.iter().map(|r| r.as_slice())).unwrap();
            let mut t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
            let kill: Vec<u32> = (0..n as u32).filter(|_| rng.bernoulli(0.4)).collect();
            for &id in &kill {
                assert!(t.remove(id, &rows[id as usize]));
            }
            assert_eq!(t.len(), n - kill.len());
            if let Some(&id) = kill.first() {
                assert!(!t.remove(id, &rows[id as usize]), "double remove must fail");
                assert_eq!(t.len(), n - kill.len(), "failed remove must not change len");
            }
            for &id in kill.iter().rev() {
                t.insert(id, &rows[id as usize]).unwrap();
            }
            assert_eq!(t.len(), fresh.len());
            assert_eq!(t.stats(), fresh.stats());
            for ti in 0..l {
                for code in 0..(1u32 << k) {
                    let mut a = fresh.bucket(ti, code as u32).to_vec();
                    let mut b = t.bucket(ti, code as u32).to_vec();
                    a.sort_unstable();
                    b.sort_unstable();
                    assert_eq!(a, b, "table {ti} code {code}");
                }
            }
        });
    }

    #[test]
    fn candidate_union_dedups_and_contains_near() {
        let rows = unit_rows(40, 10, 7);
        let h = DenseSrp::new(10, 3, 12, 8);
        let t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        let cands = t.candidate_union(&rows[3]);
        // the point itself must be a candidate (collides with itself in all tables)
        assert!(cands.contains(&3));
        let mut d = cands.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), cands.len(), "union must be deduplicated");
    }

    #[test]
    fn rebuild_replaces_contents() {
        let rows = unit_rows(10, 6, 9);
        let rows2 = unit_rows(15, 6, 10);
        let h = DenseSrp::new(6, 3, 4, 11);
        let mut t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        t.rebuild(rows2.iter().map(|r| r.as_slice())).unwrap();
        assert_eq!(t.len(), 15);
        for ti in 0..4 {
            let b = t.query_bucket(ti, &rows2[14]);
            assert!(b.contains(&14));
        }
    }
}
